import os
import sys

# Make `compile` importable when pytest runs from the repo root
# (python/ is the package root for the build-time code).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
