import os
import sys

# Make `compile` importable when pytest runs from the repo root
# (python/ is the package root for the build-time code).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))

# The package registry is unreachable in this environment. When the real
# `hypothesis` is absent, install a deterministic mini-shim implementing
# the surface the tests use (given/settings + integers/floats/sampled_from
# strategies) so the property suites still run everywhere. Shrinking is
# not implemented; failures report the drawn example via the assertion.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import functools
    import random
    import types
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _sampled_from(choices):
        choices = list(choices)
        return _Strategy(lambda r: r.choice(choices))

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest must not see the strategy params as fixtures
            del wrapper.__wrapped__
            return wrapper

        return deco

    def _settings(max_examples=20, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
