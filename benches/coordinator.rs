//! Bench: coordinator hot paths — routing, admission, batch assembly —
//! independent of PJRT (pure L3 overhead; should be negligible next to
//! model execution, per DESIGN.md §Perf L3).

use std::sync::mpsc::channel;
use std::time::Instant;

use had::coordinator::{assemble_padded, BatchPolicy, BucketQueue, Router, SessionStore};
use had::coordinator::request::Request;
use had::kvcache::KvCacheConfig;
use had::util::bench::Bencher;
use had::util::rng::Rng;

fn mk_request(id: u64, len: usize) -> Request {
    let (tx, rx) = channel();
    std::mem::forget(rx); // keep the channel alive for the bench
    Request {
        id,
        tokens: vec![1; len],
        arrival: Instant::now(),
        reply: tx,
        session: None,
        trace: had::obs::SpanId::NONE,
    }
}

fn main() {
    let b = Bencher::default();
    let router = Router::longqa_default();
    let mut rng = Rng::new(3);

    // routing
    let lens: Vec<usize> = (0..1024).map(|_| rng.range_usize(1, 1025)).collect();
    let s = b.run("router/route x1024", || {
        let mut acc = 0usize;
        for &l in &lens {
            acc += router.route(l).unwrap().n_ctx;
        }
        acc
    });
    s.print_throughput(1024.0, "req");

    // admission + drain cycle
    let bucket = router.buckets()[1].clone(); // 256-bucket
    let s = b.run("batcher/push+drain batch of 16", || {
        let mut q = BucketQueue::new(bucket.clone(), BatchPolicy::default());
        for i in 0..16u64 {
            let _ = q.push(mk_request(i, 200));
        }
        let mut n = 0;
        while !q.is_empty() {
            n += q.drain_batch().len();
        }
        n
    });
    s.print();

    // batch assembly (padding + row duplication)
    for n_ctx in [128usize, 1024] {
        let reqs: Vec<Request> = (0..8).map(|i| mk_request(i, n_ctx * 3 / 4)).collect();
        let s = b.run(&format!("batcher/assemble 8x{n_ctx}"), || {
            assemble_padded(&reqs, n_ctx, 8, 0)
        });
        s.print_throughput((8 * n_ctx) as f64, "tok");
    }

    // end-to-end queue throughput under a zipfian-ish length mix
    let s = b.run("coordinator/admit 256 mixed-length reqs", || {
        let mut queues: Vec<BucketQueue> = router
            .buckets()
            .iter()
            .map(|bk| BucketQueue::new(bk.clone(), BatchPolicy { queue_cap: 512, ..Default::default() }))
            .collect();
        let mut rng = Rng::new(7);
        let mut admitted = 0usize;
        for i in 0..256u64 {
            let len = [64usize, 200, 400, 900][rng.range_usize(0, 4)];
            let idx = router
                .buckets()
                .iter()
                .position(|bk| bk.n_ctx >= len)
                .unwrap();
            if queues[idx].push(mk_request(i, len)).is_ok() {
                admitted += 1;
            }
        }
        admitted
    });
    s.print_throughput(256.0, "req");

    // session admission: multi-turn history extension (K/V production
    // moved to the backend's decode pass, so admission is token
    // bookkeeping only — it must be cheap enough to hold the sessions
    // lock on the submit path).
    let s = b.run("coordinator/session admit 16x8 turns", || {
        let mut store = SessionStore::new(KvCacheConfig::default().into());
        let mut appended = 0usize;
        for turn in 0..8 {
            for sid in 0..16u64 {
                let tokens: Vec<i32> = (0..32).map(|t| (sid as i32 * 37 + turn * 13 + t) % 256).collect();
                let info = store.admit(sid, &tokens);
                appended += info.appended_tokens;
            }
        }
        appended
    });
    s.print_throughput((16 * 8) as f64, "admit");

    // steady-state history accounting over one long-lived store
    let mut store = SessionStore::new(KvCacheConfig::default().into());
    for turn in 0..20i32 {
        for sid in 0..8u64 {
            let tokens: Vec<i32> = (0..16).map(|t| (turn * 16 + t) % 256).collect();
            store.admit(sid, &tokens);
        }
    }
    let total: usize = (0..8u64).map(|sid| store.history_len(sid)).sum();
    println!(
        "coordinator/session store: 8 sessions x 20 turns resident, {} history tokens ({} KiB)",
        total,
        total * 4 / 1024,
    );
}
