//! Bench: PJRT execution latency per artifact — the Figure-1 measurement
//! (full vs no-attention vs HAD forward at each context length) plus the
//! host<->literal conversion overhead the §Perf pass targets.

use had::data::longqa::{longqa_batch, LongQaGen};
use had::model::ParamSet;
use had::runtime::{default_artifact_dir, HostTensor, Runtime};
use had::util::bench::Bencher;
use had::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(dir)?;
    let b = Bencher::quick();
    let mut rng = Rng::new(11);

    println!("== single-request forward latency by context (Figure 1) ==");
    for n_ctx in [128usize, 256, 512, 1024] {
        let config = format!("longqa_{n_ctx}");
        let cfg = rt.manifest.config(&config)?;
        let params = ParamSet::init(cfg, &mut rng);
        let gen = LongQaGen::new(n_ctx);
        let batch = longqa_batch(&gen, &mut rng, 1);
        let l = cfg.model.n_layers;
        for artifact in ["fwd_standard_b1", "fwd_noattn_b1", "fwd_had_b1"] {
            let exe = rt.load(&format!("{config}__{artifact}"))?;
            let mut inputs: Vec<HostTensor> = params.tensors.clone();
            inputs.push(batch.x.clone());
            inputs.push(HostTensor::vec_f32(vec![1.0; l]));
            inputs.push(HostTensor::vec_f32(vec![1.0; l]));
            inputs.push(HostTensor::scalar_f32(cfg.model.n_top as f32));
            exe.run(&inputs)?; // warm
            let s = b.run(&format!("{config}/{artifact}"), || exe.run(&inputs).unwrap());
            s.print();
        }
    }

    println!("\n== host tensor -> literal conversion overhead ==");
    let cfg = rt.manifest.config("tinyglue")?;
    let params = ParamSet::init(cfg, &mut rng);
    let s = b.run("to_literal: full tinyglue param set", || {
        params
            .tensors
            .iter()
            .map(|t| t.to_literal().unwrap())
            .count()
    });
    s.print_throughput(params.total_elems() as f64 * 4.0, "byte");

    println!("\n== batched eval forward (serving path) ==");
    let config = "longqa_256";
    let cfg = rt.manifest.config(config)?;
    let params = ParamSet::init(cfg, &mut rng);
    let gen = LongQaGen::new(256);
    let batch = longqa_batch(&gen, &mut rng, cfg.eval_batch);
    let exe = rt.load(&format!("{config}__fwd_had"))?;
    let mut inputs: Vec<HostTensor> = params.tensors.clone();
    inputs.push(batch.x.clone());
    inputs.push(HostTensor::vec_f32(vec![1.0; cfg.model.n_layers]));
    inputs.push(HostTensor::vec_f32(vec![1.0; cfg.model.n_layers]));
    inputs.push(HostTensor::scalar_f32(cfg.model.n_top as f32));
    exe.run(&inputs)?;
    let s = b.run("longqa_256/fwd_had batch=16", || exe.run(&inputs).unwrap());
    s.print_throughput(cfg.eval_batch as f64, "req");
    Ok(())
}
