//! Bench: packed-KV residency — the serving claim behind the kvcache
//! subsystem. Compares COLD full-prefill (rebuild every page, what a
//! stateless coordinator does per request) against WARM incremental
//! append (session pages resident, pack only this turn's tokens) across
//! context lengths, plus page-pool hit/miss accounting under skewed
//! multi-session traffic.
//!
//! Appends machine-readable records to results/kvcache.jsonl for
//! scripts/summarize_results.py (warm-vs-cold p50/p99 and hit rate).

use had::binary::attention::{had_attention_paged_with, Scratch};
use had::binary::HadAttnConfig;
use had::kvcache::{KvCacheConfig, PagePool, SessionKv};
use had::tensor::Mat;
use had::util::bench::{Bencher, Stats, write_jsonl};
use had::util::json::Json;
use had::util::rng::Rng;

fn latency_record(mode: &str, n_ctx: usize, s: &Stats) -> Json {
    let us = |d: std::time::Duration| d.as_nanos() as f64 / 1e3;
    Json::obj(vec![
        ("kind", Json::str("latency")),
        ("mode", Json::str(mode)),
        ("n_ctx", Json::num(n_ctx as f64)),
        ("p50_us", Json::num(us(s.p50))),
        ("p99_us", Json::num(us(s.p99))),
        ("mean_us", Json::num(us(s.mean))),
    ])
}

fn main() {
    let b = Bencher::from_env(); // HAD_BENCH_QUICK=1 for the CI smoke step
    let mut rng = Rng::new(17);
    let (d, d_v, n_q, turn, page_tokens) = (64usize, 64usize, 16usize, 16usize, 64usize);
    let mut records: Vec<Json> = Vec::new();

    println!("== paged KV cache: cold full-prefill vs warm incremental append ==");
    let mut longest: Option<(Stats, Stats)> = None;
    for n_ctx in [512usize, 2048, 8192] {
        let k = Mat::random(n_ctx, d, &mut rng, 1.0);
        let v = Mat::random(n_ctx, d_v, &mut rng, 1.0);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let cfg = HadAttnConfig { n_top: (30 * n_ctx / 256).max(1), temp: 1.0 };
        let mut scratch = Scratch::default();

        // cold: rebuild the whole session, then attend
        let s_cold = b.run(&format!("kvcache/cold prefill+attend n_ctx={n_ctx}"), || {
            let mut kv = SessionKv::new(d, d_v, page_tokens);
            kv.append(&k, &v);
            had_attention_paged_with(&q, &kv, &cfg, &mut scratch)
        });

        // warm: resident session, pack only the final `turn` tokens
        let base = n_ctx - turn;
        let turn_k = Mat::from_vec(turn, d, k.data[base * d..].to_vec());
        let turn_v = Mat::from_vec(turn, d_v, v.data[base * d_v..].to_vec());
        let mut warm = SessionKv::new(d, d_v, page_tokens);
        warm.append(&k, &v);
        let s_warm = b.run(&format!("kvcache/warm append+attend  n_ctx={n_ctx}"), || {
            warm.truncate(base);
            warm.append(&turn_k, &turn_v);
            had_attention_paged_with(&q, &warm, &cfg, &mut scratch)
        });

        s_cold.print();
        s_warm.print();
        println!(
            "  -> warm incremental speedup {:.2}x (prefill work: {n_ctx} vs {turn} tokens)",
            s_cold.mean_ns() / s_warm.mean_ns()
        );
        records.push(latency_record("cold", n_ctx, &s_cold));
        records.push(latency_record("warm", n_ctx, &s_warm));
        longest = Some((s_cold.clone(), s_warm.clone()));
    }
    // the acceptance gate: on the longest context, warm must win.
    // Relaxed in quick mode — the CI smoke step's tiny budgets on noisy
    // shared runners would make a hard perf assert flaky.
    let (cold, warm) = longest.expect("at least one context bucket");
    if had::util::bench::quick_env() {
        println!("(HAD_BENCH_QUICK set: skipping the warm-vs-cold perf gate)");
    } else {
        assert!(
            warm.mean < cold.mean,
            "warm incremental append must beat cold full prefill on the longest context"
        );
    }

    println!("\n== page-pool residency under skewed multi-turn traffic ==");
    // 2 hot sessions speak every turn; 8 one-shot cold sessions pass
    // through. The budget holds two full hot sessions only: cold sessions
    // get evicted (LRU), hot ones stay resident and keep hitting.
    let full_turns = 8usize;
    let per_turn = page_tokens; // one page per turn
    let page_payload = KvCacheConfig::default().page_payload_bytes(d, d_v);
    let pool_cfg = KvCacheConfig {
        page_tokens,
        byte_budget: 2 * full_turns * page_payload,
        ..Default::default()
    };
    let mut pool: PagePool = PagePool::new(pool_cfg);
    let mk = |rng: &mut Rng| {
        (Mat::random(per_turn, d, rng, 1.0), Mat::random(per_turn, d_v, rng, 1.0))
    };
    for t in 0..full_turns as u64 {
        // hot sessions 0 and 1 speak every turn and stay resident
        for id in 0..2u64 {
            let (k, v) = mk(&mut rng);
            pool.append(id, &k, &v);
        }
        // a different cold session appears each turn and is evicted later
        let (k, v) = mk(&mut rng);
        pool.append(100 + t, &k, &v);
    }
    let stats = pool.stats();
    println!(
        "pool: {} sessions resident, {} KiB / {} KiB budget | {} hits {} misses ({:.1}% hit) | {} evictions ({} KiB freed)",
        pool.len(),
        pool.bytes() / 1024,
        pool.budget() / 1024,
        stats.hits,
        stats.misses,
        100.0 * stats.hit_rate(),
        stats.evictions,
        stats.evicted_bytes / 1024,
    );
    records.push(Json::obj(vec![
        ("kind", Json::str("pool")),
        ("hits", Json::num(stats.hits as f64)),
        ("misses", Json::num(stats.misses as f64)),
        ("hit_rate", Json::num(stats.hit_rate())),
        ("evictions", Json::num(stats.evictions as f64)),
        ("resident_bytes", Json::num(pool.bytes() as f64)),
    ]));

    // persist for scripts/summarize_results.py
    if let Err(e) = write_jsonl("results/kvcache.jsonl", &records) {
        eprintln!("could not write results/kvcache.jsonl: {e}");
    }
    println!("\nkvcache bench OK");
}

