//! Bench: the CPU serving backend end to end — decode throughput
//! (tokens/sec) for cold prefill vs warm per-turn suffix decode, the
//! per-layer kernel share of decode time, and session serving through
//! the full coordinator (hit rate + latency percentiles).
//!
//! Appends machine-readable records to results/serve.jsonl for
//! scripts/summarize_results.py.

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::kvcache::KvCacheConfig;
use had::serve::{demo_config, HadBackend, ServeModel};
use had::util::bench::{quick_env, Bencher, write_jsonl};
use had::util::json::Json;
use had::util::rng::Rng;

fn main() {
    let b = Bencher::from_env(); // HAD_BENCH_QUICK=1 for the CI smoke step
    let quick = quick_env();
    let contexts: &[usize] = if quick { &[256] } else { &[256, 1024] };
    let turn = 16usize;

    let cfg = demo_config("serve_bench", 1024, 64);
    let vocab = cfg.model.vocab as u64;
    let model = ServeModel::random(&cfg, 0xFACE).expect("bench model");
    let kv = KvCacheConfig { page_tokens: 64, ..Default::default() };
    let backend = HadBackend::new(model.clone(), &kv);
    let mut rng = Rng::new(3);
    let mut records: Vec<Json> = Vec::new();

    println!("== serving backend: cold prefill vs warm suffix decode ==");
    let mut longest: Option<(f64, f64)> = None; // (cold mean ns, warm mean ns)
    for &n_ctx in contexts {
        let tokens: Vec<i32> = (0..n_ctx).map(|_| rng.below(vocab) as i32).collect();

        // cold: full-sequence decode into a fresh per-layer cache
        let mut cold_share = 0.0f64;
        let s_cold = b.run(&format!("serve/cold prefill n_ctx={n_ctx}"), || {
            let mut state = backend.fresh_kv();
            let (caps, stats) = backend.decode(&mut state, &tokens, &[n_ctx]);
            cold_share = stats.attn_us as f64 / (stats.decode_us.max(1)) as f64;
            caps
        });
        s_cold.print_throughput(n_ctx as f64, "tok");

        // warm: resident context, decode only a +`turn`-token suffix
        let mut state = backend.fresh_kv();
        backend.decode(&mut state, &tokens, &[n_ctx]);
        let mut warm_share = 0.0f64;
        let s_warm = b.run(&format!("serve/warm +{turn} turn  n_ctx={n_ctx}"), || {
            state.truncate(n_ctx - turn);
            let (caps, stats) = backend.decode(&mut state, &tokens, &[n_ctx]);
            debug_assert_eq!(stats.resumed_at, n_ctx - turn);
            warm_share = stats.attn_us as f64 / (stats.decode_us.max(1)) as f64;
            caps
        });
        s_warm.print_throughput(turn as f64, "tok");
        println!(
            "  -> kernel share of decode: cold {:.1}% warm {:.1}% | warm turn {:.2}x cheaper than prefill",
            100.0 * cold_share,
            100.0 * warm_share,
            s_cold.mean_ns() / s_warm.mean_ns(),
        );
        for (mode, s, items, share) in [
            ("prefill", &s_cold, n_ctx, cold_share),
            ("turn", &s_warm, turn, warm_share),
        ] {
            records.push(Json::obj(vec![
                ("kind", Json::str("decode")),
                ("mode", Json::str(mode)),
                ("n_ctx", Json::num(n_ctx as f64)),
                ("tokens_per_s", Json::num(s.throughput(items as f64))),
                ("mean_us", Json::num(s.mean_ns() / 1e3)),
                ("kernel_share", Json::num(share)),
            ]));
        }
        longest = Some((s_cold.mean_ns(), s_warm.mean_ns()));
    }
    // acceptance gate: a warm turn must beat re-running the prefill.
    // Relaxed in quick mode (noisy shared CI runners, tiny budgets).
    let (cold, warm) = longest.expect("at least one context");
    if quick {
        println!("(HAD_BENCH_QUICK set: skipping the warm-vs-cold perf gate)");
    } else {
        assert!(
            warm < cold,
            "suffix decode must beat full re-execution on the longest context"
        );
    }

    println!("\n== session serving through the coordinator ==");
    let (n_sessions, n_turns) = if quick { (3u64, 3usize) } else { (4, 5) };
    let router = Router::new(vec![Bucket { config: "serve_bench".into(), n_ctx: 1024, batch: 8 }]);
    let server = Server::builder(
        HadBackend::new(model, &kv),
        router,
        BatchPolicy { max_wait: std::time::Duration::from_millis(1), ..Default::default() },
    )
    .kv(kv)
    .start()
    .expect("server start");
    for sid in 0..n_sessions {
        for t in 0..n_turns {
            let rows = if t == 0 { 96 } else { turn };
            let append: Vec<i32> = (0..rows).map(|_| rng.below(vocab) as i32).collect();
            server.infer_session(sid, append).expect("turn served");
        }
    }
    // a short generation pass so streaming decode-step/sampling stages
    // show up in metrics and (under HAD_TRACE) the exported trace
    for sid in 0..n_sessions.min(2) {
        let prompt: Vec<i32> = (0..4).map(|_| rng.below(vocab) as i32).collect();
        let out = server
            .generate_session(sid, had::generate::GenerateRequest::greedy(prompt, 6))
            .expect("stream served");
        assert!(!out.tokens.is_empty(), "generation produced tokens");
    }
    let snap = server.metrics.snapshot();
    let stats = server.cache_stats();
    let kernel_share = if snap.decode_mean_us > 0.0 {
        snap.kernel_mean_us / snap.decode_mean_us
    } else {
        0.0
    };
    println!(
        "sessions: {} reqs | hit rate {:.1}% ({} hits / {} misses) | latency p50 {:.2} ms p99 {:.2} ms | decode mean {:.2} ms (kernel share {:.1}%)",
        snap.requests,
        100.0 * stats.hit_rate(),
        stats.hits,
        stats.misses,
        snap.p50_us as f64 / 1e3,
        snap.p99_us as f64 / 1e3,
        snap.decode_mean_us / 1e3,
        100.0 * kernel_share,
    );
    assert!(
        stats.hits >= n_sessions * (n_turns as u64 - 1),
        "warm turns must resume from resident pages"
    );
    records.push(Json::obj(vec![
        ("kind", Json::str("sessions")),
        ("requests", Json::num(snap.requests as f64)),
        ("hit_rate", Json::num(stats.hit_rate())),
        ("p50_us", Json::num(snap.p50_us as f64)),
        ("p99_us", Json::num(snap.p99_us as f64)),
        ("decode_mean_us", Json::num(snap.decode_mean_us)),
        ("kernel_share", Json::num(kernel_share)),
    ]));

    if let Err(e) = write_jsonl("results/serve.jsonl", &records) {
        eprintln!("could not write results/serve.jsonl: {e}");
    }
    // graceful shutdown BEFORE the trace flush so scheduler-side spans
    // (ticks, stream umbrellas) are all recorded by export time
    let metrics = server.metrics.clone();
    drop(server);
    if let Some(path) = had::obs::flush_trace() {
        println!("trace written to {}", path.display());
    }
    if let Some(path) = had::obs::write_metrics_snapshot(metrics.registry()) {
        println!("metrics snapshot appended to {}", path.display());
    }
    println!("\nserve_backend bench OK");
}

