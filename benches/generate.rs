//! Bench: the autoregressive generation subsystem — direct engine-loop
//! token latency (TTFT + per-token step time), then streamed generation
//! through the continuous-batching coordinator at 1/4/16 concurrent
//! streams (TTFT and inter-token p50/p99, generated tokens/sec).
//!
//! Appends machine-readable records to results/generate.jsonl for
//! scripts/summarize_results.py.

use std::time::Instant;

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::generate::{generate, GenLimits, GenerateRequest, SamplingParams, StopReason};
use had::kvcache::KvCacheConfig;
use had::serve::{demo_config, HadBackend, ServeModel};
use had::util::bench::{percentile_us as pct, quick_env, write_jsonl};
use had::util::json::Json;
use had::util::rng::Rng;

fn main() {
    let quick = quick_env();
    let n_ctx = 1024usize;
    let prompt_len = if quick { 48 } else { 128 };
    let n_new = if quick { 12 } else { 48 };
    let stream_counts: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };

    let cfg = demo_config("gen_bench", n_ctx, 64);
    let vocab = cfg.model.vocab as u64;
    let model = ServeModel::random(&cfg, 0x6E6E).expect("bench model");
    let kv = KvCacheConfig { page_tokens: 64, ..Default::default() };
    let backend = HadBackend::new(model.clone(), &kv);
    let mut rng = Rng::new(11);
    let mut records: Vec<Json> = Vec::new();

    println!("== direct engine loop: prefill {prompt_len} + {n_new} greedy tokens ==");
    let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(vocab) as i32).collect();
    let mut kv_state = backend.fresh_kv();
    let mut token_at: Vec<Instant> = Vec::with_capacity(n_new);
    let t0 = Instant::now();
    let out = generate(
        &backend,
        &mut kv_state,
        &[],
        &GenerateRequest::greedy(prompt.clone(), n_new),
        &GenLimits { max_total_tokens: n_ctx, kv_budget_bytes: kv.byte_budget, ..GenLimits::unbounded() },
        |_, _| token_at.push(Instant::now()),
    );
    assert_eq!(out.reason, StopReason::MaxTokens);
    assert_eq!(out.tokens.len(), n_new, "bench stream must run to its token budget");
    let ttft_us = token_at[0].duration_since(t0).as_micros();
    let mut inter: Vec<u128> = token_at
        .windows(2)
        .map(|w| w[1].duration_since(w[0]).as_micros())
        .collect();
    inter.sort_unstable();
    let total_s = token_at.last().unwrap().duration_since(t0).as_secs_f64();
    let tok_s = n_new as f64 / total_s.max(1e-9);
    println!(
        "engine: ttft {:.2} ms | inter-token p50 {:.2} ms p99 {:.2} ms | {:.1} tok/s",
        ttft_us as f64 / 1e3,
        pct(&inter, 0.50) as f64 / 1e3,
        pct(&inter, 0.99) as f64 / 1e3,
        tok_s,
    );
    records.push(Json::obj(vec![
        ("kind", Json::str("engine")),
        ("prompt_len", Json::num(prompt_len as f64)),
        ("new_tokens", Json::num(n_new as f64)),
        ("ttft_us", Json::num(ttft_us as f64)),
        ("inter_p50_us", Json::num(pct(&inter, 0.50) as f64)),
        ("inter_p99_us", Json::num(pct(&inter, 0.99) as f64)),
        ("tokens_per_s", Json::num(tok_s)),
    ]));

    println!("\n== continuous-batching coordinator: concurrent streams ==");
    for &streams in stream_counts {
        // fresh server per point so Metrics isolate the configuration
        let router =
            Router::new(vec![Bucket { config: "gen_bench".into(), n_ctx, batch: 8 }]);
        let server = Server::builder(
            HadBackend::new(model.clone(), &kv),
            router,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                max_streams: 16,
                ..Default::default()
            },
        )
        .kv(kv)
        .start()
        .expect("server start");
        let rxs: Vec<_> = (0..streams)
            .map(|sid| {
                let p: Vec<i32> =
                    (0..prompt_len).map(|_| rng.below(vocab) as i32).collect();
                let req = GenerateRequest {
                    prompt: p,
                    max_new_tokens: n_new,
                    stop_tokens: Vec::new(),
                    sampling: SamplingParams::greedy(),
                };
                server.submit_generate(sid as u64, req).expect("stream admitted")
            })
            .collect();
        for rx in rxs {
            let mut generated = 0usize;
            for event in rx.iter() {
                match event {
                    had::generate::StreamEvent::Token { .. } => generated += 1,
                    had::generate::StreamEvent::Done { reason, .. } => {
                        assert_eq!(reason, StopReason::MaxTokens);
                        break;
                    }
                }
            }
            assert_eq!(generated, n_new, "every stream runs to its token budget");
        }
        let snap = server.metrics.snapshot();
        println!(
            "{streams:>2} streams: ttft p50 {:.2} ms p99 {:.2} ms | inter-token p50 {:.2} ms p99 {:.2} ms | {:.1} tok/s",
            snap.ttft_p50_us as f64 / 1e3,
            snap.ttft_p99_us as f64 / 1e3,
            snap.inter_token_p50_us as f64 / 1e3,
            snap.inter_token_p99_us as f64 / 1e3,
            snap.gen_tokens_per_s,
        );
        assert_eq!(snap.gen_streams as usize, streams);
        assert_eq!(snap.gen_tokens as usize, streams * n_new);
        records.push(Json::obj(vec![
            ("kind", Json::str("streams")),
            ("streams", Json::num(streams as f64)),
            ("new_tokens", Json::num(n_new as f64)),
            ("ttft_p50_us", Json::num(snap.ttft_p50_us as f64)),
            ("ttft_p99_us", Json::num(snap.ttft_p99_us as f64)),
            ("inter_p50_us", Json::num(snap.inter_token_p50_us as f64)),
            ("inter_p99_us", Json::num(snap.inter_token_p99_us as f64)),
            ("tokens_per_s", Json::num(snap.gen_tokens_per_s)),
        ]));
    }

    if let Err(e) = write_jsonl("results/generate.jsonl", &records) {
        eprintln!("could not write results/generate.jsonl: {e}");
    }
    println!("\ngenerate bench OK");
}

