//! Adversarial stress harness for the continuous-batching coordinator:
//! scenario generators (bursty arrivals, long-tail prompt lengths, slow
//! readers, disconnect storms, seeded fault sweeps) that drive a live
//! `Server` and assert the robustness invariants on every scenario —
//!
//!   * every admitted stream retires with an explicit `StopReason`
//!     (measured as `Snapshot::gen_streams == admitted`, which counts
//!     only `record_stream_retired` calls);
//!   * the page pool returns to its baseline (0 bytes) once every
//!     session ends — no leaked pages, whatever faults fired mid-flight;
//!   * the scheduler never deadlocks: a watchdog thread hard-exits the
//!     process (code 3) if a scenario overruns its budget.
//!
//! Runs under an ambient `HAD_FAULT` plan unchanged (the CI chaos leg
//! does exactly that), so invariant checks are fault-agnostic; the
//! fault-sweep scenario additionally pins its own seeded plan through
//! `Server::builder(..).chaos(plan)` for reproducibility. Appends
//! machine-readable records to results/stress.jsonl (provenance-stamped
//! schema v2) for scripts/validate_stress.py.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::generate::{generate, GenLimits, GenerateRequest, StreamEvent};
use had::kvcache::KvCacheConfig;
use had::serve::{demo_config, HadBackend, ServeModel};
use had::store::SpillStore;
use had::util::bench::{quick_env, write_jsonl};
use had::util::fault::FaultPlan;
use had::util::json::Json;
use had::util::rng::Rng;

const N_CTX: usize = 128;

fn kv_cfg() -> KvCacheConfig {
    KvCacheConfig { page_tokens: 16, ..Default::default() }
}

fn stress_server(model: &ServeModel, policy: BatchPolicy) -> Server {
    let kv = kv_cfg();
    let router =
        Router::new(vec![Bucket { config: "stress".into(), n_ctx: N_CTX, batch: 8 }]);
    Server::builder(HadBackend::new(model.clone(), &kv), router, policy)
        .kv(kv)
        .start()
        .expect("server start")
}

fn chaos_server(model: &ServeModel, policy: BatchPolicy, plan: FaultPlan) -> Server {
    let kv = kv_cfg();
    let router =
        Router::new(vec![Bucket { config: "stress".into(), n_ctx: N_CTX, batch: 8 }]);
    Server::builder(HadBackend::new(model.clone(), &kv), router, policy)
        .kv(kv)
        .chaos(plan)
        .start()
        .expect("server start")
}

/// Arm a deadlock watchdog: unless the returned flag is set within
/// `timeout`, the process exits 3 (distinct from assertion failures) so
/// CI reports a hang instead of idling until the job limit.
fn arm_watchdog(name: &'static str, timeout: Duration) -> Arc<AtomicBool> {
    let done = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&done);
    std::thread::spawn(move || {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            if flag.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        eprintln!("[stress] WATCHDOG: scenario '{name}' still live after {timeout:?} — deadlock suspected");
        std::process::exit(3);
    });
    done
}

/// Poll the server until every admitted stream has retired (explicit
/// `StopReason` — the only path that increments `gen_streams`).
fn wait_retired(server: &Server, admitted: u64) {
    while server.metrics.snapshot().gen_streams < admitted {
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// End every session and return the bytes still resident in the pool
/// (the leak count: must be 0 once nothing references the pool).
fn leaked_bytes(server: &Server, sids: &[u64]) -> usize {
    let store = server.sessions();
    let mut store = store.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    for &sid in sids {
        store.end_session(sid);
    }
    store.pool().bytes()
}

struct Outcome {
    admitted: u64,
    done_events: u64,
    leaked: usize,
}

impl Outcome {
    fn record(&self, name: &str, server: &Server) -> Json {
        let snap = server.metrics.snapshot();
        assert_eq!(
            snap.gen_streams, self.admitted,
            "{name}: every admitted stream must retire with an explicit StopReason"
        );
        assert_eq!(self.leaked, 0, "{name}: page pool must return to baseline");
        Json::obj(vec![
            ("kind", Json::str("stress")),
            ("name", Json::str(name)),
            ("admitted", Json::num(self.admitted as f64)),
            ("retired", Json::num(snap.gen_streams as f64)),
            ("done_events", Json::num(self.done_events as f64)),
            ("leaked_bytes", Json::num(self.leaked as f64)),
            ("watchdog_ok", Json::Bool(true)),
            ("ttft_p99_us", Json::num(snap.ttft_p99_us as f64)),
            ("faults_injected", Json::num(snap.faults_injected as f64)),
            ("deadline_exceeded", Json::num(snap.deadline_exceeded as f64)),
            ("slow_reader_disconnects", Json::num(snap.slow_reader_disconnects as f64)),
            ("stream_errors", Json::num(snap.stream_errors as f64)),
        ])
    }
}

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.below(256) as i32).collect()
}

/// Drain receivers on reader threads; returns how many saw a Done event.
fn drain_all(rxs: Vec<std::sync::mpsc::Receiver<StreamEvent>>, read_delay: Duration) -> u64 {
    let handles: Vec<_> = rxs
        .into_iter()
        .map(|rx| {
            std::thread::spawn(move || {
                let mut saw_done = 0u64;
                for event in rx.iter() {
                    if !read_delay.is_zero() {
                        std::thread::sleep(read_delay);
                    }
                    if let StreamEvent::Done { .. } = event {
                        saw_done = 1;
                        break;
                    }
                }
                saw_done
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("reader thread")).sum()
}

/// Bursty arrivals: waves of concurrent streams separated by idle gaps.
fn scenario_burst(model: &ServeModel, quick: bool) -> Json {
    let done = arm_watchdog("burst", Duration::from_secs(120));
    let (waves, per_wave, n_new) = if quick { (2, 4, 6) } else { (4, 8, 12) };
    let server = stress_server(
        model,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_streams: 8,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(0xB0057);
    let mut admitted = 0u64;
    let mut done_events = 0u64;
    let mut sids = Vec::new();
    for wave in 0..waves {
        let mut rxs = Vec::new();
        for i in 0..per_wave {
            let sid = (wave * per_wave + i) as u64;
            let p = prompt(&mut rng, 8 + rng.below(24) as usize);
            if let Ok(rx) = server.submit_generate(sid, GenerateRequest::greedy(p, n_new)) {
                admitted += 1;
                sids.push(sid);
                rxs.push(rx);
            }
        }
        done_events += drain_all(rxs, Duration::ZERO);
        std::thread::sleep(Duration::from_millis(5));
    }
    wait_retired(&server, admitted);
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome { admitted, done_events, leaked };
    let rec = out.record("burst", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Long-tail lengths: mixed short prompts and near-cap prompts racing
/// through the same pool (chunked prefill keeps ticks bounded).
fn scenario_longtail(model: &ServeModel, quick: bool) -> Json {
    let done = arm_watchdog("longtail", Duration::from_secs(120));
    let n = if quick { 6 } else { 12 };
    let server = stress_server(
        model,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_streams: 4,
            prefill_chunk: 16,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(0x7A17);
    let mut admitted = 0u64;
    let mut sids = Vec::new();
    let mut rxs = Vec::new();
    for sid in 0..n as u64 {
        // 1/3 near-cap prompts, the rest short — long prefills must not
        // starve the short streams or wedge admission
        let len = if sid % 3 == 0 { N_CTX - 16 } else { 4 + rng.below(12) as usize };
        let n_new = if sid % 3 == 0 { 4 } else { 8 };
        if let Ok(rx) = server.submit_generate(sid, GenerateRequest::greedy(prompt(&mut rng, len), n_new)) {
            admitted += 1;
            sids.push(sid);
            rxs.push(rx);
        }
    }
    let done_events = drain_all(rxs, Duration::ZERO);
    wait_retired(&server, admitted);
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome { admitted, done_events, leaked };
    let rec = out.record("longtail", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Slow readers: tiny bounded event channels and readers that sleep
/// between events — the scheduler must disconnect them, never block.
fn scenario_slow_reader(model: &ServeModel, quick: bool) -> Json {
    let done = arm_watchdog("slow_reader", Duration::from_secs(120));
    let n = if quick { 4 } else { 8 };
    let server = stress_server(
        model,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_streams: 8,
            stream_event_cap: 2,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(0x510);
    let mut admitted = 0u64;
    let mut sids = Vec::new();
    let mut rxs = Vec::new();
    for sid in 0..n as u64 {
        if let Ok(rx) = server.submit_generate(sid, GenerateRequest::greedy(prompt(&mut rng, 12), 24)) {
            admitted += 1;
            sids.push(sid);
            rxs.push(rx);
        }
    }
    // readers sleep far longer than a decode step: channels fill
    let done_events = drain_all(rxs, Duration::from_millis(25));
    wait_retired(&server, admitted);
    if std::env::var("HAD_FAULT").is_err() {
        // without ambient faults racing retirement, at least one stream
        // must have hit the slow-reader disconnect path
        assert!(
            server.metrics.snapshot().slow_reader_disconnects >= 1,
            "slow_reader: bounded channels never filled"
        );
    }
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome { admitted, done_events, leaked };
    let rec = out.record("slow_reader", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Disconnect storm: half the clients drop their receivers immediately
/// after admission; the other half read normally.
fn scenario_disconnect_storm(model: &ServeModel, quick: bool) -> Json {
    let done = arm_watchdog("disconnect_storm", Duration::from_secs(120));
    let n = if quick { 6 } else { 12 };
    let server = stress_server(
        model,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_streams: 6,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(0xD15C);
    let mut admitted = 0u64;
    let mut sids = Vec::new();
    let mut rxs = Vec::new();
    for sid in 0..n as u64 {
        match server.submit_generate(sid, GenerateRequest::greedy(prompt(&mut rng, 10), 12)) {
            Ok(rx) => {
                admitted += 1;
                sids.push(sid);
                if sid % 2 == 0 {
                    drop(rx); // storm: client vanishes right away
                } else {
                    rxs.push(rx);
                }
            }
            Err(_) => {}
        }
    }
    let done_events = drain_all(rxs, Duration::ZERO);
    wait_retired(&server, admitted);
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome { admitted, done_events, leaked };
    let rec = out.record("disconnect_storm", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Seeded fault sweep: every injection site live at once, pinned to an
/// instance-scoped plan so the sweep replays identically per seed.
fn scenario_fault_sweep(model: &ServeModel, quick: bool, seed: u64) -> Json {
    let done = arm_watchdog("fault_sweep", Duration::from_secs(180));
    let n = if quick { 6 } else { 12 };
    let spec = format!(
        "decode_step:0.3:2,worker_panic:0.15,client_disconnect:0.1,pool_pressure:0.2,queue_stall:0.1:2,seed={seed}"
    );
    let plan = FaultPlan::parse(&spec).expect("fault spec");
    let server = chaos_server(
        model,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_streams: 4,
            stream_deadline_ms: 30_000,
            ..Default::default()
        },
        plan,
    );
    let mut rng = Rng::new(seed ^ 0xFA175);
    let mut admitted = 0u64;
    let mut sids = Vec::new();
    let mut rxs = Vec::new();
    for sid in 0..n as u64 {
        if let Ok(rx) = server.submit_generate(sid, GenerateRequest::greedy(prompt(&mut rng, 16), 10)) {
            admitted += 1;
            sids.push(sid);
            rxs.push(rx);
        }
    }
    let done_events = drain_all(rxs, Duration::ZERO);
    wait_retired(&server, admitted);
    assert!(
        server.metrics.snapshot().faults_injected > 0,
        "fault_sweep: the seeded plan never fired"
    );
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome { admitted, done_events, leaked };
    let rec = out.record("fault_sweep", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Spill-tier chaos: a pool budget of TWO resident sessions forces
/// constant stripe traffic to the disk tier while seeded
/// `spill_write`/`spill_read` faults fire inside the store. Invariants:
/// the pool degrades to plain eviction instead of wedging, every stream
/// retires, and — because a failed hydrate truncates to the resident
/// prefix and re-prefills — every stream's tokens stay bit-identical to
/// the fault-free oracle (corrupt KV would drift).
fn scenario_spill_chaos(model: &ServeModel, quick: bool, seed: u64) -> Json {
    let done = arm_watchdog("spill_chaos", Duration::from_secs(180));
    let n = if quick { 6 } else { 10 };
    let plan = Arc::new(
        FaultPlan::parse(&format!("spill_write:0.5,spill_read:0.5,seed={seed}"))
            .expect("fault spec"),
    );
    let dir = std::env::temp_dir().join("had-stress-spill");
    let store =
        Arc::new(SpillStore::create(&dir, Some(Arc::clone(&plan))).expect("spill store"));
    let oracle_backend = HadBackend::new(model.clone(), &kv_cfg());
    let budget = 2 * oracle_backend.fresh_kv().bytes_at(32);
    let kv = KvCacheConfig { byte_budget: budget, ..kv_cfg() };
    let router =
        Router::new(vec![Bucket { config: "stress".into(), n_ctx: N_CTX, batch: 8 }]);
    let server = Server::builder(
        HadBackend::new(model.clone(), &kv),
        router,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_streams: 4,
            ..Default::default()
        },
    )
    .kv(kv)
    .chaos(Arc::clone(&plan))
    .spill(Arc::clone(&store))
    .start()
    .expect("server start");

    // collect every stream's tokens (not just its Done event) so the
    // oracle comparison below can prove no stream saw corrupt KV
    let collect = |rxs: Vec<(u64, std::sync::mpsc::Receiver<StreamEvent>)>| {
        let handles: Vec<_> = rxs
            .into_iter()
            .map(|(sid, rx)| {
                std::thread::spawn(move || {
                    let mut tokens = Vec::new();
                    let mut saw_done = 0u64;
                    for event in rx.iter() {
                        match event {
                            StreamEvent::Token { token, .. } => tokens.push(token),
                            StreamEvent::Done { .. } => {
                                saw_done = 1;
                                break;
                            }
                        }
                    }
                    (sid, tokens, saw_done)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("reader thread")).collect::<Vec<_>>()
    };
    let oracle = |context: &[i32], req: &GenerateRequest| {
        let mut okv = oracle_backend.fresh_kv();
        generate(
            &oracle_backend,
            &mut okv,
            context,
            req,
            &GenLimits {
                max_total_tokens: N_CTX,
                kv_budget_bytes: budget,
                ..GenLimits::unbounded()
            },
            |_, _| {},
        )
        .tokens
    };

    let mut rng = Rng::new(seed ^ 0x5717);
    let prompts: Vec<Vec<i32>> = (0..n).map(|_| prompt(&mut rng, 16)).collect();
    let mut admitted = 0u64;
    let mut done_events = 0u64;
    let sids: Vec<u64> = (0..n as u64).collect();
    // turn 1: concurrent cold streams racing the budget
    let mut rxs = Vec::new();
    for &sid in &sids {
        let req = GenerateRequest::greedy(prompts[sid as usize].clone(), 10);
        if let Ok(rx) = server.submit_generate(sid, req) {
            admitted += 1;
            rxs.push((sid, rx));
        }
    }
    let mut turn1: Vec<Vec<i32>> = vec![Vec::new(); n];
    for (sid, tokens, saw) in collect(rxs) {
        assert_eq!(
            tokens,
            oracle(&[], &GenerateRequest::greedy(prompts[sid as usize].clone(), 10)),
            "spill_chaos: stream {sid} turn 1 drifted from the fault-free oracle"
        );
        turn1[sid as usize] = tokens;
        done_events += saw;
    }
    // turn 2: continues — checkouts must hydrate (or truncate and
    // re-prefill when a seeded read fault corrupts the record), never
    // serve stale or corrupt pages. Sessions whose history was dropped
    // by a fall-back eviction reject the empty continue; skip those.
    let mut rxs = Vec::new();
    for &sid in &sids {
        if let Ok(rx) = server.submit_generate(sid, GenerateRequest::greedy(Vec::new(), 6)) {
            admitted += 1;
            rxs.push((sid, rx));
        }
    }
    for (sid, tokens, saw) in collect(rxs) {
        let mut context = prompts[sid as usize].clone();
        context.extend_from_slice(&turn1[sid as usize]);
        assert_eq!(
            tokens,
            oracle(&context, &GenerateRequest::greedy(Vec::new(), 6)),
            "spill_chaos: stream {sid} turn 2 drifted after hydrate/re-prefill"
        );
        done_events += saw;
    }
    wait_retired(&server, admitted);
    let spill = store.stats();
    assert!(
        spill.writes + spill.write_failures > 0,
        "spill_chaos: budget pressure never reached the spill tier"
    );
    let leaked = leaked_bytes(&server, &sids);
    assert_eq!(store.live_records(), 0, "spill_chaos: spill records leaked past teardown");
    let out = Outcome { admitted, done_events, leaked };
    let mut rec = out.record("spill_chaos", &server);
    if let Json::Obj(m) = &mut rec {
        m.insert("spill_writes".into(), Json::num(spill.writes as f64));
        m.insert("spill_write_failures".into(), Json::num(spill.write_failures as f64));
        m.insert("spill_read_failures".into(), Json::num(spill.read_failures as f64));
        m.insert("spill_faults".into(), Json::num(plan.injected() as f64));
    }
    done.store(true, Ordering::Relaxed);
    rec
}

fn main() {
    let quick = quick_env();
    let model = ServeModel::random(&demo_config("stress", N_CTX, 32), 0x57E5).expect("model");
    let mut records: Vec<Json> = Vec::new();

    let seeds: &[u64] = if quick { &[7] } else { &[7, 11, 13] };
    let scenarios: Vec<(&str, Json)> = {
        let mut v = Vec::new();
        v.push(("burst", scenario_burst(&model, quick)));
        v.push(("longtail", scenario_longtail(&model, quick)));
        v.push(("slow_reader", scenario_slow_reader(&model, quick)));
        v.push(("disconnect_storm", scenario_disconnect_storm(&model, quick)));
        for &s in seeds {
            v.push(("fault_sweep", scenario_fault_sweep(&model, quick, s)));
        }
        for &s in seeds {
            v.push(("spill_chaos", scenario_spill_chaos(&model, quick, s)));
        }
        v
    };
    for (name, rec) in scenarios {
        println!(
            "stress/{name}: admitted {} retired {} leaked {} B | ttft p99 {:.2} ms | faults {}",
            rec.get("admitted").and_then(Json::as_f64).unwrap_or(0.0),
            rec.get("retired").and_then(Json::as_f64).unwrap_or(0.0),
            rec.get("leaked_bytes").and_then(Json::as_f64).unwrap_or(0.0),
            rec.get("ttft_p99_us").and_then(Json::as_f64).unwrap_or(0.0) / 1e3,
            rec.get("faults_injected").and_then(Json::as_f64).unwrap_or(0.0),
        );
        records.push(rec);
    }

    write_jsonl("results/stress.jsonl", &records).expect("write results/stress.jsonl");
    println!("\nall stress scenarios passed; {} records -> results/stress.jsonl", records.len());
}
