//! Bench: the paper's core claim at the kernel level — the tiled
//! blocked XNOR-popcount engine with fused streaming top-N
//! (`binary::kernel`) vs the retained scalar oracle vs dense f32
//! attention, across context lengths (the Figure-1/Table-3 shape,
//! software edition), plus serial-vs-threaded scaling on the worker
//! pool.
//!
//! Appends machine-readable records to results/attention.jsonl for
//! scripts/summarize_results.py:
//!   {"kind":"kernel","n_k","n_q","n_top","variant","mean_us",
//!    "keys_per_s","speedup_vs_standard"}   per variant per context
//!   {"kind":"scaling","n_k","workers","mean_us","speedup_vs_serial"}
//!
//! Custom harness (criterion is unavailable offline — util::bench).
//! HAD_BENCH_QUICK=1 shrinks budgets for the CI smoke step.

use had::binary::attention::{had_attention_scalar_with, had_attention_with, Scratch};
use had::binary::{had_attention_backend, had_attention_pooled, standard_attention_ref};
use had::binary::{simd, HadAttnConfig, KernelBackend, PackedKv, PackedMat};
use had::tensor::Mat;
use had::util::bench::{Bencher, Stats, write_jsonl};
use had::util::json::Json;
use had::util::rng::Rng;
use had::util::threadpool::ThreadPool;

fn kernel_record(n_k: usize, n_q: usize, n_top: usize, variant: &str, s: &Stats, std: &Stats) -> Json {
    let mean_us = s.mean_ns() / 1e3;
    Json::obj(vec![
        ("kind", Json::str("kernel")),
        ("n_k", Json::num(n_k as f64)),
        ("n_q", Json::num(n_q as f64)),
        ("n_top", Json::num(n_top as f64)),
        ("variant", Json::str(variant)),
        ("backend", Json::str(KernelBackend::active().name())),
        ("cpu_features", Json::str(simd::cpu_features())),
        ("mean_us", Json::num(mean_us)),
        // best-observed time: the noise-robust statistic the summarizer's
        // --check regression gate compares (means wobble under the CI
        // smoke step's tiny quick-mode budgets; minima do not)
        ("min_us", Json::num(s.min.as_nanos() as f64 / 1e3)),
        ("keys_per_s", Json::num((n_q * n_k) as f64 / (s.mean_ns() / 1e9))),
        ("speedup_vs_standard", Json::num(std.mean_ns() / s.mean_ns())),
    ])
}

fn main() {
    let b = Bencher::from_env();
    let mut rng = Rng::new(9);
    let d = 64;
    let d_v = 64;
    let n_q = 32; // a decode-style query block (8 tiles of 4)
    let mut records: Vec<Json> = Vec::new();

    println!("== binary vs f32 attention scores (n_q={n_q}, d={d}) ==");
    for n_k in [256usize, 1024, 4096, 16384] {
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let qp = PackedMat::pack(n_q, d, &q.data);
        let kp = PackedMat::pack(n_k, d, &k.data);
        let mut out = vec![0i32; n_q * n_k];
        let s_bin = b.run(&format!("scores/xnor-popcount n_k={n_k}"), || {
            had::binary::hamming::score_matrix(&qp, &kp, &mut out);
            out[0]
        });
        let s_f32 = b.run(&format!("scores/f32-dense     n_k={n_k}"), || q.matmul_nt(&k));
        s_bin.print_throughput((n_q * n_k) as f64, "key");
        s_f32.print();
        println!("  -> binary speedup {:.1}x", s_f32.mean_ns() / s_bin.mean_ns());
    }

    println!("\n== fused HAD attention: scalar oracle vs blocked kernel vs threaded ==");
    let worker_counts = [2usize, 4];
    let pools: Vec<ThreadPool> = worker_counts.iter().map(|&w| ThreadPool::new(w)).collect();
    let mut gate: Option<(Stats, Stats)> = None; // (scalar, best threaded) at >=4k
    for n_k in [256usize, 1024, 4096, 16384] {
        let n_top = (30 * n_k / 256).max(1);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let kv = PackedKv::new(&k, &v);
        let cfg = HadAttnConfig { n_top, temp: 1.0 };
        let mut scratch = Scratch::default();

        // bit-identity sanity before timing anything
        let want = had_attention_scalar_with(&q, &kv, &cfg, &mut scratch);
        assert_eq!(want, had_attention_with(&q, &kv, &cfg, &mut scratch), "blocked != scalar");
        for pool in &pools {
            assert_eq!(want, had_attention_pooled(&q, &kv, &cfg, pool), "threaded != scalar");
        }

        let s_scalar = b.run(&format!("attn/scalar oracle n_k={n_k} N={n_top}"), || {
            had_attention_scalar_with(&q, &kv, &cfg, &mut scratch)
        });
        let s_blocked = b.run(&format!("attn/blocked fused n_k={n_k} N={n_top}"), || {
            had_attention_with(&q, &kv, &cfg, &mut scratch)
        });
        let s_std = b.run(&format!("attn/standard f32  n_k={n_k}"), || {
            standard_attention_ref(&q, &k, &v)
        });
        s_scalar.print();
        s_blocked.print();
        s_std.print();
        println!(
            "  -> blocked vs scalar {:.2}x, blocked vs f32 standard {:.1}x",
            s_scalar.mean_ns() / s_blocked.mean_ns(),
            s_std.mean_ns() / s_blocked.mean_ns(),
        );
        records.push(kernel_record(n_k, n_q, n_top, "standard", &s_std, &s_std));
        records.push(kernel_record(n_k, n_q, n_top, "scalar", &s_scalar, &s_std));
        records.push(kernel_record(n_k, n_q, n_top, "blocked", &s_blocked, &s_std));

        let mut best_threaded: Option<Stats> = None;
        for (w, pool) in worker_counts.iter().zip(&pools) {
            let s_thr = b.run(&format!("attn/threaded w={w}    n_k={n_k}"), || {
                had_attention_pooled(&q, &kv, &cfg, pool)
            });
            s_thr.print();
            println!("  -> {w} workers: {:.2}x vs serial blocked", s_blocked.mean_ns() / s_thr.mean_ns());
            records.push(Json::obj(vec![
                ("kind", Json::str("scaling")),
                ("n_k", Json::num(n_k as f64)),
                ("workers", Json::num(*w as f64)),
                ("mean_us", Json::num(s_thr.mean_ns() / 1e3)),
                ("speedup_vs_serial", Json::num(s_blocked.mean_ns() / s_thr.mean_ns())),
            ]));
            if best_threaded.as_ref().map_or(true, |c| s_thr.mean < c.mean) {
                best_threaded = Some(s_thr);
            }
        }
        let best = best_threaded.expect("at least one worker count");
        records.push(kernel_record(n_k, n_q, n_top, "threaded", &best, &s_std));
        if n_k >= 4096 {
            gate = Some((s_scalar.clone(), best));
        }
    }
    // the acceptance gate: on long contexts the blocked+threaded kernel
    // must beat the scalar path it replaced. Skipped in quick mode: the
    // CI smoke step's tiny budgets on noisy shared runners make a hard
    // perf assert flaky; real bench runs keep it strict.
    let quick = had::util::bench::quick_env();
    let (scalar, threaded) = gate.expect("a >=4k context bucket ran");
    if quick {
        println!("\n(HAD_BENCH_QUICK set: skipping the threaded-vs-scalar perf gate)");
    } else {
        assert!(
            threaded.mean < scalar.mean,
            "blocked+threaded kernel must beat the scalar path on >=4k contexts \
             (threaded {:.0} µs vs scalar {:.0} µs)",
            threaded.mean_ns() / 1e3,
            scalar.mean_ns() / 1e3,
        );
    }

    // -- popcount backend sweep: every backend the host can run, across
    //    context lengths AND head dims (d=64 → W=1 tiles where vector
    //    setup overhead bites hardest, d=256 → the widest monomorphized
    //    W=4 tiles, d=320 → the dyn wide-head path), bit-identity
    //    asserted against the scalar oracle before timing. Each JSONL
    //    record carries the backend name and the detected CPU features.
    let features = simd::cpu_features();
    let backends = KernelBackend::available();
    println!(
        "\n== popcount backend sweep ({features}; active: {}) ==",
        KernelBackend::active().name()
    );
    for (bd, n_k) in [(64usize, 1024usize), (64, 4096), (64, 16384), (256, 4096), (320, 4096)] {
        let n_top = (30 * n_k / 256).max(1);
        let q = Mat::random(n_q, bd, &mut rng, 1.0);
        let k = Mat::random(n_k, bd, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let kv = PackedKv::new(&k, &v);
        let cfg = HadAttnConfig { n_top, temp: 1.0 };
        let mut scratch = Scratch::default();
        let want = had_attention_scalar_with(&q, &kv, &cfg, &mut scratch);
        let mut scalar_mean_ns = 0.0f64;
        for &be in &backends {
            assert_eq!(
                want,
                had_attention_backend(&q, &kv, &cfg, be),
                "backend {} != scalar oracle at d={bd} n_k={n_k}",
                be.name()
            );
            let s = b.run(&format!("attn/be={:<6} d={bd:<3} n_k={n_k}", be.name()), || {
                had_attention_backend(&q, &kv, &cfg, be)
            });
            if be == KernelBackend::Scalar {
                scalar_mean_ns = s.mean_ns();
            }
            let speedup =
                if scalar_mean_ns > 0.0 { scalar_mean_ns / s.mean_ns() } else { f64::NAN };
            s.print();
            println!("  -> {} vs scalar backend: {:.2}x", be.name(), speedup);
            records.push(Json::obj(vec![
                ("kind", Json::str("backend")),
                ("n_k", Json::num(n_k as f64)),
                ("n_q", Json::num(n_q as f64)),
                ("d", Json::num(bd as f64)),
                ("n_top", Json::num(n_top as f64)),
                ("backend", Json::str(be.name())),
                ("active", Json::Bool(be == KernelBackend::active())),
                ("cpu_features", Json::str(features.clone())),
                ("mean_us", Json::num(s.mean_ns() / 1e3)),
                ("min_us", Json::num(s.min.as_nanos() as f64 / 1e3)),
                ("keys_per_s", Json::num((n_q * n_k) as f64 / (s.mean_ns() / 1e9))),
                ("speedup_vs_scalar", Json::num(speedup)),
            ]));
        }
    }

    println!("\n== top-N selection strategies (n=4096 integer scores) ==");
    let d_dom = 64usize;
    let scores: Vec<i32> = (0..4096)
        .map(|_| rng.below((2 * d_dom + 1) as u64) as i32 - d_dom as i32)
        .collect();
    for n_top in [30usize, 120, 480] {
        let s_heap = b.run(&format!("topn/insertion N={n_top}"), || {
            had::binary::topn::select_topn_heap(&scores, n_top)
        });
        let s_count = b.run(&format!("topn/counting  N={n_top}"), || {
            had::binary::topn::select_topn_counting(&scores, n_top, d_dom)
        });
        let s_stream = b.run(&format!("topn/streaming N={n_top}"), || {
            let mut st = had::binary::StreamTopN::new();
            st.reset(n_top, d_dom);
            for (i, &s) in scores.iter().enumerate() {
                st.push(s, i);
            }
            st.finish().len()
        });
        s_heap.print();
        s_count.print();
        s_stream.print();
    }

    println!("\n== bit packing throughput ==");
    let xs = rng.normal_vec(4096 * 64, 1.0);
    let s = b.run("pack 4096x64 f32 -> bits", || PackedMat::pack(4096, 64, &xs));
    s.print_throughput(4096.0 * 64.0, "elem");

    // persist for scripts/summarize_results.py
    if let Err(e) = write_jsonl("results/attention.jsonl", &records) {
        eprintln!("could not write results/attention.jsonl: {e}");
    }
    println!("\nattention_kernels bench OK");
}

