//! Bench: the paper's core claim at the kernel level — binarized
//! XNOR+popcount attention vs dense f32 attention on CPU, across context
//! lengths (the Figure-1/Table-3 shape, software edition).
//!
//! Custom harness (criterion is unavailable offline — util::bench).

use had::binary::attention::had_attention_with;
use had::binary::{HadAttnConfig, PackedKv};
use had::binary::attention::Scratch;
use had::binary::{standard_attention_ref, PackedMat};
use had::tensor::Mat;
use had::util::bench::Bencher;
use had::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(9);
    let d = 64;
    let d_v = 64;
    let n_q = 16; // a decode-style query block

    println!("== binary vs f32 attention scores (n_q={n_q}, d={d}) ==");
    for n_k in [256usize, 1024, 4096, 16384] {
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let qp = PackedMat::pack(n_q, d, &q.data);
        let kp = PackedMat::pack(n_k, d, &k.data);
        let mut out = vec![0i32; n_q * n_k];
        let s_bin = b.run(&format!("scores/xnor-popcount n_k={n_k}"), || {
            had::binary::hamming::score_matrix(&qp, &kp, &mut out);
            out[0]
        });
        let s_f32 = b.run(&format!("scores/f32-dense     n_k={n_k}"), || q.matmul_nt(&k));
        s_bin.print();
        s_f32.print();
        println!("  -> binary speedup {:.1}x", s_f32.mean_ns() / s_bin.mean_ns());
    }

    println!("\n== fused HAD attention vs dense standard attention ==");
    for n_k in [256usize, 1024, 4096] {
        let n_top = (30 * n_k / 256).max(1);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let kv = PackedKv::new(&k, &v);
        let cfg = HadAttnConfig { n_top, temp: 1.0 };
        let mut scratch = Scratch::default();
        let s_had = b.run(&format!("attn/HAD fused    n_k={n_k} N={n_top}"), || {
            had_attention_with(&q, &kv, &cfg, &mut scratch)
        });
        let s_std = b.run(&format!("attn/standard f32 n_k={n_k}"), || {
            standard_attention_ref(&q, &k, &v)
        });
        s_had.print();
        s_std.print();
        println!("  -> HAD end-to-end speedup {:.1}x", s_std.mean_ns() / s_had.mean_ns());
    }

    println!("\n== top-N selection strategies (n=4096 integer scores) ==");
    let d_dom = 64usize;
    let scores: Vec<i32> = (0..4096)
        .map(|_| rng.below((2 * d_dom + 1) as u64) as i32 - d_dom as i32)
        .collect();
    for n_top in [30usize, 120, 480] {
        let s_heap = b.run(&format!("topn/insertion N={n_top}"), || {
            had::binary::topn::select_topn_heap(&scores, n_top)
        });
        let s_count = b.run(&format!("topn/counting  N={n_top}"), || {
            had::binary::topn::select_topn_counting(&scores, n_top, d_dom)
        });
        s_heap.print();
        s_count.print();
    }

    println!("\n== bit packing throughput ==");
    let xs = rng.normal_vec(4096 * 64, 1.0);
    let s = b.run("pack 4096x64 f32 -> bits", || PackedMat::pack(4096, 64, &xs));
    s.print_throughput(4096.0 * 64.0, "elem");
}
