//! Socket-level stress harness for the HTTP front-end: real TCP clients
//! driving a live `NetServer` over loopback — burst arrivals, slow
//! readers under injected write stalls, mid-stream disconnect storms,
//! and a seeded chaos sweep that turns on every fault site (engine and
//! net) at once. Every scenario asserts the same robustness invariants
//! as the in-process stress harness, now measured from the far side of
//! a socket:
//!
//!   * every admitted stream retires with an explicit `StopReason`
//!     (`Snapshot::gen_streams == admitted`), including streams whose
//!     client vanished mid-chunk;
//!   * the page pool returns to baseline once sessions end — no leaks
//!     however the connections died;
//!   * TTFT is measured as the client observes it (request written →
//!     first chunk readable), so the per-token flush path is gated, not
//!     trusted.
//!
//! Appends machine-readable records to results/net.jsonl (schema v2)
//! for scripts/validate_net.py.

use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::generate::{generate, GenLimits, GenerateRequest, StreamEvent};
use had::kvcache::KvCacheConfig;
use had::net::{HttpClient, NetConfig, NetServer};
use had::serve::{demo_config, HadBackend, ServeModel};
use had::util::bench::{percentile_us, quick_env, write_jsonl};
use had::util::fault::FaultPlan;
use had::util::json::Json;
use had::util::rng::Rng;

const N_CTX: usize = 128;

fn kv_cfg() -> KvCacheConfig {
    KvCacheConfig { page_tokens: 16, ..Default::default() }
}

fn coordinator(model: &ServeModel, policy: BatchPolicy, chaos: Option<FaultPlan>) -> Arc<Server> {
    let kv = kv_cfg();
    let router = Router::new(vec![Bucket { config: "net".into(), n_ctx: N_CTX, batch: 8 }]);
    let backend = HadBackend::new(model.clone(), &kv);
    let mut builder = Server::builder(backend, router, policy).kv(kv);
    if let Some(plan) = chaos {
        builder = builder.chaos(plan);
    }
    Arc::new(builder.start().expect("server start"))
}

fn bind(server: Arc<Server>, faults: Option<Arc<FaultPlan>>) -> NetServer {
    let cfg = NetConfig {
        workers: 16, // a connection holds its worker; bursts need headroom
        read_timeout: Duration::from_secs(30),
        write_timeout: Duration::from_secs(10),
        faults,
        ..Default::default()
    };
    NetServer::bind(server, "127.0.0.1:0", cfg).expect("bind loopback")
}

/// Arm a deadlock watchdog (same contract as benches/stress.rs: process
/// exit 3 on overrun so CI reports a hang, not a timeout).
fn arm_watchdog(name: &'static str, timeout: Duration) -> Arc<AtomicBool> {
    let done = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&done);
    std::thread::spawn(move || {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            if flag.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        eprintln!("[net_stress] WATCHDOG: scenario '{name}' still live after {timeout:?} — deadlock suspected");
        std::process::exit(3);
    });
    done
}

fn wait_retired(server: &Server, admitted: u64) {
    while server.metrics.snapshot().gen_streams < admitted {
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn leaked_bytes(server: &Server, sids: &[u64]) -> usize {
    let store = server.sessions();
    let mut store = store.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    for &sid in sids {
        store.end_session(sid);
    }
    store.pool().bytes()
}

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.below(256) as i32).collect()
}

/// What one HTTP generation client observed.
struct ClientRun {
    /// request fully written -> first chunk readable
    ttft_us: u128,
    /// token JSONL lines, in order (trailing newline stripped)
    token_lines: Vec<String>,
    saw_done: bool,
}

/// Run one `POST /v1/generate` over loopback, reading chunk by chunk.
/// `read_delay` simulates a slow consumer; `quit_after` closes the
/// connection after that many chunks (mid-stream disconnect).
fn generate_over_http(
    addr: std::net::SocketAddr,
    sid: u64,
    prompt: &[i32],
    n_new: usize,
    read_delay: Duration,
    quit_after: Option<usize>,
) -> io::Result<ClientRun> {
    let mut c = HttpClient::connect(addr)?;
    c.set_timeouts(Some(Duration::from_secs(60)), Some(Duration::from_secs(10)))?;
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let body = format!(
        r#"{{"session":{sid},"prompt":[{}],"max_new_tokens":{n_new}}}"#,
        toks.join(",")
    );
    let t0 = Instant::now();
    c.send("POST", "/v1/generate", Some(body.as_bytes()))?;
    let head = c.read_head()?;
    if head.status != 200 {
        return Err(io::Error::new(io::ErrorKind::Other, format!("status {}", head.status)));
    }
    let mut run = ClientRun { ttft_us: 0, token_lines: Vec::new(), saw_done: false };
    let mut n_chunks = 0usize;
    while let Some(chunk) = c.next_chunk()? {
        if n_chunks == 0 {
            run.ttft_us = t0.elapsed().as_micros();
        }
        n_chunks += 1;
        let line = String::from_utf8_lossy(&chunk).trim_end().to_string();
        if line.contains(r#""event":"done""#) {
            run.saw_done = true;
        } else {
            run.token_lines.push(line);
        }
        if quit_after.is_some_and(|q| n_chunks >= q) {
            return Ok(run); // drop the connection mid-stream
        }
        if !read_delay.is_zero() {
            std::thread::sleep(read_delay);
        }
    }
    Ok(run)
}

struct Outcome {
    admitted: u64,
    done_events: u64,
    leaked: usize,
    ttfts: Vec<u128>,
    /// extra faults fired by the net-layer plan (engine-plan firings are
    /// already in `Snapshot::faults_injected`)
    net_faults: u64,
    identity_ok: Option<bool>,
}

impl Outcome {
    fn record(&self, name: &str, server: &Server) -> Json {
        let snap = server.metrics.snapshot();
        assert_eq!(
            snap.gen_streams, self.admitted,
            "{name}: every admitted stream must retire with an explicit StopReason"
        );
        assert_eq!(self.leaked, 0, "{name}: page pool must return to baseline");
        let mut ttfts = self.ttfts.clone();
        ttfts.sort_unstable();
        let mut fields = vec![
            ("kind", Json::str("net")),
            ("name", Json::str(name)),
            ("admitted", Json::num(self.admitted as f64)),
            ("retired", Json::num(snap.gen_streams as f64)),
            ("done_events", Json::num(self.done_events as f64)),
            ("leaked_bytes", Json::num(self.leaked as f64)),
            ("watchdog_ok", Json::Bool(true)),
            ("ttft_p99_us", Json::num(percentile_us(&ttfts, 0.99) as f64)),
            ("faults_injected", Json::num((snap.faults_injected + self.net_faults) as f64)),
            ("net_connections", Json::num(snap.net_connections as f64)),
            ("net_requests", Json::num(snap.net_requests as f64)),
            ("net_parse_errors", Json::num(snap.net_parse_errors as f64)),
            ("net_slow_writes", Json::num(snap.net_slow_writes as f64)),
        ];
        if let Some(ok) = self.identity_ok {
            fields.push(("identity_ok", Json::Bool(ok)));
        }
        Json::obj(fields)
    }
}

/// Seeded identity: the streamed JSONL token events over the socket must
/// be byte-identical to the direct engine loop's, prompt for prompt.
fn scenario_identity(model: &ServeModel, quick: bool) -> Json {
    let done = arm_watchdog("net_identity", Duration::from_secs(120));
    let n = if quick { 2 } else { 4 };
    let server = coordinator(
        model,
        BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
        None,
    );
    let net = bind(Arc::clone(&server), None);
    let addr = net.local_addr();
    let oracle = HadBackend::new(model.clone(), &kv_cfg());
    let mut rng = Rng::new(0x1DE47);
    let mut admitted = 0u64;
    let mut done_events = 0u64;
    let mut ttfts = Vec::new();
    let mut sids = Vec::new();
    let mut identity_ok = true;
    for sid in 0..n as u64 {
        let p = prompt(&mut rng, 8 + rng.below(24) as usize);
        let n_new = 6usize;
        let mut want = Vec::new();
        let req = GenerateRequest::greedy(p.clone(), n_new);
        generate(&oracle, &mut oracle.fresh_kv(), &[], &req, &GenLimits::unbounded(), |index, token| {
            want.push(format!(r#"{{"event":"token","index":{index},"token":{token}}}"#));
        });
        let run = generate_over_http(addr, sid, &p, n_new, Duration::ZERO, None)
            .expect("identity stream");
        admitted += 1;
        sids.push(sid);
        done_events += u64::from(run.saw_done);
        ttfts.push(run.ttft_us);
        if run.token_lines != want {
            eprintln!("[net_stress] identity mismatch for sid {sid}:\n  want {want:?}\n  got  {:?}", run.token_lines);
            identity_ok = false;
        }
    }
    assert!(identity_ok, "net_identity: socket stream diverged from the direct engine");
    wait_retired(&server, admitted);
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome { admitted, done_events, leaked, ttfts, net_faults: 0, identity_ok: Some(identity_ok) };
    let rec = out.record("net_identity", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Burst arrivals: waves of concurrent HTTP clients, each its own
/// connection. Gates client-observed p99 TTFT downstream.
fn scenario_burst(model: &ServeModel, quick: bool) -> Json {
    let done = arm_watchdog("net_burst", Duration::from_secs(180));
    let (waves, per_wave, n_new) = if quick { (2, 4, 6) } else { (4, 8, 10) };
    let server = coordinator(
        model,
        BatchPolicy { max_wait: Duration::from_millis(1), max_streams: 8, ..Default::default() },
        None,
    );
    let net = bind(Arc::clone(&server), None);
    let addr = net.local_addr();
    let mut rng = Rng::new(0xB0057);
    let mut admitted = 0u64;
    let mut done_events = 0u64;
    let mut ttfts = Vec::new();
    let mut sids = Vec::new();
    for wave in 0..waves {
        let mut handles = Vec::new();
        for i in 0..per_wave {
            let sid = (wave * per_wave + i) as u64;
            let p = prompt(&mut rng, 8 + rng.below(24) as usize);
            handles.push((sid, std::thread::spawn(move || {
                generate_over_http(addr, sid, &p, n_new, Duration::ZERO, None)
            })));
        }
        for (sid, h) in handles {
            match h.join().expect("client thread") {
                Ok(run) => {
                    admitted += 1;
                    sids.push(sid);
                    done_events += u64::from(run.saw_done);
                    ttfts.push(run.ttft_us);
                }
                Err(e) => panic!("net_burst: client {sid} failed: {e}"),
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    wait_retired(&server, admitted);
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome { admitted, done_events, leaked, ttfts, net_faults: 0, identity_ok: None };
    let rec = out.record("net_burst", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Slow readers with injected write stalls: every chunk write is delayed
/// by the seeded `net_write` fault while clients also consume slowly.
/// Streams must still retire and the slow-write counter must move.
fn scenario_slow_reader(model: &ServeModel, quick: bool) -> Json {
    let done = arm_watchdog("net_slow_reader", Duration::from_secs(180));
    let n = if quick { 3 } else { 6 };
    let server = coordinator(
        model,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_streams: 8,
            stream_event_cap: 4,
            ..Default::default()
        },
        None,
    );
    let net_plan = Arc::new(FaultPlan::parse("net_write:1.0:2,seed=11").expect("net plan"));
    let net = bind(Arc::clone(&server), Some(Arc::clone(&net_plan)));
    let addr = net.local_addr();
    let mut rng = Rng::new(0x510);
    let mut admitted = 0u64;
    let mut done_events = 0u64;
    let mut ttfts = Vec::new();
    let mut sids = Vec::new();
    let mut handles = Vec::new();
    for sid in 0..n as u64 {
        let p = prompt(&mut rng, 12);
        handles.push((sid, std::thread::spawn(move || {
            generate_over_http(addr, sid, &p, 12, Duration::from_millis(10), None)
        })));
    }
    for (sid, h) in handles {
        if let Ok(run) = h.join().expect("client thread") {
            admitted += 1;
            sids.push(sid);
            done_events += u64::from(run.saw_done);
            ttfts.push(run.ttft_us);
        }
    }
    wait_retired(&server, admitted);
    assert!(
        server.metrics.snapshot().net_slow_writes > 0,
        "net_slow_reader: the injected write stall never fired"
    );
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome {
        admitted, done_events, leaked, ttfts,
        net_faults: net_plan.injected(),
        identity_ok: None,
    };
    let rec = out.record("net_slow_reader", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Disconnect storm: half the clients close the socket after the first
/// chunk; the scheduler must observe the dropped receivers and retire
/// every stream anyway.
fn scenario_disconnect_storm(model: &ServeModel, quick: bool) -> Json {
    let done = arm_watchdog("net_disconnect_storm", Duration::from_secs(180));
    let n = if quick { 6 } else { 12 };
    let server = coordinator(
        model,
        BatchPolicy { max_wait: Duration::from_millis(1), max_streams: 6, ..Default::default() },
        None,
    );
    let net = bind(Arc::clone(&server), None);
    let addr = net.local_addr();
    let mut rng = Rng::new(0xD15C);
    let mut admitted = 0u64;
    let mut done_events = 0u64;
    let mut ttfts = Vec::new();
    let mut sids = Vec::new();
    let mut handles = Vec::new();
    for sid in 0..n as u64 {
        let p = prompt(&mut rng, 10);
        let quit = if sid % 2 == 0 { Some(1) } else { None };
        handles.push((sid, std::thread::spawn(move || {
            generate_over_http(addr, sid, &p, 12, Duration::ZERO, quit)
        })));
    }
    for (sid, h) in handles {
        if let Ok(run) = h.join().expect("client thread") {
            admitted += 1;
            sids.push(sid);
            done_events += u64::from(run.saw_done);
            ttfts.push(run.ttft_us);
        }
    }
    wait_retired(&server, admitted);
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome { admitted, done_events, leaked, ttfts, net_faults: 0, identity_ok: None };
    let rec = out.record("net_disconnect_storm", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

/// Seeded chaos across the whole stack: engine sites on the scheduler's
/// plan, net sites on the listener's plan, same grammar, both seeded.
/// Clients retry dropped connections (`net_accept` denies them).
fn scenario_fault_sweep(model: &ServeModel, quick: bool, seed: u64) -> Json {
    let done = arm_watchdog("net_fault_sweep", Duration::from_secs(240));
    let n = if quick { 4 } else { 8 };
    let engine_plan = FaultPlan::parse(&format!(
        "decode_step:0.3:2,worker_panic:0.15,client_disconnect:0.1,pool_pressure:0.2,queue_stall:0.1:2,seed={seed}"
    ))
    .expect("engine plan");
    let net_plan = Arc::new(
        FaultPlan::parse(&format!("net_accept:0.3,net_write:0.2:2,seed={seed}"))
            .expect("net plan"),
    );
    let server = coordinator(
        model,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_streams: 4,
            stream_deadline_ms: 30_000,
            ..Default::default()
        },
        Some(engine_plan),
    );
    let net = bind(Arc::clone(&server), Some(Arc::clone(&net_plan)));
    let addr = net.local_addr();
    let mut rng = Rng::new(seed ^ 0xFA175);
    let mut admitted = 0u64;
    let mut done_events = 0u64;
    let mut ttfts = Vec::new();
    let mut sids = Vec::new();
    for sid in 0..n as u64 {
        let p = prompt(&mut rng, 16);
        // retry: net_accept drops connections before a byte is served
        for _attempt in 0..8 {
            match generate_over_http(addr, sid, &p, 8, Duration::ZERO, None) {
                Ok(run) => {
                    admitted += 1;
                    sids.push(sid);
                    done_events += u64::from(run.saw_done);
                    ttfts.push(run.ttft_us);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        }
    }
    wait_retired(&server, admitted);
    let total_faults =
        server.metrics.snapshot().faults_injected + net_plan.injected();
    assert!(total_faults > 0, "net_fault_sweep: no site ever fired");
    let leaked = leaked_bytes(&server, &sids);
    let out = Outcome {
        admitted, done_events, leaked, ttfts,
        net_faults: net_plan.injected(),
        identity_ok: None,
    };
    let rec = out.record("net_fault_sweep", &server);
    done.store(true, Ordering::Relaxed);
    rec
}

fn main() {
    let quick = quick_env();
    let model = ServeModel::random(&demo_config("net", N_CTX, 32), 0x57E5).expect("model");
    let mut records: Vec<Json> = Vec::new();

    let seeds: &[u64] = if quick { &[7] } else { &[7, 11] };
    let scenarios: Vec<(&str, Json)> = {
        let mut v = Vec::new();
        v.push(("net_identity", scenario_identity(&model, quick)));
        v.push(("net_burst", scenario_burst(&model, quick)));
        v.push(("net_slow_reader", scenario_slow_reader(&model, quick)));
        v.push(("net_disconnect_storm", scenario_disconnect_storm(&model, quick)));
        for &s in seeds {
            v.push(("net_fault_sweep", scenario_fault_sweep(&model, quick, s)));
        }
        v
    };
    for (name, rec) in scenarios {
        println!(
            "net/{name}: admitted {} retired {} leaked {} B | client ttft p99 {:.2} ms | faults {} | slow-writes {}",
            rec.get("admitted").and_then(Json::as_f64).unwrap_or(0.0),
            rec.get("retired").and_then(Json::as_f64).unwrap_or(0.0),
            rec.get("leaked_bytes").and_then(Json::as_f64).unwrap_or(0.0),
            rec.get("ttft_p99_us").and_then(Json::as_f64).unwrap_or(0.0) / 1e3,
            rec.get("faults_injected").and_then(Json::as_f64).unwrap_or(0.0),
            rec.get("net_slow_writes").and_then(Json::as_f64).unwrap_or(0.0),
        );
        records.push(rec);
    }

    write_jsonl("results/net.jsonl", &records).expect("write results/net.jsonl");
    println!("\nall net scenarios passed; {} records -> results/net.jsonl", records.len());
}
