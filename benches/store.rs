//! Bench: persistent store — checkpoint load (legacy cold heap load vs
//! zero-copy mmap), KV stripe spill/hydrate throughput, and restart
//! identity under budget pressure through a live `Server`.
//!
//! Acceptance gates (hard asserts in full mode, relaxed under
//! HAD_BENCH_QUICK=1 where tiny budgets on noisy CI runners would make
//! perf asserts flaky — identity asserts always run):
//!
//!   * mmap-loaded weights produce bit-identical logits to heap-loaded;
//!   * a spilled-and-hydrated KV is bit-identical to the original;
//!   * at >=4k context, hydrating from disk beats re-prefilling.
//!
//! Appends machine-readable records to results/store.jsonl for
//! scripts/validate_store.py (the CI store-smoke gate).

use std::sync::Arc;
use std::time::{Duration, Instant};

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::kvcache::KvCacheConfig;
use had::model::{load_checkpoint, save_checkpoint, Checkpoint, ParamSet};
use had::serve::{demo_config, HadBackend, ServeModel};
use had::store::{write_checkpoint, SpillStore};
use had::util::bench::{quick_env, write_jsonl};
use had::util::json::Json;
use had::util::rng::Rng;

fn temp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("had-store-bench-{}-{name}", std::process::id()))
}

fn us(d: Duration) -> f64 {
    d.as_nanos() as f64 / 1e3
}

/// Best-of-n wall time for `f` (loads and I/O are long enough that the
/// minimum is the stable statistic; no need for the full Bencher).
fn best_of<R>(n: usize, mut f: impl FnMut() -> R) -> (Duration, R) {
    let mut best: Option<Duration> = None;
    let mut out = None;
    for _ in 0..n {
        let t0 = Instant::now();
        let r = f();
        let dt = t0.elapsed();
        if best.map_or(true, |b| dt < b) {
            best = Some(dt);
        }
        out = Some(r);
    }
    (best.unwrap(), out.unwrap())
}

/// Part 1: checkpoint container — cold (legacy HADCKPT1 stream decode
/// into heap tensors) vs zero-copy mmap open of the HADSTOR1 container,
/// plus logits identity between the two loaded models.
fn bench_checkpoint(iters: usize) -> Json {
    let cfg = demo_config("store_bench", 128, 32);
    let mut rng = Rng::new(0x57031);
    let ckpt = Checkpoint {
        config: cfg.name.clone(),
        step: 1.0,
        sigma_q: vec![0.8, 1.1],
        sigma_k: vec![0.9, 1.2],
        params: ParamSet::init(&cfg, &mut rng),
    };
    let legacy = temp("ckpt-legacy.bin");
    let stor = temp("ckpt.stor");
    save_checkpoint(&legacy, &cfg, &ckpt).expect("legacy save");
    write_checkpoint(&stor, &cfg, &ckpt).expect("store write");

    let (cold, heap_model) = best_of(iters, || {
        let loaded = load_checkpoint(&legacy, &cfg).expect("legacy load");
        ServeModel::from_checkpoint(&cfg, &loaded).expect("heap model")
    });
    let (mmap, mapped_model) =
        best_of(iters, || ServeModel::from_store(&cfg, &stor).expect("mapped model"));

    // identity gate: bit-identical logits from both load paths
    let kv = KvCacheConfig { page_tokens: 16, ..Default::default() };
    let toks: Vec<i32> = (0..32).map(|i| (i * 7) % 256).collect();
    let lh = HadBackend::new(heap_model, &kv).forward_logits(&toks);
    let lm = HadBackend::new(mapped_model, &kv).forward_logits(&toks);
    let identity_ok = lh == lm;
    println!(
        "store/checkpoint: cold load {:.1} us | mmap load {:.1} us ({:.2}x) | logits identical: {identity_ok}",
        us(cold),
        us(mmap),
        us(cold) / us(mmap).max(1e-9),
    );
    assert!(identity_ok, "mmap-loaded logits must be bit-identical to heap-loaded");
    std::fs::remove_file(&legacy).ok();
    std::fs::remove_file(&stor).ok();
    Json::obj(vec![
        ("kind", Json::str("checkpoint")),
        ("cold_us", Json::num(us(cold))),
        ("mmap_us", Json::num(us(mmap))),
        ("identity_ok", Json::Bool(identity_ok)),
    ])
}

/// Part 2: spill/hydrate a long-context session's stripes and compare
/// against re-prefilling the same tokens through the backend — the cost
/// a spill-less pool pays after evicting the session.
fn bench_spill(n_ctx: usize, iters: usize, quick: bool) -> Json {
    let cfg = demo_config("store_spill", n_ctx, 32);
    let model = ServeModel::random(&cfg, 0x5B1).expect("model");
    let kv_cfg = KvCacheConfig { page_tokens: 64, ..Default::default() };
    let backend = HadBackend::new(model, &kv_cfg);
    let mut rng = Rng::new(0x5B2);
    let toks: Vec<i32> = (0..n_ctx).map(|_| rng.below(256) as i32).collect();

    let mut kv = backend.fresh_kv();
    backend.decode(&mut kv, &toks, &[toks.len()]);
    let reference = kv.clone();
    let resident_bytes = kv.bytes();

    let store = SpillStore::create(&temp("spill"), None).expect("spill store");
    let (mut spill_best, mut hydrate_best) = (Duration::MAX, Duration::MAX);
    let mut spilled_bytes = 0usize;
    for _ in 0..iters {
        let t0 = Instant::now();
        let mut freed = 0usize;
        while let Some((b, _)) = kv.spill_one(&store) {
            freed += b;
        }
        spill_best = spill_best.min(t0.elapsed());
        spilled_bytes = freed;
        let t0 = Instant::now();
        let (pages_in, failures) = kv.hydrate(&store);
        hydrate_best = hydrate_best.min(t0.elapsed());
        assert!(pages_in > 0 && failures == 0, "hydrate must restore every stripe");
    }
    // bit-identity: the hydrated pages ARE the original pages
    let geom = kv.geom();
    let mut identity_ok = kv.tokens() == reference.tokens();
    let mut row = vec![0.0f32; geom.d_head];
    let mut want = vec![0.0f32; geom.d_head];
    'outer: for l in 0..geom.n_layers {
        for h in 0..geom.n_heads {
            let (a, b) = (kv.chain(l, h), reference.chain(l, h));
            for i in 0..b.len() {
                a.value_into(i, &mut row);
                b.value_into(i, &mut want);
                if a.key(i) != b.key(i) || row != want {
                    identity_ok = false;
                    break 'outer;
                }
            }
        }
    }
    assert!(identity_ok, "hydrated KV must be bit-identical to the original");
    assert_eq!(store.live_records(), 0, "hydrate must release every spill record");

    // the alternative to hydrating: re-prefill the evicted context
    let (reprefill, _) = best_of(iters, || {
        let mut fresh = backend.fresh_kv();
        backend.decode(&mut fresh, &toks, &[toks.len()]);
    });
    let mb = spilled_bytes as f64 / (1024.0 * 1024.0);
    println!(
        "store/spill n_ctx={n_ctx}: {} KiB resident | spill {:.1} us ({:.1} MB/s) | hydrate {:.1} us ({:.1} MB/s) | re-prefill {:.1} us ({:.1}x slower than hydrate)",
        resident_bytes / 1024,
        us(spill_best),
        mb / spill_best.as_secs_f64().max(1e-12),
        us(hydrate_best),
        mb / hydrate_best.as_secs_f64().max(1e-12),
        us(reprefill),
        us(reprefill) / us(hydrate_best).max(1e-9),
    );
    if n_ctx >= 4096 && !quick {
        assert!(
            hydrate_best < reprefill,
            "at {n_ctx} context, hydrating ({hydrate_best:?}) must beat re-prefill ({reprefill:?})"
        );
    }
    Json::obj(vec![
        ("kind", Json::str("spill")),
        ("n_ctx", Json::num(n_ctx as f64)),
        ("spilled_bytes", Json::num(spilled_bytes as f64)),
        ("spill_us", Json::num(us(spill_best))),
        ("hydrate_us", Json::num(us(hydrate_best))),
        ("reprefill_us", Json::num(us(reprefill))),
        ("identity_ok", Json::Bool(identity_ok)),
        ("checksum_failures", Json::num(store.stats().read_failures as f64)),
    ])
}

/// Part 3: restart identity through a live server — a session whose
/// stripes were forced to disk by another session's admission must come
/// back bit-identical on its next turn.
fn bench_restart() -> Json {
    let cfg = demo_config("store_restart", 128, 32);
    let model = ServeModel::random(&cfg, 0x5B3).expect("model");
    let kv_probe = KvCacheConfig { page_tokens: 16, ..Default::default() };
    let oracle_backend = HadBackend::new(model.clone(), &kv_probe);
    // budget = exactly ONE 64-token session: session 2's checkin forces
    // session 1's stripes out to the disk tier
    let budget = oracle_backend.fresh_kv().bytes_at(64);
    let kv = KvCacheConfig { page_tokens: 16, byte_budget: budget, ..Default::default() };
    let store = Arc::new(SpillStore::create(&temp("restart"), None).expect("spill store"));
    let router =
        Router::new(vec![Bucket { config: "store_restart".into(), n_ctx: 128, batch: 4 }]);
    let server = Server::builder(
        HadBackend::new(model, &kv),
        router,
        BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
    )
    .kv(kv)
    .spill(Arc::clone(&store))
    .start()
    .expect("server start");

    let mut rng = Rng::new(0x5B4);
    let ctx: Vec<i32> = (0..64).map(|_| rng.below(256) as i32).collect();
    let other: Vec<i32> = (0..64).map(|_| rng.below(256) as i32).collect();
    server.infer_session(1, ctx.clone()).expect("turn 1");
    server.infer_session(2, other).expect("pressure turn");
    let spill_pages_out = server.cache_stats().spill_pages_out;
    let append: Vec<i32> = vec![3, 1, 4, 1];
    let resp = server.infer_session(1, append.clone()).expect("restart turn");
    let mut full = ctx;
    full.extend_from_slice(&append);
    let identity_ok = resp.logits == oracle_backend.forward_logits(&full);
    let stats = server.cache_stats();
    println!(
        "store/restart: {} pages spilled, {} hydrated back ({} hydrating checkouts) | {} checksum failures | logits identical: {identity_ok}",
        stats.spill_pages_out, stats.spill_pages_in, stats.hydrate_hits,
        stats.store_checksum_failures,
    );
    assert!(spill_pages_out > 0, "the pressure turn must actually spill");
    assert!(identity_ok, "post-hydrate logits must be bit-identical to a fresh forward");
    assert_eq!(stats.store_checksum_failures, 0);
    Json::obj(vec![
        ("kind", Json::str("restart")),
        ("spill_pages_out", Json::num(stats.spill_pages_out as f64)),
        ("spill_pages_in", Json::num(stats.spill_pages_in as f64)),
        ("hydrate_hits", Json::num(stats.hydrate_hits as f64)),
        ("checksum_failures", Json::num(stats.store_checksum_failures as f64)),
        ("identity_ok", Json::Bool(identity_ok)),
    ])
}

fn main() {
    let quick = quick_env();
    let iters = if quick { 3 } else { 10 };
    let n_ctx = if quick { 512 } else { 4096 };
    let mut records: Vec<Json> = Vec::new();

    println!("== persistent store: checkpoint load, KV spill tier, restart identity ==");
    records.push(bench_checkpoint(iters));
    records.push(bench_spill(n_ctx, iters.min(5), quick));
    records.push(bench_restart());

    write_jsonl("results/store.jsonl", &records).expect("write results/store.jsonl");
    println!("\nstore bench OK; {} records -> results/store.jsonl", records.len());
}
