//! Bench: hwsim model evaluation cost (it's analytic — must be instant)
//! plus the Table-3 numbers printed for the record.

use had::hwsim::{breakdown, context_sweep, Design, Tech, Workload};
use had::util::bench::Bencher;

fn main() {
    let tech = Tech::default();
    let b = Bencher::quick();

    let s = b.run("hwsim/breakdown paper workload", || {
        let sa = breakdown(Design::Standard, Workload::paper(), &tech);
        let had = breakdown(Design::Had, Workload::paper(), &tech);
        (sa.total_area(), had.total_area())
    });
    s.print();

    let s = b.run("hwsim/context sweep 6 points", || {
        context_sweep(&tech, &[128, 256, 512, 1024, 2048, 4096])
    });
    s.print();

    // the actual Table-3 numbers, for bench_output.txt
    println!("\n{}", had::hwsim::table3_text(&tech));
}
