//! Bench: cross-session prefix sharing — N concurrent streams over ONE
//! identical prompt, sharing on vs off, at 1/4/16 streams.
//!
//! With sharing enabled the elected prefiller pays the prompt's prefill
//! once; every other stream adopts the published content-hashed stripes
//! and skips straight to decode. Acceptance gates (hard asserts, and
//! re-checked by scripts/validate_prefix.py over the emitted records):
//!
//!   * tokens bit-identical to the sharing-off baseline, stream by
//!     stream;
//!   * the shareable prompt prefix is prefilled exactly once —
//!     `prefix_tokens_reused == (n-1) * share_tokens`, no follower
//!     re-executed a shared stripe;
//!   * the pool (private pages AND shared registry) drains to zero
//!     bytes once every session ends;
//!   * at 16 streams the shared run resides a fraction of the baseline
//!     bytes (shared bytes counted once) and, in full mode, finishes
//!     faster.
//!
//! Appends machine-readable records to results/prefix.jsonl for
//! scripts/validate_prefix.py (the CI prefix-smoke gate). Full mode
//! uses a 4096-token prompt; HAD_BENCH_QUICK=1 shrinks it to 256 so
//! the smoke leg stays fast (identity/counter asserts always run).

use std::time::Instant;

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::generate::{GenerateRequest, StopReason, StreamEvent};
use had::kvcache::KvCacheConfig;
use had::serve::{demo_config, HadBackend, ServeModel};
use had::util::bench::{quick_env, write_jsonl};
use had::util::json::Json;
use had::util::rng::Rng;

const N_NEW: usize = 8; // decoded tokens per stream after the prompt

fn serve(model: &ServeModel, kv: KvCacheConfig, n_ctx: usize, sharing: bool) -> Server {
    let router =
        Router::new(vec![Bucket { config: "prefix".into(), n_ctx, batch: 16 }]);
    Server::builder(
        HadBackend::new(model.clone(), &kv),
        router,
        BatchPolicy {
            max_wait: std::time::Duration::from_millis(1),
            max_streams: 16,
            ..Default::default()
        },
    )
    .kv(kv)
    .prefix_sharing(sharing)
    .start()
    .expect("server start")
}

/// Submit `n` identical greedy streams, drain them all, and return
/// (per-stream tokens, wall time ms, pool bytes resident after every
/// stream retired but before its session ends).
fn run(server: &Server, prompt: &[i32], n: u64) -> (Vec<Vec<i32>>, f64, usize) {
    let t0 = Instant::now();
    let rxs: Vec<_> = (1..=n)
        .map(|sid| {
            server
                .submit_generate(sid, GenerateRequest::greedy(prompt.to_vec(), N_NEW))
                .expect("admitted")
        })
        .collect();
    let streams: Vec<Vec<i32>> = rxs
        .into_iter()
        .map(|rx| {
            let mut tokens = Vec::new();
            for event in rx.iter() {
                match event {
                    StreamEvent::Token { token, .. } => tokens.push(token),
                    StreamEvent::Done { reason, .. } => {
                        assert_eq!(reason, StopReason::MaxTokens, "stream must run to budget");
                        return tokens;
                    }
                }
            }
            panic!("server dropped the stream");
        })
        .collect();
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let resident = server.sessions().lock().unwrap().pool().bytes();
    (streams, ms, resident)
}

/// End every session and return the pool bytes left behind (the
/// drain-to-zero gate: shared registry entries must die with their
/// last reference).
fn drain(server: &Server, n: u64) -> usize {
    let sessions = server.sessions();
    let mut store = sessions.lock().unwrap();
    for sid in 1..=n {
        store.end_session(sid);
    }
    store.pool().bytes()
}

fn bench_point(model: &ServeModel, kv: KvCacheConfig, n_ctx: usize, prompt: &[i32], n: u64, quick: bool) -> Json {
    let share_tokens = (prompt.len() - 1) / kv.page_tokens * kv.page_tokens;
    let expected_reuse = (n - 1) * share_tokens as u64;

    let baseline = serve(model, kv, n_ctx, false);
    let shared = serve(model, kv, n_ctx, true);
    let (base_tokens, base_ms, base_bytes) = run(&baseline, prompt, n);
    let (shared_tokens, shared_ms, shared_bytes) = run(&shared, prompt, n);

    let identity_ok = shared_tokens == base_tokens;
    assert!(identity_ok, "prefix sharing must be bit-identical to unshared serving");

    let stats = shared.cache_stats();
    // counter math is deterministic, not a perf statistic: every
    // follower adopts the shareable prefix exactly once, so the prompt
    // was prefilled exactly once across all n streams
    let prefill_once = stats.prefix_tokens_reused == expected_reuse;
    assert!(
        prefill_once,
        "streams={n}: reused {} prompt tokens, expected exactly {expected_reuse}",
        stats.prefix_tokens_reused,
    );
    let base_stats = baseline.cache_stats();
    assert_eq!(
        (base_stats.shared_pages, base_stats.prefix_tokens_reused),
        (0, 0),
        "sharing off: prefix counters stay zero"
    );

    let bytes_ratio = shared_bytes as f64 / base_bytes.max(1) as f64;
    let leftover = drain(&shared, n) + drain(&baseline, n);
    let drained_ok = leftover == 0;
    assert!(drained_ok, "{leftover} pool bytes leaked after every session ended");

    println!(
        "prefix/streams={n}: sharing {shared_ms:.1} ms vs baseline {base_ms:.1} ms \
         ({:.2}x) | {} tokens reused ({} hits) | resident {:.0}% of baseline | \
         drained to zero: {drained_ok}",
        base_ms / shared_ms.max(1e-9),
        stats.prefix_tokens_reused,
        stats.prefix_hits,
        bytes_ratio * 100.0,
    );
    if n >= 16 && !quick {
        assert!(
            shared_ms < base_ms,
            "at {n} streams one shared prefill must beat {n} private ones"
        );
    }
    Json::obj(vec![
        ("kind", Json::str("streams")),
        ("streams", Json::num(n as f64)),
        ("prompt_tokens", Json::num(prompt.len() as f64)),
        ("share_tokens", Json::num(share_tokens as f64)),
        ("baseline_ms", Json::num(base_ms)),
        ("sharing_ms", Json::num(shared_ms)),
        ("shared_pages", Json::num(stats.shared_pages as f64)),
        ("prefix_hits", Json::num(stats.prefix_hits as f64)),
        ("tokens_reused", Json::num(stats.prefix_tokens_reused as f64)),
        ("expected_reuse", Json::num(expected_reuse as f64)),
        ("cow_copies", Json::num(stats.cow_copies as f64)),
        ("baseline_bytes", Json::num(base_bytes as f64)),
        ("sharing_bytes", Json::num(shared_bytes as f64)),
        ("bytes_ratio", Json::num(bytes_ratio)),
        ("identity_ok", Json::Bool(identity_ok)),
        ("prefill_once", Json::Bool(prefill_once)),
        ("drained_ok", Json::Bool(drained_ok)),
    ])
}

fn main() {
    let quick = quick_env();
    let prompt_len = if quick { 256 } else { 4096 };
    let n_ctx = prompt_len + 2 * N_NEW;
    let cfg = demo_config("prefix_bench", n_ctx, 32);
    let model = ServeModel::random(&cfg, 0x9E1F).expect("model");
    let kv_probe = KvCacheConfig { page_tokens: 64, ..Default::default() };
    // budget: every stream fully resident plus headroom — eviction and
    // spill are store.rs territory; this bench isolates sharing
    let budget =
        18 * HadBackend::new(model.clone(), &kv_probe).fresh_kv().bytes_at(n_ctx);
    let kv = KvCacheConfig { page_tokens: 64, byte_budget: budget, ..Default::default() };

    let mut rng = Rng::new(0x9E20);
    let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(256) as i32).collect();

    println!(
        "== prefix sharing: {prompt_len}-token identical prompt, sharing on vs off =="
    );
    let mut records: Vec<Json> = Vec::new();
    for n in [1u64, 4, 16] {
        records.push(bench_point(&model, kv, n_ctx, &prompt, n, quick));
    }
    write_jsonl("results/prefix.jsonl", &records).expect("write results/prefix.jsonl");
    println!("\nprefix bench OK; {} records -> results/prefix.jsonl", records.len());
}
