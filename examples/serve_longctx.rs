//! Long-context serving demo: the L3 coordinator serving batched
//! requests across length buckets with the binarized (fwd_had) models.
//!
//! Spawns client threads generating a mixed-length workload, routes
//! through the length-bucket router + dynamic batcher onto the PJRT
//! engine thread, and reports latency percentiles / throughput / batch
//! occupancy per the paper's serving motivation.
//!
//! Run: cargo run --release --example serve_longctx -- [--requests 64] [--clients 4]

use anyhow::Result;
use had::coordinator::{BatchPolicy, Router, Server, ServingModel};
use had::data::longqa::LongQaGen;
use had::runtime::{default_artifact_dir, Engine};
use had::util::cli::Args;
use had::util::rng::Rng;

fn main() -> Result<()> {
    had::util::log::init_from_env();
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 64);
    let n_clients = args.get_usize("clients", 4);
    let fwd = args.get_str("fwd", "fwd_had");

    // engine thread owns PJRT; handles are Send
    let engine = Engine::start(default_artifact_dir())?;
    let router = Router::longqa_default();

    // one serving model per bucket (random weights: serving-path demo)
    let manifest = had::runtime::Manifest::load(default_artifact_dir())?;
    let models: Vec<ServingModel> = router
        .buckets()
        .iter()
        .map(|b| ServingModel::random(&manifest, &b.config, 7, &fwd))
        .collect::<Result<_>>()?;

    // pre-compile every bucket so latency numbers are steady-state
    for b in router.buckets() {
        let ms = engine.handle().warmup(&format!("{}__{}", b.config, fwd))?;
        println!("warmed {}__{fwd} in {ms} ms", b.config);
    }

    let server = Server::start(
        engine.handle(),
        router,
        models,
        BatchPolicy { max_wait: std::time::Duration::from_millis(4), ..Default::default() },
    )?;

    println!("\nserving {n_requests} requests from {n_clients} client threads...");
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let server = &server;
            scope.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                for i in 0..n_requests / n_clients {
                    // mixed-length workload across all buckets
                    let n_ctx = [128usize, 256, 512, 1024][rng.range_usize(0, 4)];
                    let gen = LongQaGen::new(n_ctx);
                    let mut tokens = vec![0i32; n_ctx];
                    let _label = gen.sample(&mut rng, &mut tokens);
                    match server.infer(tokens) {
                        Ok(resp) => {
                            if i == 0 {
                                println!(
                                    "client {c}: first response from {} in {:.2} ms (pred {}, occ {})",
                                    resp.bucket,
                                    resp.latency_us as f64 / 1e3,
                                    resp.pred,
                                    resp.batch_occupancy
                                );
                            }
                        }
                        Err(e) => eprintln!("client {c}: {e:#}"),
                    }
                }
            });
        }
    });
    let elapsed = t0.elapsed();

    let snap = server.metrics.snapshot();
    snap.print("serve_longctx");
    println!(
        "wall time {elapsed:?} => {:.1} req/s end-to-end",
        snap.requests as f64 / elapsed.as_secs_f64()
    );
    println!("serve_longctx OK");
    Ok(())
}
