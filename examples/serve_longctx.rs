//! Long-context serving demo: the L3 coordinator serving a mixed-length
//! batched workload with REAL logits from the CPU bitpacked backend —
//! no PJRT artifacts required (the engine is now an optional cross-check
//! path, not the decode path).
//!
//! Spawns client threads generating mixed-length sessionless requests
//! plus a set of multi-turn sessions, routes through the length-bucket
//! router + dynamic batcher onto the backend decode pass, and reports
//! latency percentiles, throughput, batch occupancy, AND cache hit rate
//! (the serving metrics pair from the paper's motivation).
//!
//! Run: cargo run --release --example serve_longctx -- [--requests 32] [--clients 4]

use anyhow::Result;
use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::kvcache::KvCacheConfig;
use had::serve::{demo_config, HadBackend, ServeModel};
use had::util::cli::Args;
use had::util::rng::Rng;

fn main() -> Result<()> {
    had::util::log::init_from_env();
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 32);
    let n_clients = args.get_usize("clients", 4);
    let session_turns = args.get_usize("session-turns", 4);

    // one model serves every bucket (the backend is shape-agnostic; the
    // buckets only partition batching by length)
    let max_ctx = 1024usize;
    let cfg = demo_config("cpu_longctx", max_ctx, 64);
    let vocab = cfg.model.vocab as u64;
    let model = ServeModel::random(&cfg, 7).expect("demo model");
    let kv = KvCacheConfig { page_tokens: 64, ..Default::default() };
    let backend = HadBackend::new(model, &kv);
    let router = Router::new(
        [(128usize, 16usize), (256, 16), (512, 8), (1024, 4)]
            .iter()
            .map(|&(n, b)| Bucket { config: format!("cpu_{n}"), n_ctx: n, batch: b })
            .collect(),
    );
    let server = Server::builder(
        backend,
        router,
        BatchPolicy { max_wait: std::time::Duration::from_millis(4), ..Default::default() },
    )
    .kv(kv)
    .start()?;

    println!("\nserving {n_requests} mixed-length requests from {n_clients} client threads...");
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            // &Server is Copy: each move closure gets its own copy of the
            // reference to the outer server
            let srv = &server;
            scope.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                for i in 0..n_requests / n_clients {
                    // mixed-length workload across all buckets
                    let n_ctx = [128usize, 256, 512, 1024][rng.range_usize(0, 4)];
                    let tokens: Vec<i32> =
                        (0..n_ctx).map(|_| rng.below(vocab) as i32).collect();
                    match srv.infer(tokens) {
                        Ok(resp) => {
                            assert!(resp.logits.iter().all(|x| x.is_finite()));
                            if i == 0 {
                                println!(
                                    "client {c}: first response from {} in {:.2} ms (pred {}, occ {}, kernel share {:.0}%)",
                                    resp.bucket,
                                    resp.latency_us as f64 / 1e3,
                                    resp.pred,
                                    resp.batch_occupancy,
                                    if resp.decode_us > 0 {
                                        100.0 * resp.kernel_us as f64 / resp.decode_us as f64
                                    } else {
                                        0.0
                                    },
                                );
                            }
                        }
                        Err(e) => eprintln!("client {c}: {e:#}"),
                    }
                }
            });
            // one multi-turn session per client rides along: its warm
            // turns decode only the appended suffix (cache hits)
            scope.spawn(move || {
                let mut rng = Rng::new(2000 + c as u64);
                let sid = 9000 + c as u64;
                for turn in 0..session_turns {
                    let rows = if turn == 0 { 96 } else { 24 };
                    let append: Vec<i32> =
                        (0..rows).map(|_| rng.below(vocab) as i32).collect();
                    if let Err(e) = srv.infer_session(sid, append) {
                        eprintln!("session {sid}: {e:#}");
                    }
                }
            });
        }
    });
    let elapsed = t0.elapsed();

    let snap = server.metrics.snapshot();
    snap.print("serve_longctx");
    let stats = server.cache_stats();
    println!(
        "cache hit rate {:.1}% ({} hits / {} misses) | latency p50 {:.2} ms p99 {:.2} ms",
        100.0 * stats.hit_rate(),
        stats.hits,
        stats.misses,
        snap.p50_us as f64 / 1e3,
        snap.p99_us as f64 / 1e3,
    );
    println!(
        "wall time {elapsed:?} => {:.1} req/s end-to-end",
        snap.requests as f64 / elapsed.as_secs_f64()
    );
    println!("serve_longctx OK");
    Ok(())
}
