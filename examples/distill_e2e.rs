//! End-to-end driver (EXPERIMENTS.md §E2E): the full system on one real
//! workload, proving all layers compose:
//!
//!   teacher pre-training (PJRT train-step artifact)
//!   -> sigma calibration (Eq. 12)
//!   -> 4-stage HAD distillation (Algorithm 1, tanh -> STE)
//!   -> evaluation of teacher vs binarized student (fused Pallas fwd)
//!   -> checkpoint save/load round trip
//!
//! Logs the loss curve and accuracy; scale with --scale / --task.
//!
//! Run: cargo run --release --example distill_e2e -- [--scale 0.5] [--task QQP]

use anyhow::Result;
use had::data::tinyglue::{GlueGen, GlueTask};
use had::data::token_batch;
use had::distill::{evaluate, Method, Pipeline, Schedule};
use had::exp::SuiteOptions;
use had::model::{load_checkpoint, save_checkpoint};
use had::runtime::{default_artifact_dir, Runtime};
use had::util::cli::Args;
use had::util::rng::Rng;

fn main() -> Result<()> {
    had::util::log::init_from_env();
    let args = Args::parse(std::env::args().skip(1));
    let mut opts = SuiteOptions::default();
    opts.scale = args.get_f64("scale", 1.0);
    opts.seed = args.get_u64("seed", opts.seed);
    let task_name = args.get_str("task", "QQP");
    let task = GlueTask::ALL
        .iter()
        .copied()
        .find(|t| t.name().eq_ignore_ascii_case(&task_name))
        .unwrap_or(GlueTask::Qqp);

    let rt = Runtime::new(default_artifact_dir())?;
    let cfg = rt.manifest.config("tinyglue")?;
    let n_ctx = cfg.model.n_ctx;
    let tb = cfg.train_batch;
    let n_top = cfg.model.n_top as f32;

    println!("=== distill_e2e: task {} | scale {} ===", task.name(), opts.scale);
    let gen = GlueGen::new(task);
    let mut train = |rng: &mut Rng| token_batch(&gen, rng, tb, n_ctx);

    // 1) teacher
    let schedule = Schedule::new(opts.budget(), opts.lr);
    let mut pipeline = Pipeline::new(&rt, cfg, schedule);
    pipeline.teacher_lr = opts.teacher_lr;
    let mut rng = Rng::new(opts.seed);
    let t0 = std::time::Instant::now();
    let (teacher_params, teacher_acc) = pipeline.train_teacher(&mut rng, &mut train)?;
    println!("teacher trained: {} steps, acc~{teacher_acc:.3}, {:?}", opts.budget().teacher, t0.elapsed());

    // 2) calibration (paper Eq. 12)
    let (sq, sk) = pipeline.calibrate_sigma(&teacher_params, &mut rng, &mut train, opts.calib_batches)?;
    println!("sigma_q={sq:?} sigma_k={sk:?}");

    // 3) 4-stage distillation
    let t1 = std::time::Instant::now();
    let outcome = pipeline.distill(Method::Had, &teacher_params, &sq, &sk, n_top, &mut rng, &mut train)?;
    println!(
        "distilled {} steps in {:?}; loss curve (step, kl_att, kl_out):",
        outcome.loss_trace.len(),
        t1.elapsed()
    );
    let stride = (outcome.loss_trace.len() / 12).max(1);
    for (step, kl_att, kl_out) in outcome.loss_trace.iter().step_by(stride) {
        println!("  step {step:>5}  kl_att {kl_att:>9.5}  kl_out {kl_out:>9.5}");
    }

    // 4) evaluate teacher vs student on a held-out stream
    let eval_gen = GlueGen::new(task);
    let mut eval_rng = Rng::new(opts.seed ^ 0xE7A1);
    let evals: Vec<_> = (0..opts.eval_batches)
        .map(|_| token_batch(&eval_gen, &mut eval_rng, tb, n_ctx))
        .collect();
    let teacher_ckpt = had::model::Checkpoint {
        config: "tinyglue".into(),
        step: 0.0,
        sigma_q: sq.clone(),
        sigma_k: sk.clone(),
        params: teacher_params,
    };
    let base = evaluate(&rt, cfg, "fwd_standard", &teacher_ckpt, &evals, n_top)?;
    let student = evaluate(&rt, cfg, "fwd_had", &outcome.student, &evals, n_top)?;
    println!(
        "accuracy: teacher(fp32 attention) {:.2}%  vs  HAD student (binary K/Q, top-{}) {:.2}%",
        base.metric("accuracy"),
        cfg.model.n_top,
        student.metric("accuracy")
    );

    // 5) checkpoint round trip
    let path = std::path::PathBuf::from("results").join("distill_e2e.ckpt");
    save_checkpoint(&path, cfg, &outcome.student)?;
    let loaded = load_checkpoint(&path, cfg)?;
    let re = evaluate(&rt, cfg, "fwd_had", &loaded, &evals, n_top)?;
    assert_eq!(re.preds, student.preds, "checkpoint round-trip must be exact");
    println!("checkpoint save/load round-trip OK -> {path:?}");
    println!("distill_e2e OK");
    Ok(())
}
