//! Hardware cost report: the paper's Table 3 plus scaling sweeps from the
//! hwsim component model (CAM-based HAD unit vs BF16 standard attention).
//!
//! Run: cargo run --release --example hwsim_report

use had::hwsim::{breakdown, context_sweep, render_comparison, Design, Tech, Workload};

fn main() {
    let tech = Tech::default();

    // Paper workload (Table 3): n=256, d=1024, N=30
    println!("{}", had::hwsim::table3_text(&tech));

    // Other design points: the serving buckets of this repo
    for (n, d, ntop) in [(128usize, 512usize, 15usize), (1024, 512, 120), (4096, 1024, 480)] {
        let w = Workload { n_ctx: n, d_model: d, n_top: ntop };
        let sa = breakdown(Design::Standard, w, &tech);
        let had_ = breakdown(Design::Had, w, &tech);
        println!("{}", render_comparison(&sa, &had_));
    }

    println!("Energy-per-query sweep (N scaled linearly with n):");
    println!("{:>8} {:>12} {:>12} {:>8}", "n_ctx", "SA nJ", "HAD nJ", "ratio");
    for (n, sa_nj, had_nj, _) in context_sweep(&tech, &[128, 256, 512, 1024, 2048, 4096, 8192]) {
        println!("{n:>8} {sa_nj:>12.1} {had_nj:>12.1} {:>7.1}x", sa_nj / had_nj);
    }
}
