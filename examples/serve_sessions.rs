//! Session-aware serving demo: multi-turn conversations served END TO
//! END by the CPU bitpacked backend through the full coordinator —
//! router, dynamic batcher, per-layer paged KV cache, real logits.
//!
//! Each turn appends a few tokens to its session; the batch decode
//! checks the session's per-layer page chains out of the byte-budgeted
//! pool and executes ONLY the non-resident suffix (packed-K residency:
//! pages from earlier turns are re-scored in place). Responses carry the
//! backend's real logits, which are cross-checked here against a fresh
//! full-sequence forward of the same weights — bit for bit, because
//! causal decode makes incremental serving exact.
//!
//! Reports cache hit rate alongside latency percentiles (the serving
//! metrics pair the ROADMAP asks the demos to show). Runs without PJRT
//! artifacts (pure CPU).
//!
//! Run: cargo run --release --example serve_sessions -- [--sessions 4] [--turns 5]

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::kvcache::KvCacheConfig;
use had::serve::{demo_config, HadBackend, ServeModel};
use had::util::cli::Args;
use had::util::rng::Rng;

fn main() {
    had::util::log::init_from_env();
    let args = Args::parse(std::env::args().skip(1));
    let n_sessions = args.get_usize("sessions", 4) as u64;
    let n_turns = args.get_usize("turns", 5);
    let prefill = args.get_usize("prefill", 128); // first-turn context
    let turn_tokens = args.get_usize("turn-tokens", 24); // follow-up appends
    let n_ctx = 512usize;

    let cfg = demo_config("cpu_512", n_ctx, 48);
    let vocab = cfg.model.vocab as u64;
    let model = ServeModel::random(&cfg, 0xCAFE).expect("demo model");
    let kv = KvCacheConfig { page_tokens: 32, ..Default::default() };
    // identical probe backend = the full-sequence oracle
    let probe = HadBackend::new(model.clone(), &kv);
    let backend = HadBackend::new(model, &kv);
    let router = Router::new(vec![Bucket { config: "cpu_512".into(), n_ctx, batch: 8 }]);
    let server = Server::builder(
        backend,
        router,
        BatchPolicy { max_wait: std::time::Duration::from_millis(2), ..Default::default() },
    )
    .kv(kv)
    .start()
    .expect("server start");

    let mut rng = Rng::new(0xBEEF);
    let mut transcripts: Vec<Vec<i32>> = vec![Vec::new(); n_sessions as usize];
    let mut checked = 0usize;
    println!(
        "serving {n_sessions} sessions x {n_turns} turns (prefill {prefill}, +{turn_tokens}/turn) on the CPU backend\n"
    );
    for turn in 0..n_turns {
        for sid in 0..n_sessions {
            let rows = if turn == 0 { prefill } else { turn_tokens };
            let append: Vec<i32> = (0..rows).map(|_| rng.below(vocab) as i32).collect();
            transcripts[sid as usize].extend_from_slice(&append);
            let resp = server.infer_session(sid, append).expect("turn served");
            assert_eq!(
                resp.logits,
                probe.forward_logits(&transcripts[sid as usize]),
                "served logits must equal the full-sequence forward (session {sid}, turn {turn})"
            );
            checked += 1;
        }
        let stats = server.cache_stats();
        let snap = server.metrics.snapshot();
        println!(
            "turn {turn}: pool {} KiB resident | {} hits {} misses ({:.1}% hit) | decode mean {:.2} ms (kernel share {:.1}%)",
            snap.cache_bytes / 1024,
            stats.hits,
            stats.misses,
            100.0 * stats.hit_rate(),
            snap.decode_mean_us / 1e3,
            if snap.decode_mean_us > 0.0 { 100.0 * snap.kernel_mean_us / snap.decode_mean_us } else { 0.0 },
        );
    }

    let snap = server.metrics.snapshot();
    snap.print("serve_sessions");
    let stats = server.cache_stats();
    // the serving pair the ROADMAP wants demos to report: hit rate
    // alongside latency percentiles
    println!(
        "\ncache hit rate {:.1}% ({} hits / {} misses) | latency p50 {:.2} ms p99 {:.2} ms",
        100.0 * stats.hit_rate(),
        stats.hits,
        stats.misses,
        snap.p50_us as f64 / 1e3,
        snap.p99_us as f64 / 1e3,
    );
    assert!(
        stats.hits as usize >= n_sessions as usize * (n_turns - 1),
        "every warm turn must resume from resident pages"
    );
    println!("{checked} turns served, every response matched the full-sequence oracle");
    println!("serve_sessions OK");
}
