//! Session-aware serving demo: multi-turn conversations over the paged
//! bit-packed KV cache, end to end on the CPU fast path.
//!
//! Each turn appends a few tokens to its session, packs ONLY the
//! non-resident suffix into the byte-budgeted page pool (packed-K
//! residency: pages from earlier turns are reused in place), then answers
//! the turn with `had_attention_paged` scored directly over the
//! non-contiguous pages. Warm turns are compared against rebuilding the
//! cache from scratch — the cost a stateless coordinator pays — and every
//! output is cross-checked against the contiguous `had_attention` path.
//!
//! Runs without PJRT artifacts (pure CPU). For the PJRT-backed
//! coordinator variant of the same flow see `Server::submit_session`.
//!
//! Run: cargo run --release --example serve_sessions -- [--sessions 4] [--turns 6]

use std::time::Instant;

use had::binary::attention::{had_attention_paged_with, had_attention_with, Scratch};
use had::binary::{HadAttnConfig, PackedKv};
use had::kvcache::{KvCacheConfig, PagePool};
use had::tensor::Mat;
use had::util::cli::Args;
use had::util::rng::Rng;

/// Append `rows` onto a row-major matrix transcript.
fn append_rows(m: &mut Mat, rows: &Mat) {
    assert_eq!(m.cols, rows.cols, "column mismatch");
    m.data.extend_from_slice(&rows.data);
    m.rows += rows.rows;
}

/// Copy rows [lo..] of a transcript into an owned Mat.
fn tail_rows(m: &Mat, lo: usize) -> Mat {
    Mat::from_vec(m.rows - lo, m.cols, m.data[lo * m.cols..].to_vec())
}

fn main() {
    had::util::log::init_from_env();
    let args = Args::parse(std::env::args().skip(1));
    let n_sessions = args.get_usize("sessions", 4) as u64;
    let n_turns = args.get_usize("turns", 6);
    let (d, d_v, page_tokens) = (64usize, 64usize, 64usize);
    let prefill = 512usize; // first-turn context
    let turn_tokens = 32usize; // follow-up appends
    let n_q = 8usize; // query block answering each turn

    let pool_cfg = KvCacheConfig { page_tokens, ..Default::default() };
    let mut pool = PagePool::new(pool_cfg);
    let cfg = HadAttnConfig { n_top: 48, temp: 1.0 };
    let mut scratch = Scratch::default();
    let mut rng = Rng::new(0xCAFE);

    // Full per-session K/V transcript: the cold oracle rebuilds from it;
    // the warm path only ever packs its non-resident tail.
    let mut transcripts: Vec<(Mat, Mat)> = (0..n_sessions)
        .map(|_| (Mat::zeros(0, d), Mat::zeros(0, d_v)))
        .collect();

    let mut warm_us = 0.0f64;
    let mut cold_us = 0.0f64;
    let mut checked = 0usize;
    println!(
        "serving {n_sessions} sessions x {n_turns} turns (prefill {prefill}, +{turn_tokens}/turn)\n"
    );
    for turn in 0..n_turns {
        for sid in 0..n_sessions {
            let rows = if turn == 0 { prefill } else { turn_tokens };
            let k_new = Mat::random(rows, d, &mut rng, 1.0);
            let v_new = Mat::random(rows, d_v, &mut rng, 1.0);
            let q = Mat::random(n_q, d, &mut rng, 1.0);
            let (tk, tv) = &mut transcripts[sid as usize];
            append_rows(tk, &k_new);
            append_rows(tv, &v_new);

            // --- warm path: pack only what the pool doesn't hold (the new
            // turn; the full transcript again if the session was evicted)
            let t0 = Instant::now();
            let cached = pool.cached_tokens(sid);
            let (k_fresh, v_fresh) = (tail_rows(tk, cached), tail_rows(tv, cached));
            pool.append(sid, &k_fresh, &v_fresh);
            let kv = pool.get(sid).expect("session resident after append");
            let out_warm = had_attention_paged_with(&q, kv, &cfg, &mut scratch);
            warm_us += t0.elapsed().as_nanos() as f64 / 1e3;

            // --- cold oracle: rebuild the contiguous cache every turn
            let t1 = Instant::now();
            let rebuilt = PackedKv::from_parts(tk, tv.clone());
            let out_cold = had_attention_with(&q, &rebuilt, &cfg, &mut scratch);
            cold_us += t1.elapsed().as_nanos() as f64 / 1e3;

            assert_eq!(
                out_warm, out_cold,
                "paged warm path must match contiguous rebuild (session {sid}, turn {turn})"
            );
            checked += 1;
        }
        let stats = pool.stats();
        println!(
            "turn {turn}: pool {} sessions / {} KiB | {} hits {} misses | warm {:.0} µs vs cold-rebuild {:.0} µs (cum)",
            pool.len(),
            pool.bytes() / 1024,
            stats.hits,
            stats.misses,
            warm_us,
            cold_us,
        );
    }

    let stats = pool.stats();
    let tokens_resident: usize = transcripts.iter().map(|(tk, _)| tk.rows).sum();
    println!(
        "\n{checked} turns served, every output matched the contiguous oracle; cache hit rate {:.1}%",
        100.0 * stats.hit_rate()
    );
    println!(
        "packed-K residency: {} KiB of sign-bit keys vs {} KiB as f32 ({}x smaller)",
        tokens_resident * 8 / 1024,
        tokens_resident * d * 4 / 1024,
        d * 4 / 8,
    );
    println!(
        "warm incremental serving was {:.1}x faster than per-turn rebuilds",
        cold_us / warm_us.max(1.0)
    );
    println!("serve_sessions OK");
}
