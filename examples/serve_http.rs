//! End-to-end HTTP serving demo: boots the coordinator behind the
//! dependency-free net front-end on loopback, then plays a real client
//! against it — health probe, a multi-turn classification session over
//! `POST /v1/sessions` (second turn resuming warm), a streamed
//! `POST /v1/generate` read chunk by chunk with client-observed TTFT,
//! a metrics scrape showing the net counters, and a `DELETE` that
//! releases the session's KV pages.
//!
//! With `--listen`, keeps serving instead (try the README's curl
//! examples against the printed address; ctrl-C to stop).
//!
//! Run: cargo run --release --example serve_http -- [--port 0] [--listen]

use std::sync::Arc;
use std::time::{Duration, Instant};

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::kvcache::KvCacheConfig;
use had::net::{roundtrip, HttpClient, NetConfig, NetServer};
use had::serve::{demo_config, HadBackend, ServeModel};
use had::util::cli::Args;
use had::util::json::Json;

fn main() {
    had::util::log::init_from_env();
    let args = Args::parse(std::env::args().skip(1));
    let port = args.get_usize("port", 0);
    let listen = args.get_bool("listen");
    let n_ctx = 256usize;

    let cfg = demo_config("http_256", n_ctx, 48);
    let model = ServeModel::random(&cfg, 0xD0DE).expect("demo model");
    let kv = KvCacheConfig { page_tokens: 32, ..Default::default() };
    let router = Router::new(vec![Bucket { config: "http_256".into(), n_ctx, batch: 8 }]);
    let server = Arc::new(
        Server::builder(
            HadBackend::new(model, &kv),
            router,
            BatchPolicy {
                max_wait: Duration::from_millis(2),
                max_streams: 8,
                ..Default::default()
            },
        )
        .kv(kv)
        .start()
        .expect("server start"),
    );
    let net = NetServer::bind(
        Arc::clone(&server),
        format!("127.0.0.1:{port}"),
        NetConfig::default(),
    )
    .expect("bind");
    let addr = net.local_addr();
    println!("serving on http://{addr}\n");

    if listen {
        println!("listening (ctrl-C to stop) — try:");
        println!("  curl -s http://{addr}/healthz");
        println!(
            "  curl -s -N -X POST http://{addr}/v1/generate -d '{{\"session\":1,\"prompt\":[1,2,3],\"max_new_tokens\":8}}'"
        );
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }

    // liveness
    let (status, body) = roundtrip(addr, "GET", "/healthz", None).expect("healthz");
    assert_eq!(status, 200);
    println!("GET /healthz -> {status} {}", String::from_utf8_lossy(&body));

    // two session turns; the second resumes warm from the first's pages
    let (status, body) =
        roundtrip(addr, "POST", "/v1/sessions", Some(br#"{"session":1,"tokens":[1,2,3,4,5,6,7,8]}"#))
            .expect("turn 1");
    assert_eq!(status, 200);
    let turn1 = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    println!(
        "POST /v1/sessions (turn 1) -> pred {} bucket {:?} cached {}",
        turn1.get("pred").and_then(Json::as_f64).unwrap(),
        turn1.get("bucket").and_then(Json::as_str).unwrap(),
        turn1.get("cached_tokens").and_then(Json::as_usize).unwrap(),
    );
    let (status, body) =
        roundtrip(addr, "POST", "/v1/sessions", Some(br#"{"session":1,"tokens":[9,10]}"#))
            .expect("turn 2");
    assert_eq!(status, 200);
    let turn2 = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let cached = turn2.get("cached_tokens").and_then(Json::as_usize).unwrap();
    assert_eq!(cached, 8, "turn 2 must resume from turn 1's context");
    println!("POST /v1/sessions (turn 2) -> cached {cached} (warm resume)");

    // streamed generation, read the way a real client would
    let mut c = HttpClient::connect(addr).expect("connect");
    c.set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();
    let t0 = Instant::now();
    c.send(
        "POST",
        "/v1/generate",
        Some(br#"{"session":2,"prompt":[1,2,3,4],"max_new_tokens":12}"#),
    )
    .expect("send generate");
    let head = c.read_head().expect("head");
    assert_eq!(head.status, 200);
    assert!(head.chunked());
    let mut first_chunk_ms = 0.0;
    let mut n_tokens = 0usize;
    while let Some(chunk) = c.next_chunk().expect("chunk") {
        if first_chunk_ms == 0.0 {
            first_chunk_ms = t0.elapsed().as_micros() as f64 / 1e3;
        }
        let line = String::from_utf8_lossy(&chunk);
        let event = Json::parse(line.trim_end()).expect("event json");
        match event.get("event").and_then(Json::as_str) {
            Some("token") => {
                n_tokens += 1;
                print!("{} ", event.get("token").and_then(Json::as_f64).unwrap());
            }
            Some("done") => println!(
                "\nPOST /v1/generate -> {} tokens ({}), client TTFT {first_chunk_ms:.2} ms",
                event.get("generated").and_then(Json::as_usize).unwrap(),
                event.get("reason").and_then(Json::as_str).unwrap(),
            ),
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert_eq!(n_tokens, 12);

    // metrics scrape: the net counters observed all of the above
    let (status, body) = roundtrip(addr, "GET", "/v1/metrics", None).expect("metrics");
    assert_eq!(status, 200);
    let metrics = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let reqs = metrics.at(&["counters", "net_requests"]).and_then(Json::as_f64).unwrap();
    println!("GET /v1/metrics -> net_requests {reqs}");
    assert!(reqs >= 5.0);

    // end the generation session; its pages return to the pool
    let (status, _) = roundtrip(addr, "DELETE", "/v1/sessions/2", None).expect("delete");
    assert_eq!(status, 200);
    roundtrip(addr, "DELETE", "/v1/sessions/1", None).expect("delete");
    assert_eq!(server.sessions().lock().unwrap().pool().bytes(), 0, "pages released");
    println!("DELETE /v1/sessions/{{1,2}} -> pool back to 0 B");

    server.metrics.snapshot().print("serve_http");
    println!("serve_http OK");
}
