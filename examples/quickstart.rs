//! Quickstart: the three faces of HAD attention, agreeing with each other.
//!
//! 1. the Rust bit-packed CPU fast path (XNOR + popcount),
//! 2. the dense f32 oracle,
//! 3. the AOT Pallas kernel running under PJRT (fwd_had artifact),
//! plus a speed comparison of binary vs float attention scores.
//!
//! Run: cargo run --release --example quickstart

use anyhow::Result;
use had::binary::{had_attention, had_attention_ref, simd, HadAttnConfig, KernelBackend, PackedKv};
use had::runtime::{default_artifact_dir, Runtime};
use had::tensor::Mat;
use had::util::bench::Bencher;
use had::util::rng::Rng;

fn main() -> Result<()> {
    had::util::log::init_from_env();
    let mut rng = Rng::new(42);

    // --- 1+2: bit-packed fast path vs dense oracle --------------------------
    let (n_q, n_k, d, d_v, n_top) = (64, 1024, 64, 64, 30);
    let q = Mat::random(n_q, d, &mut rng, 1.0);
    let k = Mat::random(n_k, d, &mut rng, 1.0);
    let v = Mat::random(n_k, d_v, &mut rng, 1.0);
    let cfg = HadAttnConfig { n_top, temp: 1.0 };

    let kv = PackedKv::new(&k, &v);
    let fast = had_attention(&q, &kv, &cfg);
    let oracle = had_attention_ref(&q, &k, &v, &cfg);
    println!(
        "bit-packed vs dense-oracle max |Δ| = {:.2e}  (n_k={n_k}, d={d}, N={n_top})",
        fast.max_abs_diff(&oracle)
    );
    assert!(fast.max_abs_diff(&oracle) < 1e-5);

    // packed K is 32x smaller at rest — the long-context residency story
    println!(
        "K cache: {} KiB f32  ->  {} KiB bit-packed ({}x smaller)",
        n_k * d * 4 / 1024,
        kv.keys.bytes() / 1024,
        n_k * d * 4 / kv.keys.bytes()
    );

    // --- speed: binary scores vs float scores -------------------------------
    let b = Bencher::default();
    let s_binary = b.run("XNOR+popcount scores (packed)", || {
        let mut out = vec![0i32; n_q * n_k];
        had::binary::hamming::score_matrix(
            &had::binary::PackedMat::pack(n_q, d, &q.data),
            &kv.keys,
            &mut out,
        );
        out
    });
    let s_float = b.run("f32 dot-product scores (dense)", || q.matmul_nt(&k));
    s_binary.print();
    s_float.print();
    println!(
        "binary-score speedup on CPU: {:.1}x\n",
        s_float.mean_ns() / s_binary.mean_ns()
    );

    // --- kernel backend dispatch --------------------------------------------
    // The blocked engine's popcount inner loop is a runtime-selected
    // backend: scalar (`count_ones`, the oracle), portable SWAR, AVX2
    // (nibble-LUT popcount), AVX-512 VPOPCNTQ, or NEON CNT — whichever
    // the host's CPU offers. Every backend is property-tested
    // bit-identical to the scalar oracle, so the choice only moves
    // throughput, never a single output bit. Override the automatic
    // pick per process with the HAD_KERNEL env var, e.g.:
    //   HAD_KERNEL=scalar cargo run --release --example quickstart
    //   HAD_KERNEL=avx2   cargo bench --bench attention_kernels
    // (unknown or host-unavailable names fail loudly at first dispatch)
    println!(
        "kernel backend: {} | host {} | available: {}\n  (override with HAD_KERNEL=scalar|swar|avx2|avx512|neon|auto)\n",
        KernelBackend::active().name(),
        simd::cpu_features(),
        simd::available_names(),
    );

    // --- 3: the AOT Pallas kernel through PJRT ------------------------------
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` to include the PJRT leg");
        return Ok(());
    }
    let rt = Runtime::new(dir)?;
    let cfg_entry = rt.manifest.config("tinyglue")?;
    let mut prng = Rng::new(7);
    let params = had::model::ParamSet::init(cfg_entry, &mut prng);
    let gen = had::data::tinyglue::GlueGen::new(had::data::tinyglue::GlueTask::Sst2);
    let batch = had::data::token_batch(&gen, &mut prng, cfg_entry.eval_batch, cfg_entry.model.n_ctx);

    let mut inputs = params.tensors.clone();
    inputs.push(batch.x.clone());
    inputs.push(had::runtime::HostTensor::vec_f32(vec![1.0; 2]));
    inputs.push(had::runtime::HostTensor::vec_f32(vec![1.0; 2]));
    inputs.push(had::runtime::HostTensor::scalar_f32(15.0));
    let out = rt.exec("tinyglue__fwd_had", &inputs)?;
    let logits = out[0].as_f32()?;
    println!(
        "PJRT fwd_had (fused Pallas kernel) OK: logits shape [{}x{}], first row {:?}",
        cfg_entry.eval_batch,
        cfg_entry.model.n_classes,
        &logits[..cfg_entry.model.n_classes]
    );
    println!("quickstart OK");
    Ok(())
}
