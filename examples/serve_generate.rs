//! Streamed multi-session generation demo: several sessions generate
//! concurrently through the continuous-batching coordinator, tokens
//! arriving as StreamEvents the moment the scheduler samples them —
//! interleaved across sessions, one decode step per stream per tick.
//!
//! Every greedy stream is oracle-checked token-for-token against the
//! direct single-stream engine loop on identical weights, and a
//! follow-up turn per session shows the generated tokens became real
//! session context (warm resume from the same per-layer KV pages).
//!
//! Run: cargo run --release --example serve_generate -- [--sessions 3] [--new-tokens 24]

use had::coordinator::{BatchPolicy, Bucket, Router, Server};
use had::generate::{generate, GenLimits, GenerateRequest, StreamEvent};
use had::kvcache::KvCacheConfig;
use had::serve::{demo_config, HadBackend, ServeModel};
use had::util::cli::Args;
use had::util::rng::Rng;

fn main() {
    had::util::log::init_from_env();
    let args = Args::parse(std::env::args().skip(1));
    let n_sessions = args.get_usize("sessions", 3);
    let prompt_len = args.get_usize("prompt", 64);
    let n_new = args.get_usize("new-tokens", 24);
    let n_ctx = 512usize;

    let cfg = demo_config("gen_512", n_ctx, 48);
    let vocab = cfg.model.vocab as u64;
    let model = ServeModel::random(&cfg, 0xD0DE).expect("demo model");
    let kv = KvCacheConfig { page_tokens: 32, ..Default::default() };
    // identical probe backend = the direct engine-loop oracle
    let probe = HadBackend::new(model.clone(), &kv);
    let backend = HadBackend::new(model, &kv);
    let router = Router::new(vec![Bucket { config: "gen_512".into(), n_ctx, batch: 8 }]);
    let server = Server::builder(
        backend,
        router,
        BatchPolicy {
            max_wait: std::time::Duration::from_millis(2),
            max_streams: 8,
            ..Default::default()
        },
    )
    .kv(kv)
    .start()
    .expect("server start");
    let limits = GenLimits { max_total_tokens: n_ctx, kv_budget_bytes: kv.byte_budget, ..GenLimits::unbounded() };

    let mut rng = Rng::new(0xABCD);
    let prompts: Vec<Vec<i32>> = (0..n_sessions)
        .map(|_| (0..prompt_len).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    println!(
        "streaming {n_sessions} concurrent greedy sessions (prompt {prompt_len}, +{n_new} tokens each)\n"
    );

    // submit every stream before draining any: all are live at once and
    // the scheduler interleaves their decode steps tick by tick
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(sid, p)| {
            server
                .submit_generate(sid as u64, GenerateRequest::greedy(p.clone(), n_new))
                .expect("stream admitted")
        })
        .collect();

    // round-robin drain to SHOW the interleaving: poll each live stream
    // and print tokens in arrival order
    let mut streams: Vec<Option<_>> = rxs.into_iter().map(Some).collect();
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); n_sessions];
    let mut live = n_sessions;
    while live > 0 {
        for (sid, slot) in streams.iter_mut().enumerate() {
            let Some(rx) = slot else { continue };
            match rx.try_recv() {
                Ok(StreamEvent::Token { index, token }) => {
                    println!("session {sid} token[{index}] = {token}");
                    outputs[sid].push(token);
                }
                Ok(StreamEvent::Done { reason, generated, ttft_us }) => {
                    println!(
                        "session {sid} done: {generated} tokens ({reason}), ttft {:.2} ms",
                        ttft_us as f64 / 1e3
                    );
                    *slot = None;
                    live -= 1;
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => {}
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    panic!("server dropped stream {sid}")
                }
            }
        }
        std::thread::sleep(std::time::Duration::from_micros(200));
    }

    // oracle: every stream token-for-token equals the direct engine loop
    for (sid, prompt) in prompts.iter().enumerate() {
        let mut okv = probe.fresh_kv();
        let want = generate(
            &probe,
            &mut okv,
            &[],
            &GenerateRequest::greedy(prompt.clone(), n_new),
            &limits,
            |_, _| {},
        );
        assert_eq!(
            outputs[sid], want.tokens,
            "session {sid}: coordinator stream must equal the direct engine loop"
        );
    }
    println!("\nall {n_sessions} streams matched the direct engine-loop oracle");

    // follow-up turns: the generated tokens are real session context
    for (sid, prompt) in prompts.iter().enumerate() {
        let append: Vec<i32> = (0..8).map(|_| rng.below(vocab) as i32).collect();
        let mut full = prompt.clone();
        full.extend_from_slice(&outputs[sid]);
        full.extend_from_slice(&append);
        let resp = server.infer_session(sid as u64, append).expect("turn served");
        assert_eq!(
            resp.cached_tokens,
            prompt_len + n_new,
            "session {sid}: prompt AND generated tokens resume warm"
        );
        assert_eq!(
            resp.logits,
            probe.forward_logits(&full),
            "session {sid}: follow-up logits equal the full-sequence forward"
        );
    }
    println!("follow-up turns resumed warm from the generated context");

    let snap = server.metrics.snapshot();
    snap.print("serve_generate");
    let stats = server.cache_stats();
    println!(
        "\ncache hit rate {:.1}% ({} hits / {} misses) | ttft p50 {:.2} ms p99 {:.2} ms | inter-token p50 {:.2} ms p99 {:.2} ms | {:.1} generated tok/s",
        100.0 * stats.hit_rate(),
        stats.hits,
        stats.misses,
        snap.ttft_p50_us as f64 / 1e3,
        snap.ttft_p99_us as f64 / 1e3,
        snap.inter_token_p50_us as f64 / 1e3,
        snap.inter_token_p99_us as f64 / 1e3,
        snap.gen_tokens_per_s,
    );
    assert_eq!(snap.gen_streams as usize, n_sessions);
    assert_eq!(snap.gen_tokens as usize, n_sessions * n_new);
    println!("serve_generate OK");
}
