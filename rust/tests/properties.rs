//! Property-based tests (util::quickcheck substrate) over the paper's
//! core invariants and the coordinator's routing/batching/state logic.
//! These need no artifacts and run everywhere.

use had::binary::topn::{select_topn_counting, select_topn_heap};
use had::binary::{
    had_attention, had_attention_backend, had_attention_paged, had_attention_paged_backend,
    had_attention_paged_pooled, had_attention_paged_pooled_backend, had_attention_paged_scalar,
    had_attention_pooled, had_attention_pooled_backend, had_attention_ref, had_attention_scalar,
    HadAttnConfig, KernelBackend, PackedKv, PackedMat, StreamTopN,
};
use had::coordinator::{BatchPolicy, BucketQueue, Router};
use had::kvcache::{KvCacheConfig, PagePool, SessionKv, ValueDtype};
use had::tensor::Mat;
use had::util::quickcheck::{check, pair, usize_in, Config, Gen};
use had::util::rng::Rng;
use had::util::threadpool::ThreadPool;

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xC0FFEE, max_shrink_steps: 100 }
}

#[test]
fn prop_hamming_identity_all_dims() {
    // sign(q).sign(k) == d - 2*ham for every dimension, including ragged
    let gen = pair(usize_in(1, 200), usize_in(0, 1 << 20));
    check(&cfg(120), &gen, |&(d, seed)| {
        let mut rng = Rng::new(seed as u64);
        let q = rng.normal_vec(d, 1.0);
        let k = rng.normal_vec(d, 1.0);
        let qp = PackedMat::pack(1, d, &q);
        let kp = PackedMat::pack(1, d, &k);
        let fast = had::binary::hamming::binary_dot(qp.row(0), kp.row(0), d);
        let slow: i32 = (0..d)
            .map(|i| {
                let qs = if q[i] >= 0.0 { 1 } else { -1 };
                let ks = if k[i] >= 0.0 { 1 } else { -1 };
                qs * ks
            })
            .sum();
        fast == slow
    });
}

#[test]
fn prop_topn_selection_agrees_across_algorithms() {
    let gen = pair(usize_in(1, 300), pair(usize_in(1, 64), usize_in(0, 1 << 20)));
    check(&cfg(150), &gen, |&(n, (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let scores: Vec<i32> = (0..n)
            .map(|_| rng.below((2 * d + 1) as u64) as i32 - d as i32)
            .collect();
        let n_top = 1 + (seed % n);
        select_topn_heap(&scores, n_top) == select_topn_counting(&scores, n_top, d)
    });
}

#[test]
fn prop_topn_output_invariants() {
    // selected scores are >= every unselected score; indices unique
    let gen = pair(usize_in(2, 200), usize_in(0, 1 << 20));
    check(&cfg(100), &gen, |&(n, seed)| {
        let mut rng = Rng::new(seed as u64);
        let d = 32usize;
        let scores: Vec<i32> = (0..n)
            .map(|_| rng.below((2 * d + 1) as u64) as i32 - d as i32)
            .collect();
        let n_top = 1 + (seed % (n - 1));
        let kept = select_topn_counting(&scores, n_top, d);
        let mut kept_idx: Vec<usize> = kept.iter().map(|&(_, i)| i).collect();
        kept_idx.sort_unstable();
        kept_idx.dedup();
        if kept_idx.len() != kept.len() {
            return false;
        }
        let min_kept = kept.iter().map(|&(s, _)| s).min().unwrap();
        scores
            .iter()
            .enumerate()
            .filter(|(i, _)| !kept_idx.contains(i))
            .all(|(_, &s)| s <= min_kept)
    });
}

#[test]
fn prop_attention_rows_are_convex_weights() {
    // fused attention output stays inside the convex hull of V rows
    let gen = pair(usize_in(4, 64), usize_in(0, 1 << 20));
    check(&cfg(40), &gen, |&(n_k, seed)| {
        let mut rng = Rng::new(seed as u64);
        let (n_q, d, d_v) = (4usize, 32usize, 8usize);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let kv = PackedKv::new(&k, &v);
        let n_top = 1 + seed % n_k;
        let out = had_attention(&q, &kv, &HadAttnConfig { n_top, temp: 1.0 });
        (0..d_v).all(|c| {
            let vmin = (0..n_k).map(|r| v.at(r, c)).fold(f32::INFINITY, f32::min);
            let vmax = (0..n_k).map(|r| v.at(r, c)).fold(f32::NEG_INFINITY, f32::max);
            (0..n_q).all(|r| out.at(r, c) >= vmin - 1e-4 && out.at(r, c) <= vmax + 1e-4)
        })
    });
}

#[test]
fn prop_fused_matches_oracle_randomized() {
    let gen = pair(usize_in(1, 48), pair(usize_in(2, 96), usize_in(0, 1 << 20)));
    check(&cfg(30), &gen, |&(n_q, (n_k, seed))| {
        let mut rng = Rng::new(seed as u64);
        let (d, d_v) = (48usize, 16usize);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let c = HadAttnConfig { n_top: 1 + seed % n_k, temp: 0.8 };
        let kv = PackedKv::new(&k, &v);
        had_attention(&q, &kv, &c).max_abs_diff(&had_attention_ref(&q, &k, &v, &c)) < 1e-4
    });
}

#[test]
fn prop_paged_attention_equals_contiguous_and_oracle() {
    // paged scoring over non-contiguous pages must agree with the
    // contiguous fast path bit-for-bit and with the dense oracle to 1e-5,
    // for random page sizes, ragged (non-multiple-of-64) head dims, and
    // partial final pages — appended in random-sized chunks.
    let gen = pair(
        pair(usize_in(1, 24), usize_in(2, 90)), // (page_tokens, n_k)
        pair(usize_in(1, 130), usize_in(0, 1 << 20)), // (d, seed)
    );
    check(&cfg(40), &gen, |&((page_tokens, n_k), (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let (n_q, d_v) = (3usize, 8usize);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let c = HadAttnConfig { n_top: 1 + seed % n_k, temp: 0.9 };

        let mut paged = SessionKv::new(d, d_v, page_tokens);
        let mut lo = 0usize;
        while lo < n_k {
            let hi = (lo + 1 + rng.range_usize(0, n_k)).min(n_k);
            let rows = hi - lo;
            let kc = Mat::from_vec(rows, d, k.data[lo * d..hi * d].to_vec());
            let vc = Mat::from_vec(rows, d_v, v.data[lo * d_v..hi * d_v].to_vec());
            paged.append(&kc, &vc);
            lo = hi;
        }

        let fast = had_attention(&q, &PackedKv::new(&k, &v), &c);
        let from_pages = had_attention_paged(&q, &paged, &c);
        let oracle = had_attention_ref(&q, &k, &v, &c);
        from_pages == fast && from_pages.max_abs_diff(&oracle) < 1e-5
    });
}

#[test]
fn prop_blocked_kernel_equals_scalar_bit_for_bit() {
    // the tiled engine (4-query blocking + fused streaming top-N) must
    // reproduce the scalar oracle exactly: ragged head dims crossing u64
    // word boundaries, ragged n_q covering partial query blocks, and
    // n_top at both extremes {1, n_k} plus a random interior value
    let gen = pair(
        pair(usize_in(1, 11), usize_in(1, 90)), // (n_q, n_k)
        pair(usize_in(1, 130), usize_in(0, 1 << 20)), // (d, seed)
    );
    check(&cfg(40), &gen, |&((n_q, n_k), (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let d_v = 1 + seed % 9;
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let kv = PackedKv::new(&k, &v);
        [1usize, 1 + seed % n_k, n_k].into_iter().all(|n_top| {
            let c = HadAttnConfig { n_top, temp: 0.9 };
            had_attention(&q, &kv, &c) == had_attention_scalar(&q, &kv, &c)
        })
    });
}

#[test]
fn prop_paged_kernel_equals_scalar_over_straddling_pages() {
    // page sizes that straddle the 4-query tile and the page-major
    // traversal must not change a single bit vs the scalar paged oracle
    // (and the contiguous kernel, closing the square)
    let gen = pair(
        pair(usize_in(1, 24), usize_in(2, 90)), // (page_tokens, n_k)
        pair(usize_in(1, 130), usize_in(0, 1 << 20)), // (d, seed)
    );
    check(&cfg(30), &gen, |&((page_tokens, n_k), (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let (n_q, d_v) = (5usize, 8usize);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let mut paged = SessionKv::new(d, d_v, page_tokens);
        paged.append(&k, &v);
        [1usize, 1 + seed % n_k, n_k].into_iter().all(|n_top| {
            let c = HadAttnConfig { n_top, temp: 1.1 };
            let fast = had_attention_paged(&q, &paged, &c);
            fast == had_attention_paged_scalar(&q, &paged, &c)
                && fast == had_attention(&q, &PackedKv::new(&k, &v), &c)
        })
    });
}

#[test]
fn prop_every_available_backend_equals_scalar_oracle_bit_for_bit() {
    // the backend matrix contract: every popcount backend the host can
    // run (scalar, swar, and whichever of avx2/avx512/neon detection
    // admits) must reproduce the scalar oracle exactly — ragged head
    // dims crossing u64 word boundaries, partial final pages from
    // random-chunk appends, and n_top at both extremes {1, n_k} plus a
    // random interior value, contiguous and paged alike.
    let backends = KernelBackend::available();
    assert!(backends.contains(&KernelBackend::Scalar));
    assert!(backends.contains(&KernelBackend::active()));
    let gen = pair(
        pair(usize_in(1, 24), usize_in(2, 90)), // (page_tokens, n_k)
        pair(usize_in(1, 130), usize_in(0, 1 << 20)), // (d, seed)
    );
    check(&cfg(25), &gen, |&((page_tokens, n_k), (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let (n_q, d_v) = (5usize, 8usize);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let kv = PackedKv::new(&k, &v);
        // partial final page via random-sized appends
        let mut paged = SessionKv::new(d, d_v, page_tokens);
        let mut lo = 0usize;
        while lo < n_k {
            let hi = (lo + 1 + rng.range_usize(0, n_k)).min(n_k);
            let kc = Mat::from_vec(hi - lo, d, k.data[lo * d..hi * d].to_vec());
            let vc = Mat::from_vec(hi - lo, d_v, v.data[lo * d_v..hi * d_v].to_vec());
            paged.append(&kc, &vc);
            lo = hi;
        }
        [1usize, 1 + seed % n_k, n_k].into_iter().all(|n_top| {
            let c = HadAttnConfig { n_top, temp: 0.9 };
            let want = had_attention_scalar(&q, &kv, &c);
            let want_paged = had_attention_paged_scalar(&q, &paged, &c);
            backends.iter().all(|&be| {
                had_attention_backend(&q, &kv, &c, be) == want
                    && had_attention_paged_backend(&q, &paged, &c, be) == want_paged
            })
        })
    });
}

#[test]
fn prop_backend_matrix_survives_threading() {
    // backend dispatch composes with query-block sharding: pooled
    // output equals the scalar-oracle output for every backend and
    // worker count
    let backends = KernelBackend::available();
    let pools: Vec<ThreadPool> = (1..=3).map(ThreadPool::new).collect();
    let gen = pair(
        pair(usize_in(1, 13), usize_in(1, 70)), // (n_q, n_k)
        pair(usize_in(1, 100), usize_in(0, 1 << 20)), // (d, seed)
    );
    check(&cfg(10), &gen, |&((n_q, n_k), (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let d_v = 6usize;
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let kv = PackedKv::new(&k, &v);
        let mut paged = SessionKv::new(d, d_v, 1 + seed % 16);
        paged.append(&k, &v);
        let c = HadAttnConfig { n_top: 1 + seed % n_k, temp: 0.8 };
        let want = had_attention_scalar(&q, &kv, &c);
        backends.iter().all(|&be| {
            pools.iter().all(|pool| {
                had_attention_pooled_backend(&q, &kv, &c, pool, be) == want
                    && had_attention_paged_pooled_backend(&q, &paged, &c, pool, be) == want
            })
        })
    });
}

#[test]
fn prop_threaded_kernel_equals_serial_for_1_to_4_workers() {
    // sharding query blocks across the pool must be invisible in the
    // output at every worker count, contiguous and paged alike
    let pools: Vec<ThreadPool> = (1..=4).map(ThreadPool::new).collect();
    let gen = pair(
        pair(usize_in(1, 13), usize_in(1, 70)), // (n_q, n_k)
        pair(usize_in(1, 100), usize_in(0, 1 << 20)), // (d, seed)
    );
    check(&cfg(20), &gen, |&((n_q, n_k), (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let d_v = 6usize;
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let kv = PackedKv::new(&k, &v);
        let mut paged = SessionKv::new(d, d_v, 1 + seed % 16);
        paged.append(&k, &v);
        let c = HadAttnConfig { n_top: 1 + seed % n_k, temp: 0.8 };
        let serial = had_attention(&q, &kv, &c);
        let serial_paged = had_attention_paged(&q, &paged, &c);
        serial == serial_paged
            && pools.iter().all(|pool| {
                had_attention_pooled(&q, &kv, &c, pool) == serial
                    && had_attention_paged_pooled(&q, &paged, &c, pool) == serial
            })
    });
}

#[test]
fn prop_streaming_topn_equals_counting_selection() {
    // the kernel's inline threshold selection must equal the two-pass
    // counting oracle on the materialized row, including tie handling
    let gen = pair(usize_in(1, 300), pair(usize_in(1, 64), usize_in(0, 1 << 20)));
    check(&cfg(100), &gen, |&(n, (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let scores: Vec<i32> = (0..n)
            .map(|_| rng.below((2 * d + 1) as u64) as i32 - d as i32)
            .collect();
        [1usize, 1 + seed % n, n].into_iter().all(|n_top| {
            let mut st = StreamTopN::new();
            st.reset(n_top, d);
            for (i, &s) in scores.iter().enumerate() {
                st.push(s, i);
            }
            st.finish() == select_topn_counting(&scores, n_top, d).as_slice()
        })
    });
}

#[test]
fn prop_bf16_values_keep_selection_and_bound_accumulation_error() {
    // bf16 value storage touches ONLY the AV accumulation: keys (and so
    // scores, selection, and softmax weights) are bit-identical to the
    // f32-valued cache, and the output error is bounded by the worst
    // value-rounding error — |round_bf16(v) - v| <= |v| * 2^-8 — since
    // attention rows are convex combinations of value rows.
    let gen = pair(
        pair(usize_in(1, 20), usize_in(2, 60)), // (page_tokens, n_k)
        pair(usize_in(1, 100), usize_in(0, 1 << 20)), // (d, seed)
    );
    check(&cfg(40), &gen, |&((page_tokens, n_k), (d, seed))| {
        let mut rng = Rng::new(seed as u64);
        let (n_q, d_v) = (3usize, 8usize);
        let q = Mat::random(n_q, d, &mut rng, 1.0);
        let k = Mat::random(n_k, d, &mut rng, 1.0);
        let v = Mat::random(n_k, d_v, &mut rng, 1.0);
        let c = HadAttnConfig { n_top: 1 + seed % n_k, temp: 0.9 };
        let mut f32_kv = SessionKv::new(d, d_v, page_tokens);
        f32_kv.append(&k, &v);
        let mut bf_kv = SessionKv::new_with(d, d_v, page_tokens, ValueDtype::Bf16);
        bf_kv.append(&k, &v);
        // kernel == scalar bit for bit, on bf16 pages too
        let bf_out = had_attention_paged(&q, &bf_kv, &c);
        if bf_out != had_attention_paged_scalar(&q, &bf_kv, &c) {
            return false;
        }
        let f32_out = had_attention_paged(&q, &f32_kv, &c);
        let max_abs_v = v.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let bound = max_abs_v / 256.0 + 1e-5;
        f32_out.max_abs_diff(&bf_out) <= bound
    });
}

#[test]
fn prop_serve_chunked_decode_equals_one_shot() {
    // the serving backend's incremental session decode must be invisible
    // in the output: any split of a sequence into turns produces the
    // same final logits, bit for bit, as decoding it in one pass — the
    // causality property the whole suffix-only serving path rests on.
    use had::kvcache::KvCacheConfig;
    use had::runtime::ModelCfg;
    use had::serve::{token_config_entry, HadBackend, ServeModel};
    let cfg = token_config_entry(
        "prop_serve",
        ModelCfg {
            n_layers: 2, d_model: 32, n_heads: 2, d_ff: 48, n_ctx: 32,
            n_classes: 3, vocab: 24, input_dim: 0, n_top: 6, block_q: 16,
        },
    );
    let model = ServeModel::random(&cfg, 0xD1CE).unwrap();
    let backend = HadBackend::new(
        model,
        &KvCacheConfig { page_tokens: 4, ..Default::default() },
    );
    let gen = pair(usize_in(2, 24), usize_in(0, 1 << 20));
    check(&cfg_cases(10), &gen, |&(n, seed)| {
        let mut rng = Rng::new(seed as u64);
        let tokens: Vec<i32> = (0..n).map(|_| rng.below(24) as i32).collect();
        let mut kv_once = backend.fresh_kv();
        let (want, _) = backend.decode(&mut kv_once, &tokens, &[n]);
        // random turn boundaries
        let mut kv = backend.fresh_kv();
        let mut lo = 0usize;
        let mut got = None;
        while lo < n {
            let hi = (lo + 1 + rng.range_usize(0, n)).min(n);
            let (caps, stats) = backend.decode(&mut kv, &tokens[..hi], &[hi]);
            if lo > 0 && stats.resumed_at != lo {
                return false; // warm turns must resume, not re-execute
            }
            got = Some(caps.into_iter().next().unwrap());
            lo = hi;
        }
        got.unwrap().logits == want[0].logits
    });
}

/// Smaller-case config for the expensive decode property.
fn cfg_cases(cases: usize) -> Config {
    Config { cases, seed: 0xC0FFEE, max_shrink_steps: 20 }
}

/// Shared tiny backend for the generation properties.
fn gen_backend() -> had::serve::HadBackend {
    use had::kvcache::KvCacheConfig;
    use had::runtime::ModelCfg;
    use had::serve::{token_config_entry, HadBackend, ServeModel};
    let cfg = token_config_entry(
        "prop_gen",
        ModelCfg {
            n_layers: 2, d_model: 32, n_heads: 2, d_ff: 48, n_ctx: 48,
            n_classes: 4, vocab: 24, input_dim: 0, n_top: 6, block_q: 16,
        },
    );
    let model = ServeModel::random(&cfg, 0x6E4).unwrap();
    HadBackend::new(model, &KvCacheConfig { page_tokens: 4, ..Default::default() })
}

#[test]
fn prop_greedy_generation_is_repeated_argmax_over_decode() {
    // acceptance property (a): greedy generation == the raw decode +
    // argmax token feedback loop, bit for bit, for any prompt
    use had::generate::{generate, GenLimits, GenerateRequest};
    use had::tensor::ops::argmax;
    let backend = gen_backend();
    let gen = pair(usize_in(1, 20), usize_in(0, 1 << 20));
    check(&cfg_cases(8), &gen, |&(n, seed)| {
        let mut rng = Rng::new(seed as u64);
        let prompt: Vec<i32> = (0..n).map(|_| rng.below(24) as i32).collect();
        let n_new = 1 + rng.range_usize(0, 6);
        let mut kv = backend.fresh_kv();
        let out = generate(
            &backend,
            &mut kv,
            &[],
            &GenerateRequest::greedy(prompt.clone(), n_new),
            &GenLimits::unbounded(),
            |_, _| {},
        );
        if out.tokens.len() != n_new {
            return false;
        }
        // oracle: argmax over raw decode logits, token by token
        let mut seq = prompt;
        let mut okv = backend.fresh_kv();
        for &got in &out.tokens {
            let (caps, _) = backend.decode(&mut okv, &seq, &[seq.len()]);
            let want = argmax(&caps.last().unwrap().logits) as i32;
            if got != want {
                return false;
            }
            seq.push(want);
        }
        true
    });
}

#[test]
fn prop_same_seed_and_params_reproduce_the_stream() {
    // acceptance property (b): a (seed, sampling params, prompt) triple
    // fully determines the token stream
    use had::generate::{generate, GenLimits, GenerateRequest, SamplingParams};
    let backend = gen_backend();
    let gen = pair(usize_in(1, 16), usize_in(0, 1 << 20));
    check(&cfg_cases(8), &gen, |&(n, seed)| {
        let mut rng = Rng::new(seed as u64);
        let prompt: Vec<i32> = (0..n).map(|_| rng.below(24) as i32).collect();
        let req = GenerateRequest {
            prompt,
            max_new_tokens: 1 + rng.range_usize(0, 6),
            stop_tokens: vec![rng.below(4) as i32],
            sampling: SamplingParams {
                temperature: 0.25 + rng.next_f32() * 1.5,
                top_k: rng.range_usize(0, 4),
                top_p: 0.5 + 0.5 * rng.next_f32(),
                seed: seed as u64,
            },
        };
        let run = || {
            let mut kv = backend.fresh_kv();
            generate(&backend, &mut kv, &[], &req, &GenLimits::unbounded(), |_, _| {})
        };
        let (a, b) = (run(), run());
        a.tokens == b.tokens && a.reason == b.reason
    });
}

#[test]
fn prop_coordinator_stream_equals_direct_engine_loop() {
    // acceptance property (c): a stream generated through the
    // continuous-batching coordinator equals the direct single-stream
    // engine loop token for token — including when several sessions'
    // streams are live and interleaved tick by tick.
    use had::coordinator::{Bucket, Server};
    use had::generate::{generate, GenLimits, GenerateRequest, SamplingParams, StreamEvent};
    use had::kvcache::KvCacheConfig;
    let backend = gen_backend();
    let kv_cfg = KvCacheConfig { page_tokens: 4, ..Default::default() };
    let gen = usize_in(0, 1 << 20);
    check(&cfg_cases(4), &gen, |&seed| {
        let mut rng = Rng::new(seed as u64);
        let n_streams = 2 + rng.range_usize(0, 2);
        let reqs: Vec<GenerateRequest> = (0..n_streams)
            .map(|_| {
                let n = 1 + rng.range_usize(0, 10);
                GenerateRequest {
                    prompt: (0..n).map(|_| rng.below(24) as i32).collect(),
                    max_new_tokens: 1 + rng.range_usize(0, 5),
                    stop_tokens: vec![rng.below(4) as i32],
                    sampling: SamplingParams {
                        temperature: if rng.chance(0.5) { 0.0 } else { 0.9 },
                        top_k: 0,
                        top_p: 1.0,
                        seed: rng.next_u64(),
                    },
                }
            })
            .collect();
        let server = Server::builder(
            gen_backend(),
            Router::new(vec![Bucket { config: "prop_gen".into(), n_ctx: 48, batch: 4 }]),
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                max_streams: 4,
                ..Default::default()
            },
        )
        .kv(kv_cfg)
        .start()
        .expect("server start");
        // submit every stream before draining any: they interleave
        let rxs: Vec<_> = reqs
            .iter()
            .enumerate()
            .map(|(sid, req)| server.submit_generate(sid as u64, req.clone()).expect("admitted"))
            .collect();
        let limits = GenLimits { max_total_tokens: 48, kv_budget_bytes: kv_cfg.byte_budget, ..GenLimits::unbounded() };
        for (sid, rx) in rxs.into_iter().enumerate() {
            let mut tokens = Vec::new();
            let mut reason = None;
            for event in rx.iter() {
                match event {
                    StreamEvent::Token { token, .. } => tokens.push(token),
                    StreamEvent::Done { reason: r, .. } => {
                        reason = Some(r);
                        break;
                    }
                }
            }
            let mut okv = backend.fresh_kv();
            let want = generate(&backend, &mut okv, &[], &reqs[sid], &limits, |_, _| {});
            if tokens != want.tokens || reason != Some(want.reason) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_faulted_streams_retire_explicitly_and_leak_nothing() {
    // robustness property: under a seeded fault schedule (worker panics,
    // client disconnects, decode delays, pool-pressure spikes, queue
    // stalls) every admitted stream still retires with an explicit
    // StopReason, its emitted tokens are a PREFIX of the fault-free
    // direct-engine stream (exactly equal when it retires MaxTokens —
    // faults truncate a stream, they never corrupt it), and the page
    // pool returns to zero bytes once every session ends.
    use had::coordinator::{Bucket, Server};
    use had::generate::{generate, GenLimits, GenerateRequest, StopReason, StreamEvent};
    use had::util::fault::FaultPlan;
    let backend = gen_backend();
    let kv_cfg = KvCacheConfig { page_tokens: 4, ..Default::default() };
    for seed in [3u64, 17, 29, 42] {
        let spec = format!(
            "decode_step:0.25:1,worker_panic:0.1,client_disconnect:0.15,\
             pool_pressure:0.1,queue_stall:0.1:1,seed={seed}"
        );
        let server = Server::builder(
            gen_backend(),
            Router::new(vec![Bucket { config: "prop_gen".into(), n_ctx: 48, batch: 4 }]),
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                max_streams: 3,
                ..Default::default()
            },
        )
        .kv(kv_cfg)
        .chaos(FaultPlan::parse(&spec).expect("fault spec"))
        .start()
        .expect("server start");
        let mut rng = Rng::new(seed);
        let reqs: Vec<GenerateRequest> = (0..4)
            .map(|_| {
                let n = 1 + rng.range_usize(0, 12);
                let prompt: Vec<i32> = (0..n).map(|_| rng.below(24) as i32).collect();
                GenerateRequest::greedy(prompt, 1 + rng.range_usize(0, 5))
            })
            .collect();
        let rxs: Vec<_> = reqs
            .iter()
            .enumerate()
            .map(|(sid, req)| server.submit_generate(sid as u64, req.clone()).expect("admitted"))
            .collect();
        let limits = GenLimits {
            max_total_tokens: 48,
            kv_budget_bytes: kv_cfg.byte_budget,
            ..GenLimits::unbounded()
        };
        for (sid, rx) in rxs.into_iter().enumerate() {
            let mut tokens = Vec::new();
            let mut reason = None;
            for event in rx.iter() {
                match event {
                    StreamEvent::Token { token, .. } => tokens.push(token),
                    StreamEvent::Done { reason: r, .. } => {
                        reason = Some(r);
                        break;
                    }
                }
            }
            let reason =
                reason.expect("every admitted stream must close with an explicit StopReason");
            let mut okv = backend.fresh_kv();
            let want = generate(&backend, &mut okv, &[], &reqs[sid], &limits, |_, _| {});
            assert!(
                tokens.len() <= want.tokens.len()
                    && tokens[..] == want.tokens[..tokens.len()],
                "seed {seed} stream {sid}: a faulted stream must emit a prefix of the \
                 fault-free stream, got {tokens:?} want prefix of {:?}",
                want.tokens
            );
            if reason == StopReason::MaxTokens {
                assert_eq!(
                    tokens, want.tokens,
                    "seed {seed} stream {sid}: an unfaulted stream must be token-identical"
                );
            }
        }
        assert_eq!(
            server.metrics.snapshot().gen_streams,
            4,
            "seed {seed}: a stream vanished without retiring"
        );
        let store = server.sessions();
        let mut store = store.lock().unwrap();
        for sid in 0..4u64 {
            store.end_session(sid);
        }
        assert_eq!(store.pool().bytes(), 0, "seed {seed}: leaked pool bytes");
    }
}

#[test]
fn prop_pool_respects_byte_budget_and_accounting() {
    // After any admission sequence: pool bytes equal the sum of resident
    // session bytes, and the budget holds whenever more than the single
    // protected session is resident. hits+misses equals admissions.
    let gen = pair(usize_in(1, 40), pair(usize_in(1, 6), usize_in(0, 1 << 20)));
    check(&cfg(40), &gen, |&(n_ops, (budget_pages, seed))| {
        let mut rng = Rng::new(seed as u64);
        let (d, d_v, page_tokens) = (32usize, 8usize, 4usize);
        let page_bytes = page_tokens * (8 + d_v * 4);
        let mut pool: PagePool = PagePool::new(KvCacheConfig {
            page_tokens,
            byte_budget: budget_pages * page_bytes,
            ..Default::default()
        });
        let mut last_id = 0u64;
        for _ in 0..n_ops {
            let id = rng.range_usize(0, 5) as u64;
            let rows = rng.range_usize(1, 2 * page_tokens + 1);
            let k = Mat::random(rows, d, &mut rng, 1.0);
            let v = Mat::random(rows, d_v, &mut rng, 1.0);
            pool.append(id, &k, &v);
            last_id = id;
        }
        let resident: usize = (0..5u64)
            .filter_map(|id| pool.peek(id).map(|kv| kv.bytes()))
            .sum();
        let stats = pool.stats();
        let budget_ok = pool.bytes() <= pool.budget()
            || (pool.len() == 1 && pool.peek(last_id).is_some());
        resident == pool.bytes()
            && budget_ok
            && stats.hits + stats.misses == n_ops as u64
    });
}

#[test]
fn prop_router_minimality_and_totality() {
    let router = Router::longqa_default();
    check(&cfg(200), &usize_in(1, 2048), |&len| {
        match router.route(len) {
            Ok(b) => {
                b.n_ctx >= len
                    && router
                        .buckets()
                        .iter()
                        .all(|c| c.n_ctx < len || c.n_ctx >= b.n_ctx)
            }
            Err(_) => len > router.max_ctx(),
        }
    });
}

#[test]
fn prop_batcher_never_exceeds_capacity_or_loses_requests() {
    use std::sync::mpsc::channel;
    use std::time::Instant;
    let gen = pair(usize_in(1, 64), usize_in(1, 32));
    check(&cfg(80), &gen, |&(n_reqs, cap)| {
        let bucket = had::coordinator::Bucket {
            config: "longqa_128".into(),
            n_ctx: 128,
            batch: 8,
        };
        let mut q = BucketQueue::new(
            bucket,
            BatchPolicy { queue_cap: cap, ..Default::default() },
        );
        let mut admitted = 0usize;
        let mut rejected = 0usize;
        for i in 0..n_reqs {
            let (tx, _rx) = channel();
            let req = had::coordinator::Request {
                id: i as u64,
                tokens: vec![1; 64],
                arrival: Instant::now(),
                reply: tx,
                session: None,
                trace: had::obs::SpanId::NONE,
            };
            if q.len() >= cap {
                // must reject at capacity
                if q.push(req).is_ok() {
                    return false;
                }
                rejected += 1;
            } else if q.push(req).is_ok() {
                admitted += 1;
            } else {
                return false; // rejected below capacity
            }
        }
        // drain everything back out, in FIFO batches of <= bucket.batch
        let mut drained = 0usize;
        let mut last_id = None::<u64>;
        while !q.is_empty() {
            let batch = q.drain_batch();
            if batch.is_empty() || batch.len() > 8 {
                return false;
            }
            for r in &batch {
                if let Some(prev) = last_id {
                    if r.id <= prev {
                        return false; // FIFO violated
                    }
                }
                last_id = Some(r.id);
            }
            drained += batch.len();
        }
        admitted == drained && admitted + rejected == n_reqs
    });
}

#[test]
fn prop_packed_bytes_32x_reduction() {
    check(&cfg(60), &pair(usize_in(1, 128), usize_in(32, 256)), |&(rows, d)| {
        let mut rng = Rng::new((rows * 1000 + d) as u64);
        let xs = rng.normal_vec(rows * d, 1.0);
        let p = PackedMat::pack(rows, d, &xs);
        // packed size is within one word/row of f32/32
        p.bytes() <= rows * (d.div_ceil(64)) * 8 && p.bytes() * 8 >= rows * d / 8
    });
}

#[test]
fn prop_schedule_c_monotone_nonincreasing() {
    use had::distill::{Budget, Schedule};
    let gen = pair(usize_in(2, 500), usize_in(2, 500));
    check(&cfg(50), &gen, |&(s1, s2)| {
        let s = Schedule::new(
            Budget { teacher: 0, stage1: s1, stage2: s2, stage3: 10, stage4: 10 },
            1e-4,
        );
        let total = s.budget.total_distill();
        let mut prev = f32::INFINITY;
        for step in 0..total {
            let c = s.c_at(step);
            if c > prev + 1e-5 || !(0.0..=5.0 + 1e-6).contains(&c) {
                return false;
            }
            prev = c;
        }
        true
    });
}
