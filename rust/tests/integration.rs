//! Integration tests across runtime + coordinator + distill, executing
//! the real PJRT artifacts (skipped gracefully when `make artifacts` has
//! not been run). Kept deliberately small: each test does a few steps,
//! not a full training run (the experiment suite covers that).

use had::data::longqa::{longqa_batch, LongQaGen};
use had::data::tinyglue::{GlueGen, GlueTask};
use had::data::token_batch;
use had::distill::{Budget, Method, Pipeline, Schedule};
use had::model::ParamSet;
use had::runtime::{HostTensor, Runtime};
use had::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(artifacts_dir()).unwrap())
}

fn tiny_schedule() -> Schedule {
    Schedule::new(
        Budget { teacher: 3, stage1: 2, stage2: 2, stage3: 2, stage4: 2 },
        1e-4,
    )
}

#[test]
fn teacher_step_reduces_loss_on_constant_batch() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.config("tinyglue").unwrap();
    let exe = rt.load("tinyglue__teacher_step").unwrap();
    let mut rng = Rng::new(1);
    let mut state = had::model::TrainState::new(cfg, &mut rng);
    let gen = GlueGen::new(GlueTask::Sst2);
    let batch = token_batch(&gen, &mut rng, cfg.train_batch, cfg.model.n_ctx);
    let mut losses = Vec::new();
    for _ in 0..8 {
        let mut inputs = state.to_inputs();
        inputs.push(batch.x.clone());
        inputs.push(batch.y.clone());
        inputs.push(HostTensor::scalar_f32(5e-3));
        let out = exe.run(&inputs).unwrap();
        let (next, aux) = had::model::TrainState::from_outputs(cfg, out).unwrap();
        state = next;
        losses.push(aux[0].scalar().unwrap());
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "overfitting one batch must reduce loss: {losses:?}"
    );
    assert_eq!(state.t, 8.0, "step counter advances");
}

#[test]
fn full_pipeline_smoke_all_methods() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.config("tinyglue").unwrap();
    let pipeline = Pipeline::new(&rt, cfg, tiny_schedule());
    let mut rng = Rng::new(2);
    let gen = GlueGen::new(GlueTask::Qnli);
    let mut batches =
        |rng: &mut Rng| token_batch(&gen, rng, cfg.train_batch, cfg.model.n_ctx);
    let (teacher, _) = pipeline.train_teacher(&mut rng, &mut batches).unwrap();
    let (sq, sk) = pipeline
        .calibrate_sigma(&teacher, &mut rng, &mut batches, 2)
        .unwrap();
    assert!(sq.iter().all(|&x| x > 0.0) && sk.iter().all(|&x| x > 0.0));
    for method in [Method::Had, Method::Bit, Method::Sab, Method::HadNoTanh] {
        let outcome = pipeline
            .distill(method, &teacher, &sq, &sk, 15.0, &mut rng, &mut batches)
            .unwrap();
        assert_eq!(outcome.loss_trace.len(), tiny_schedule().budget.total_distill());
        // student params must have moved off the teacher
        assert!(
            outcome.student.params.l2_distance(&teacher) > 0.0,
            "{method:?} student unchanged"
        );
        // losses finite
        assert!(outcome
            .loss_trace
            .iter()
            .all(|(_, a, o)| a.is_finite() && o.is_finite()));
    }
}

#[test]
fn fwd_standard_and_fwd_had_consistent_shapes() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.config("tinyglue").unwrap();
    let mut rng = Rng::new(3);
    let params = ParamSet::init(cfg, &mut rng);
    let gen = GlueGen::new(GlueTask::Qqp);
    let batch = token_batch(&gen, &mut rng, cfg.eval_batch, cfg.model.n_ctx);
    for artifact in ["fwd_standard", "fwd_had", "fwd_bit", "fwd_sab"] {
        let mut inputs = params.tensors.clone();
        inputs.push(batch.x.clone());
        inputs.push(HostTensor::vec_f32(vec![1.0; cfg.model.n_layers]));
        inputs.push(HostTensor::vec_f32(vec![1.0; cfg.model.n_layers]));
        inputs.push(HostTensor::scalar_f32(15.0));
        let out = rt
            .exec(&format!("tinyglue__{artifact}"), &inputs)
            .unwrap_or_else(|e| panic!("{artifact}: {e:#}"));
        assert_eq!(out[0].shape(), &[cfg.eval_batch, cfg.model.n_classes]);
        assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
    }
}

#[test]
fn pallas_fwd_matches_jnp_binary_semantics() {
    // fwd_had (fused Pallas kernel) and fwd_standard share params; with
    // identical Q/K signs and N = n_ctx the binarized model is a
    // deterministic function — this asserts it runs and differs from the
    // fp32 model (binarization must actually change the computation).
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.config("tinyglue").unwrap();
    let mut rng = Rng::new(4);
    let params = ParamSet::init(cfg, &mut rng);
    let gen = GlueGen::new(GlueTask::Mnli);
    let batch = token_batch(&gen, &mut rng, cfg.eval_batch, cfg.model.n_ctx);
    let mut inputs = params.tensors.clone();
    inputs.push(batch.x.clone());
    inputs.push(HostTensor::vec_f32(vec![1.0; cfg.model.n_layers]));
    inputs.push(HostTensor::vec_f32(vec![1.0; cfg.model.n_layers]));
    inputs.push(HostTensor::scalar_f32(cfg.model.n_ctx as f32));
    let had_out = rt.exec("tinyglue__fwd_had", &inputs).unwrap();
    let std_out = rt.exec("tinyglue__fwd_standard", &inputs).unwrap();
    let a = had_out[0].as_f32().unwrap();
    let b = std_out[0].as_f32().unwrap();
    let max_diff = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff > 1e-4, "binarization changed nothing? diff={max_diff}");
    // determinism of the fused kernel
    let had_out2 = rt.exec("tinyglue__fwd_had", &inputs).unwrap();
    assert_eq!(had_out[0], had_out2[0]);
}

#[test]
fn serving_end_to_end_one_bucket() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use had::coordinator::{BatchPolicy, Bucket, Router, Server, ServingModel};
    let engine = had::runtime::Engine::start(artifacts_dir()).unwrap();
    let manifest = had::runtime::Manifest::load(artifacts_dir()).unwrap();
    let router = Router::new(vec![Bucket {
        config: "longqa_128".into(),
        n_ctx: 128,
        batch: manifest.config("longqa_128").unwrap().eval_batch,
    }]);
    let models =
        vec![ServingModel::random(&manifest, "longqa_128", 1, "fwd_had").unwrap()];
    let server = Server::start(
        engine.handle(),
        router,
        models,
        BatchPolicy { max_wait: std::time::Duration::from_millis(1), ..Default::default() },
    )
    .unwrap();
    let gen = LongQaGen::new(128);
    let mut rng = Rng::new(5);
    let b = longqa_batch(&gen, &mut rng, 3);
    let xs = b.x.as_i32().unwrap();
    let mut replies = Vec::new();
    for i in 0..3 {
        let tokens = xs[i * 128..(i + 1) * 128].to_vec();
        replies.push(server.submit(tokens).unwrap());
    }
    for rx in replies {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.bucket, "longqa_128");
        assert!((0..4).contains(&resp.pred));
        assert_eq!(resp.cached_tokens, 0, "sessionless requests hit no cache");
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 3);

    // session path: two turns through the same pipeline; the second turn
    // reuses the first turn's resident pages and reports it
    let turn1 = server.infer_session(7, vec![3; 40]).unwrap();
    assert_eq!(turn1.bucket, "longqa_128");
    assert_eq!(turn1.cached_tokens, 0, "first turn is cold");
    let turn2 = server.infer_session(7, vec![4; 30]).unwrap();
    assert_eq!(turn2.cached_tokens, 40, "second turn reuses the prefix");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.session_requests, 2);
    assert_eq!(snap.cache_hit_tokens, 40);
    assert_eq!(snap.cache_miss_tokens, 70);
    // (the page pool only fills on the CPU decode path — see the
    // serving_cpu_backend test — so no pool assertions here)

    // too-long requests are rejected up front (both paths)
    assert!(server.submit(vec![0; 4096]).is_err());
    assert!(server.submit_session(8, vec![0; 4096]).is_err());
}

/// End-to-end serving on the CPU backend: needs NO artifacts, so this
/// runs everywhere. `Response.logits` must be the backend's real logits
/// (checked bit-for-bit against a direct forward of the same weights),
/// and a session's second turn must resume from resident per-layer pages
/// rather than re-executing the full sequence.
#[test]
fn serving_cpu_backend_end_to_end() {
    use had::coordinator::{BatchPolicy, Bucket, Router, Server};
    use had::kvcache::KvCacheConfig;
    use had::runtime::ModelCfg;
    use had::serve::{token_config_entry, HadBackend, ServeModel};

    let cfg = token_config_entry(
        "cpu_64",
        ModelCfg {
            n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 64,
            n_classes: 4, vocab: 32, input_dim: 0, n_top: 8, block_q: 16,
        },
    );
    // the served model goes through checkpoint IO: distilled weights +
    // calibrated sigmas on disk are what production serving loads
    let ckpt = had::model::Checkpoint {
        config: "cpu_64".into(),
        step: 100.0,
        sigma_q: vec![0.8, 1.1],
        sigma_k: vec![1.2, 0.9],
        params: ParamSet::init(&cfg, &mut Rng::new(42)),
    };
    let ckpt_path = std::env::temp_dir().join("had_serve_e2e.ckpt");
    had::model::save_checkpoint(&ckpt_path, &cfg, &ckpt).unwrap();
    let loaded = had::model::load_checkpoint(&ckpt_path, &cfg).unwrap();
    std::fs::remove_file(&ckpt_path).ok();
    let model = ServeModel::from_checkpoint(&cfg, &loaded).unwrap();
    assert_eq!(model.sigma_q, vec![0.8, 1.1], "calibrated sigmas flow into serving");
    let kv = KvCacheConfig { page_tokens: 8, ..Default::default() };
    // an identical probe backend acts as the logits oracle
    let probe = HadBackend::new(model.clone(), &kv);
    let backend = HadBackend::new(model, &kv);
    let router = Router::new(vec![Bucket { config: "cpu_64".into(), n_ctx: 64, batch: 4 }]);
    let server = Server::builder(
        backend,
        router,
        BatchPolicy { max_wait: std::time::Duration::from_millis(1), ..Default::default() },
    )
    .kv(kv)
    .start()
    .unwrap();

    let mut rng = Rng::new(5);
    let toks = |rng: &mut Rng, n: usize| -> Vec<i32> {
        (0..n).map(|_| rng.below(32) as i32).collect()
    };

    // sessionless: served logits == a direct backend forward, bit for bit
    let plain = toks(&mut rng, 20);
    let resp = server.infer(plain.clone()).unwrap();
    assert_eq!(resp.logits, probe.forward_logits(&plain));
    assert_eq!(resp.pred as usize, {
        let l = probe.forward_logits(&plain);
        let mut best = 0;
        for i in 1..l.len() {
            if l[i] > l[best] {
                best = i;
            }
        }
        best
    });
    assert_eq!(resp.cached_tokens, 0, "sessionless requests hit no cache");
    assert!(resp.kernel_us <= resp.decode_us, "kernel time is a share of decode time");

    // session path: turn 2 extends turn 1's context and must (a) serve
    // logits equal to the full-sequence forward and (b) resume from the
    // resident pages (pool hit) instead of re-executing turn 1
    let t1 = toks(&mut rng, 24);
    let turn1 = server.infer_session(7, t1.clone()).unwrap();
    assert_eq!(turn1.cached_tokens, 0, "first turn is cold");
    assert_eq!(turn1.logits, probe.forward_logits(&t1));
    let t2 = toks(&mut rng, 10);
    let mut full = t1.clone();
    full.extend_from_slice(&t2);
    let turn2 = server.infer_session(7, t2).unwrap();
    assert_eq!(turn2.cached_tokens, 24, "second turn reuses the prefix");
    assert_eq!(turn2.logits, probe.forward_logits(&full));
    let stats = server.cache_stats();
    assert_eq!(stats.hits, 1, "turn 2 resumed from resident per-layer pages");
    assert_eq!(stats.misses, 1, "turn 1 started cold");

    let snap = server.metrics.snapshot();
    assert_eq!(snap.session_requests, 2);
    assert_eq!(snap.cache_hit_tokens, 24);
    assert_eq!(snap.cache_miss_tokens, 34);
    assert_eq!(snap.requests, 3);
    assert_eq!(snap.decode_requests, 3, "every request was backend-decoded");
    assert!(snap.cache_bytes > 0, "per-layer pages resident after decode");

    // a session whose accumulated context outgrows every bucket restarts
    // its context with the new turn (fresh-context semantics, like an
    // eviction) instead of wedging the session id in permanent rejection
    let t3 = toks(&mut rng, 40); // 34 resident + 40 > 64 = max bucket
    let turn3 = server.infer_session(7, t3.clone()).unwrap();
    assert_eq!(turn3.cached_tokens, 0, "overflow restarts the context");
    assert_eq!(turn3.logits, probe.forward_logits(&t3));
    let turn4 = server.infer_session(7, vec![1, 2]).unwrap();
    assert_eq!(turn4.cached_tokens, 40, "the restarted context continues normally");

    // too-long requests are rejected up front (both paths)
    assert!(server.submit(vec![0; 4096]).is_err());
    assert!(server.submit_session(8, vec![0; 4096]).is_err());
}

/// Streamed generation end to end through the continuous-batching
/// coordinator: tokens arrive as StreamEvents, greedy output matches the
/// direct engine loop, stop tokens retire the stream, generated context
/// is reusable by classification turns, and batch traffic keeps flowing
/// while streams are live.
#[test]
fn serving_generation_end_to_end() {
    use had::coordinator::{BatchPolicy, Bucket, Router, Server};
    use had::generate::{
        generate, GenLimits, GenerateRequest, SamplingParams, StopReason, StreamEvent,
    };
    use had::kvcache::KvCacheConfig;
    use had::runtime::ModelCfg;
    use had::serve::{token_config_entry, HadBackend, ServeModel};

    let cfg = token_config_entry(
        "gen_64",
        ModelCfg {
            n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 64,
            n_classes: 4, vocab: 32, input_dim: 0, n_top: 8, block_q: 16,
        },
    );
    let model = ServeModel::random(&cfg, 0xF00D).unwrap();
    let kv = KvCacheConfig { page_tokens: 8, ..Default::default() };
    let probe = HadBackend::new(model.clone(), &kv);
    let backend = HadBackend::new(model, &kv);
    let router = Router::new(vec![Bucket { config: "gen_64".into(), n_ctx: 64, batch: 4 }]);
    let server = Server::builder(
        backend,
        router,
        BatchPolicy {
            max_wait: std::time::Duration::from_millis(1),
            max_streams: 4,
            ..Default::default()
        },
    )
    .kv(kv)
    .start()
    .unwrap();
    let limits = GenLimits { max_total_tokens: 64, kv_budget_bytes: kv.byte_budget, ..GenLimits::unbounded() };

    let mut rng = Rng::new(9);
    let toks = |rng: &mut Rng, n: usize| -> Vec<i32> {
        (0..n).map(|_| rng.below(32) as i32).collect()
    };

    // two live streams + a classification request in the same window
    let p1 = toks(&mut rng, 12);
    let p2 = toks(&mut rng, 7);
    let rx1 = server
        .submit_generate(1, GenerateRequest::greedy(p1.clone(), 6))
        .unwrap();
    let rx2 = server
        .submit_generate(
            2,
            GenerateRequest {
                prompt: p2.clone(),
                max_new_tokens: 10,
                stop_tokens: vec![0, 1, 2, 3], // any class id stops after one token
                sampling: SamplingParams { temperature: 0.6, top_k: 0, top_p: 0.95, seed: 77 },
            },
        )
        .unwrap();
    let plain = toks(&mut rng, 15);
    let plain_resp = server.infer(plain.clone()).unwrap();
    assert_eq!(plain_resp.logits, probe.forward_logits(&plain), "batch traffic coexists");

    let drain = |rx: std::sync::mpsc::Receiver<StreamEvent>| {
        let mut tokens = Vec::new();
        for event in rx.iter() {
            match event {
                StreamEvent::Token { index, token } => {
                    assert_eq!(index, tokens.len(), "in-order streaming");
                    tokens.push(token);
                }
                StreamEvent::Done { reason, generated, ttft_us } => {
                    assert_eq!(generated, tokens.len());
                    return (tokens, reason, ttft_us);
                }
            }
        }
        panic!("stream ended without Done");
    };
    let (t1, r1, ttft1) = drain(rx1);
    let (t2, r2, _) = drain(rx2);
    assert_eq!(r1, StopReason::MaxTokens);
    assert_eq!(t1.len(), 6);
    assert!(ttft1 > 0, "TTFT measured");
    assert_eq!(r2, StopReason::StopToken, "every class id is a stop token");
    assert_eq!(t2.len(), 1, "the stop token is emitted, then the stream ends");

    // greedy stream == direct engine loop on identical weights
    let mut okv = probe.fresh_kv();
    let want = generate(
        &probe,
        &mut okv,
        &[],
        &GenerateRequest::greedy(p1.clone(), 6),
        &limits,
        |_, _| {},
    );
    assert_eq!(t1, want.tokens);

    // generated tokens are real session context for later turns
    let append = toks(&mut rng, 5);
    let mut full = p1;
    full.extend_from_slice(&t1);
    full.extend_from_slice(&append);
    let turn = server.infer_session(1, append).unwrap();
    assert_eq!(turn.cached_tokens, 18, "prompt + generated tokens were cached");
    assert_eq!(turn.logits, probe.forward_logits(&full));

    let snap = server.metrics.snapshot();
    assert_eq!(snap.gen_streams, 2);
    assert_eq!(snap.gen_tokens, 7);
    assert!(snap.ttft_p99_us > 0);
    assert!(snap.gen_tokens_per_s > 0.0);
    // the 6-token stream produced 5 inter-token gaps
    assert!(snap.inter_token_p99_us > 0);
}
