//! From-scratch stand-in for the `anyhow` crate (the cargo registry is
//! unreachable in this environment — DESIGN.md §Substrates).
//!
//! Implements exactly the surface this repository uses: `Error`, `Result`,
//! the `anyhow!` / `bail!` / `ensure!` macros, and the `Context` extension
//! trait on `Result` and `Option`. Error values carry their context chain
//! as strings; `{e}` prints the outermost message, `{e:#}` the full chain
//! joined by `": "` (matching anyhow's Display semantics).

use std::fmt;

/// `Result` defaulting to [`Error`], as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chained error. Unlike `anyhow::Error` it does not box the
/// original error value — only the rendered message chain survives — which
/// is all the callers here ever consume.
pub struct Error {
    /// Outermost context first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like anyhow, `Error` deliberately does NOT implement std::error::Error —
// that is what makes this blanket From (and thus `?` on any std error)
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension, implemented for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (captures like `format!`).
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("Condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/had")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn display_plain_vs_alternate() {
        let e: Result<()> = Err(anyhow!("root"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        let v = Some(7u32);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e: Result<()> = Err(anyhow!("inner"));
        let e = e.context("mid").map_err(|e| e.context("outer")).unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }
}
