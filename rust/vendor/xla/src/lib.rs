//! Stub of the `xla` PJRT bindings used by `runtime/` (the real
//! xla_extension shared library is not present in this environment).
//!
//! Host-side `Literal` construction/reshape/readback is fully functional —
//! `HostTensor` round-trips and their tests run everywhere. Device-side
//! entry points (`HloModuleProto::from_text_file`, `PjRtClient::compile`,
//! `PjRtLoadedExecutable::execute`) return a descriptive error instead:
//! callers already treat missing artifacts/PJRT as a skip condition, so
//! the serving and experiment paths degrade exactly like a machine without
//! `make artifacts`.

use std::fmt;

/// Error type for all stubbed operations. Implements `std::error::Error`
/// so `anyhow::Context` attaches to it transparently.
#[derive(Debug, Clone)]
pub struct XlaError {
    msg: String,
}

impl XlaError {
    pub fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }

    fn unavailable(what: &str) -> XlaError {
        XlaError::new(format!(
            "{what} is unavailable: this build uses the vendored xla stub (no libxla/PJRT runtime)"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types this repo's artifacts use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    Tuple,
}

/// Typed literal storage (public only so `NativeType` can name it).
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Rust scalar types that can back a Literal.
pub trait NativeType: Copy {
    const PRIMITIVE: PrimitiveType;
    fn wrap(data: &[Self]) -> LiteralData;
    fn extract(data: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const PRIMITIVE: PrimitiveType = PrimitiveType::F32;
    fn wrap(data: &[f32]) -> LiteralData {
        LiteralData::F32(data.to_vec())
    }
    fn extract(data: &LiteralData) -> Option<Vec<f32>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const PRIMITIVE: PrimitiveType = PrimitiveType::S32;
    fn wrap(data: &[i32]) -> LiteralData {
        LiteralData::I32(data.to_vec())
    }
    fn extract(data: &LiteralData) -> Option<Vec<i32>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host literal: shape + typed data, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data) }
    }

    fn numel(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret under a new shape of equal element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.numel() {
            return Err(XlaError::new(format!(
                "reshape: {} elements into shape {dims:?}",
                self.numel()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            LiteralData::F32(_) => PrimitiveType::F32,
            LiteralData::I32(_) => PrimitiveType::S32,
            LiteralData::Tuple(_) => {
                return Err(XlaError::new("array_shape on a tuple literal"))
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data).ok_or_else(|| {
            XlaError::new(format!("to_vec: literal is not {:?}", T::PRIMITIVE))
        })
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(v) => Ok(v),
            _ => Err(XlaError::new("to_tuple on a non-tuple literal")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<std::path::Path>) -> Result<HloModuleProto> {
        Err(XlaError::unavailable(&format!(
            "HLO text parsing ({})",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host-only PJRT client: construction succeeds (so startup logging and
/// manifest validation run), compilation reports the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> &'static str {
        "stub-cpu"
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("PJRT compilation"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("PJRT execution"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        let s = m.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.primitive_type(), PrimitiveType::F32);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(m.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_shape() {
        let l = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn device_paths_report_stub() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let comp = XlaComputation { _private: () };
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("stub"));
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo").is_err());
    }
}
