//! Serving-side weight views: a `ServeModel` is a `Checkpoint` (or raw
//! `ParamSet`) re-sliced for the per-layer decode loop.
//!
//! The manifest stores layer parameters stacked on a leading `n_layers`
//! axis (the `jax.lax.scan` layout — python/compile/model.py's
//! `param_specs` is THE contract). The decode engine wants one weight
//! matrix per layer, so construction slices each stacked tensor into
//! per-layer `Mat`s once; decode then never indexes into stacked storage.

use anyhow::{ensure, Context, Result};

use crate::model::{Checkpoint, ParamSet};
use crate::runtime::{ConfigEntry, Init, ModelCfg, ParamSpec};
use crate::store::StoreError;
use crate::tensor::Mat;

type StoreResult<T> = std::result::Result<T, StoreError>;

/// One transformer layer's weights, de-stacked.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Mat,
    pub bq: Vec<f32>,
    pub wk: Mat,
    pub bk: Vec<f32>,
    pub wv: Mat,
    pub bv: Vec<f32>,
    pub wo: Mat,
    pub bo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Mat,
    pub b1: Vec<f32>,
    pub w2: Mat,
    pub b2: Vec<f32>,
}

/// Everything the CPU backend needs to run the distilled HAD model:
/// weights, architecture, and the per-layer calibrated sigmas whose
/// product becomes the Hamming softmax temperature (paper §3.4).
#[derive(Clone, Debug)]
pub struct ServeModel {
    pub cfg: ModelCfg,
    /// (vocab, d_model) token embedding — token-mode models only.
    pub tok_emb: Mat,
    /// (n_ctx, d_model) learned positions; decode wraps `p % n_ctx` for
    /// sessions that outgrow the trained context.
    pub pos_emb: Mat,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub head_w: Mat,
    pub head_b: Vec<f32>,
    pub sigma_q: Vec<f32>,
    pub sigma_k: Vec<f32>,
    pub n_top: usize,
}

impl ServeModel {
    /// Slice a manifest-ordered `ParamSet` into the decode layout.
    pub fn from_params(
        cfg: &ConfigEntry,
        params: &ParamSet,
        sigma_q: Vec<f32>,
        sigma_k: Vec<f32>,
    ) -> Result<ServeModel> {
        let m = &cfg.model;
        ensure!(m.vocab > 0, "the serving backend is token-mode only (vocab == 0)");
        ensure!(m.n_heads > 0 && m.d_model % m.n_heads == 0, "d_model must split into heads");
        ensure!(
            sigma_q.len() == m.n_layers && sigma_k.len() == m.n_layers,
            "need one sigma_q/sigma_k per layer ({} layers, got {}/{})",
            m.n_layers,
            sigma_q.len(),
            sigma_k.len()
        );
        let (l_count, d, f) = (m.n_layers, m.d_model, m.d_ff);

        // named fn (not a closure): the returned slice borrows from
        // `params`, which closure lifetime inference cannot express
        fn tensor<'a>(params: &'a ParamSet, cfg: &ConfigEntry, name: &str) -> Result<&'a [f32]> {
            params
                .by_name(cfg, name)
                .with_context(|| format!("model parameter {name} missing from config"))?
                .as_f32()
        }
        let mat = |name: &str, rows: usize, cols: usize| -> Result<Mat> {
            let data = tensor(params, cfg, name)?;
            ensure!(data.len() == rows * cols, "{name}: {} != {rows}x{cols}", data.len());
            Ok(Mat::from_vec(rows, cols, data.to_vec()))
        };
        // layer `l`'s slab of a stacked (L, ...) tensor
        let layer_mat = |name: &str, l: usize, rows: usize, cols: usize| -> Result<Mat> {
            let data = tensor(params, cfg, name)?;
            ensure!(data.len() == l_count * rows * cols, "{name}: bad stacked shape");
            let slab = &data[l * rows * cols..(l + 1) * rows * cols];
            Ok(Mat::from_vec(rows, cols, slab.to_vec()))
        };
        let layer_vec = |name: &str, l: usize, len: usize| -> Result<Vec<f32>> {
            let data = tensor(params, cfg, name)?;
            ensure!(data.len() == l_count * len, "{name}: bad stacked shape");
            Ok(data[l * len..(l + 1) * len].to_vec())
        };

        let mut layers = Vec::with_capacity(l_count);
        for l in 0..l_count {
            layers.push(LayerWeights {
                ln1_g: layer_vec("ln1_g", l, d)?,
                ln1_b: layer_vec("ln1_b", l, d)?,
                wq: layer_mat("wq", l, d, d)?,
                bq: layer_vec("bq", l, d)?,
                wk: layer_mat("wk", l, d, d)?,
                bk: layer_vec("bk", l, d)?,
                wv: layer_mat("wv", l, d, d)?,
                bv: layer_vec("bv", l, d)?,
                wo: layer_mat("wo", l, d, d)?,
                bo: layer_vec("bo", l, d)?,
                ln2_g: layer_vec("ln2_g", l, d)?,
                ln2_b: layer_vec("ln2_b", l, d)?,
                w1: layer_mat("w1", l, d, f)?,
                b1: layer_vec("b1", l, f)?,
                w2: layer_mat("w2", l, f, d)?,
                b2: layer_vec("b2", l, d)?,
            });
        }

        Ok(ServeModel {
            cfg: m.clone(),
            tok_emb: mat("tok_emb", m.vocab, d)?,
            pos_emb: mat("pos_emb", m.n_ctx, d)?,
            layers,
            lnf_g: tensor(params, cfg, "lnf_g")?.to_vec(),
            lnf_b: tensor(params, cfg, "lnf_b")?.to_vec(),
            head_w: mat("head_w", d, m.n_classes)?,
            head_b: tensor(params, cfg, "head_b")?.to_vec(),
            sigma_q,
            sigma_k,
            n_top: m.n_top,
        })
    }

    /// Load a distilled checkpoint (weights + calibrated sigmas).
    pub fn from_checkpoint(cfg: &ConfigEntry, ckpt: &Checkpoint) -> Result<ServeModel> {
        ServeModel::from_params(cfg, &ckpt.params, ckpt.sigma_q.clone(), ckpt.sigma_k.clone())
    }

    /// Zero-copy load from a `HADSTOR1` checkpoint container: weight
    /// matrices become [`crate::tensor::Slab`] views borrowing the
    /// read-only mmap (per-layer slices of the stacked sections, no heap
    /// copies), so load cost is CRC verification plus demand paging and
    /// the logits are bit-identical to [`ServeModel::from_checkpoint`].
    /// Small vectors (biases, layernorm params, sigmas) are copied to the
    /// heap — they are a rounding error next to the matrices.
    ///
    /// Every failure mode (corrupt file, wrong config, geometry drift) is
    /// a typed [`StoreError`]; callers fall back to a cold heap load.
    pub fn from_store(cfg: &ConfigEntry, path: &std::path::Path) -> StoreResult<ServeModel> {
        let mut sp = crate::obs::root_span("mmap_load");
        let c = crate::store::open_checkpoint(path, cfg)?;
        let m = &cfg.model;
        if m.vocab == 0 {
            return Err(StoreError::ShapeMismatch("serving store is token-mode only".into()));
        }
        let sigma_q = crate::store::meta_sigmas(&c, "sigma_q")?;
        let sigma_k = crate::store::meta_sigmas(&c, "sigma_k")?;
        if sigma_q.len() != m.n_layers || sigma_k.len() != m.n_layers {
            return Err(StoreError::ShapeMismatch(format!(
                "need one sigma per layer ({} layers, got {}/{})",
                m.n_layers,
                sigma_q.len(),
                sigma_k.len()
            )));
        }
        let (l_count, d, f) = (m.n_layers, m.d_model, m.d_ff);

        let sect = |name: &str, numel: usize| -> StoreResult<crate::tensor::Slab> {
            let s = c.section_f32(name)?;
            if s.len() != numel {
                return Err(StoreError::ShapeMismatch(format!(
                    "{name}: {} f32s on disk, architecture wants {numel}",
                    s.len()
                )));
            }
            Ok(s)
        };
        let mat = |name: &str, rows: usize, cols: usize| -> StoreResult<Mat> {
            Ok(Mat::from_slab(rows, cols, sect(name, rows * cols)?))
        };
        // layer `l`'s sub-view of a stacked (L, ...) section — zero-copy
        let layer_mat = |name: &str, l: usize, rows: usize, cols: usize| -> StoreResult<Mat> {
            let s = sect(name, l_count * rows * cols)?;
            Ok(Mat::from_slab(rows, cols, s.slice(l * rows * cols, rows * cols)))
        };
        let layer_vec = |name: &str, l: usize, len: usize| -> StoreResult<Vec<f32>> {
            let s = sect(name, l_count * len)?;
            Ok(s.as_slice()[l * len..(l + 1) * len].to_vec())
        };

        let mut layers = Vec::with_capacity(l_count);
        for l in 0..l_count {
            layers.push(LayerWeights {
                ln1_g: layer_vec("ln1_g", l, d)?,
                ln1_b: layer_vec("ln1_b", l, d)?,
                wq: layer_mat("wq", l, d, d)?,
                bq: layer_vec("bq", l, d)?,
                wk: layer_mat("wk", l, d, d)?,
                bk: layer_vec("bk", l, d)?,
                wv: layer_mat("wv", l, d, d)?,
                bv: layer_vec("bv", l, d)?,
                wo: layer_mat("wo", l, d, d)?,
                bo: layer_vec("bo", l, d)?,
                ln2_g: layer_vec("ln2_g", l, d)?,
                ln2_b: layer_vec("ln2_b", l, d)?,
                w1: layer_mat("w1", l, d, f)?,
                b1: layer_vec("b1", l, f)?,
                w2: layer_mat("w2", l, f, d)?,
                b2: layer_vec("b2", l, d)?,
            });
        }

        let model = ServeModel {
            cfg: m.clone(),
            tok_emb: mat("tok_emb", m.vocab, d)?,
            pos_emb: mat("pos_emb", m.n_ctx, d)?,
            layers,
            lnf_g: sect("lnf_g", d)?.into_vec(),
            lnf_b: sect("lnf_b", d)?.into_vec(),
            head_w: mat("head_w", d, m.n_classes)?,
            head_b: sect("head_b", m.n_classes)?.into_vec(),
            sigma_q,
            sigma_k,
            n_top: m.n_top,
        };
        let total: usize = cfg.params.iter().map(|p| p.numel() * 4).sum();
        sp.set_payload(total as u64);
        Ok(model)
    }

    /// Randomly initialized model with unit sigmas (latency/throughput
    /// demos and serving-path tests where accuracy is irrelevant).
    pub fn random(cfg: &ConfigEntry, seed: u64) -> Result<ServeModel> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let params = ParamSet::init(cfg, &mut rng);
        let l = cfg.model.n_layers;
        ServeModel::from_params(cfg, &params, vec![1.0; l], vec![1.0; l])
    }

    /// Softmax temperature of layer `l`: sigma_q * sigma_k (the
    /// calibrated standardization folded into the Hamming softmax).
    #[inline]
    pub fn temp(&self, l: usize) -> f32 {
        self.sigma_q[l] * self.sigma_k[l]
    }
}

/// Build a token-mode `ConfigEntry` without a compiled manifest — the
/// parameter list replicates python `param_specs` (name/shape/init order,
/// layer tensors stacked on a leading `n_layers` axis) so checkpoints and
/// `ParamSet`s built against it are layout-compatible with lowered
/// artifacts of the same architecture. Used by serving demos, benches,
/// and tests that run the CPU backend without PJRT artifacts.
pub fn token_config_entry(name: &str, model: ModelCfg) -> ConfigEntry {
    assert!(model.vocab > 0, "token_config_entry is token-mode only");
    let (l, d, f) = (model.n_layers, model.d_model, model.d_ff);
    let spec = |name: &str, shape: Vec<usize>, init: Init| ParamSpec {
        name: name.to_string(),
        shape,
        init,
    };
    let mut params = vec![
        spec("tok_emb", vec![model.vocab, d], Init::Normal),
        spec("pos_emb", vec![model.n_ctx, d], Init::Normal),
    ];
    for (pname, shape, init) in [
        ("ln1_g", vec![l, d], Init::Ones),
        ("ln1_b", vec![l, d], Init::Zeros),
        ("wq", vec![l, d, d], Init::Normal),
        ("bq", vec![l, d], Init::Zeros),
        ("wk", vec![l, d, d], Init::Normal),
        ("bk", vec![l, d], Init::Zeros),
        ("wv", vec![l, d, d], Init::Normal),
        ("bv", vec![l, d], Init::Zeros),
        ("wo", vec![l, d, d], Init::Normal),
        ("bo", vec![l, d], Init::Zeros),
        ("ln2_g", vec![l, d], Init::Ones),
        ("ln2_b", vec![l, d], Init::Zeros),
        ("w1", vec![l, d, f], Init::Normal),
        ("b1", vec![l, f], Init::Zeros),
        ("w2", vec![l, f, d], Init::Normal),
        ("b2", vec![l, d], Init::Zeros),
    ] {
        params.push(spec(pname, shape, init));
    }
    params.extend([
        spec("lnf_g", vec![d], Init::Ones),
        spec("lnf_b", vec![d], Init::Zeros),
        spec("head_w", vec![d, model.n_classes], Init::Normal),
        spec("head_b", vec![model.n_classes], Init::Zeros),
    ]);
    ConfigEntry {
        name: name.to_string(),
        model,
        train_batch: 1,
        eval_batch: 1,
        params,
    }
}

/// A small default architecture for serving demos/benches: token mode,
/// `n_ctx` as given, geometry chosen so attention dominates at long
/// context but full decodes stay CI-cheap.
pub fn demo_config(name: &str, n_ctx: usize, n_top: usize) -> ConfigEntry {
    token_config_entry(
        name,
        ModelCfg {
            n_layers: 2,
            d_model: 64,
            n_heads: 4,
            d_ff: 128,
            n_ctx,
            n_classes: 4,
            vocab: 256,
            input_dim: 0,
            n_top,
            block_q: 64,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ConfigEntry {
        token_config_entry(
            "serve_tiny",
            ModelCfg {
                n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 16,
                n_classes: 3, vocab: 24, input_dim: 0, n_top: 8, block_q: 16,
            },
        )
    }

    #[test]
    fn from_params_slices_stacked_layers() {
        let cfg = tiny_cfg();
        let mut rng = crate::util::rng::Rng::new(1);
        let params = ParamSet::init(&cfg, &mut rng);
        let model =
            ServeModel::from_params(&cfg, &params, vec![0.5, 0.7], vec![0.9, 1.1]).unwrap();
        assert_eq!(model.layers.len(), 2);
        assert_eq!((model.tok_emb.rows, model.tok_emb.cols), (24, 32));
        assert_eq!((model.head_w.rows, model.head_w.cols), (32, 3));
        assert!((model.temp(0) - 0.45).abs() < 1e-6);
        // layer 1's wq slab is the second half of the stacked tensor
        let stacked = params.by_name(&cfg, "wq").unwrap().as_f32().unwrap();
        assert_eq!(model.layers[1].wq.data.as_slice(), &stacked[32 * 32..]);
        assert_eq!(model.layers[0].wq.data.as_slice(), &stacked[..32 * 32]);
        // init kinds flow through: layernorm gains are ones
        assert!(model.layers[0].ln1_g.iter().all(|&x| x == 1.0));
        assert!(model.lnf_g.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn sigma_arity_is_enforced() {
        let cfg = tiny_cfg();
        let mut rng = crate::util::rng::Rng::new(2);
        let params = ParamSet::init(&cfg, &mut rng);
        assert!(ServeModel::from_params(&cfg, &params, vec![1.0], vec![1.0, 1.0]).is_err());
    }

    #[test]
    fn checkpoint_roundtrips_into_serve_model() {
        let cfg = tiny_cfg();
        let mut rng = crate::util::rng::Rng::new(3);
        let ckpt = Checkpoint {
            config: cfg.name.clone(),
            step: 7.0,
            sigma_q: vec![0.5, 0.6],
            sigma_k: vec![0.7, 0.8],
            params: ParamSet::init(&cfg, &mut rng),
        };
        let dir = std::env::temp_dir().join("had_serve_model_test");
        let path = dir.join("m.ckpt");
        crate::model::save_checkpoint(&path, &cfg, &ckpt).unwrap();
        let loaded = crate::model::load_checkpoint(&path, &cfg).unwrap();
        let model = ServeModel::from_checkpoint(&cfg, &loaded).unwrap();
        assert_eq!(model.sigma_q, vec![0.5, 0.6]);
        let direct = ServeModel::from_checkpoint(&cfg, &ckpt).unwrap();
        assert_eq!(model.layers[0].wq, direct.layers[0].wq);
        assert_eq!(model.tok_emb, direct.tok_emb);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_dense_input_mode() {
        let mut cfg = tiny_cfg();
        cfg.model.vocab = 0;
        cfg.model.input_dim = 8;
        // param list no longer matches, but vocab gate fires first
        let mut rng = crate::util::rng::Rng::new(4);
        let params = ParamSet::init(&cfg, &mut rng);
        assert!(ServeModel::from_params(&cfg, &params, vec![1.0; 2], vec![1.0; 2]).is_err());
    }
}
