//! The CPU-native HAD decode engine: executes the real transformer
//! forward token by token over a [`LayeredKv`] — per-layer Q/K/V
//! projections from the checkpoint weights, sigma-standardized sign
//! binarization (sign bits packed on append; `sigma_q * sigma_k` folded
//! into the Hamming softmax temperature), XNOR-popcount attention with
//! streaming top-N via `binary::kernel`, f32 value accumulation, GELU
//! MLP, and classification logits out.
//!
//! ## Incremental exactness
//!
//! Decode is causal: position `p` attends over keys `0..=p`, so a
//! position's hidden state depends only on its prefix. Appending a
//! suffix to a resident [`LayeredKv`] therefore reproduces, bit for bit,
//! the state a from-scratch decode of the full sequence would build —
//! THE property that lets a session's turn N pay only for its new
//! tokens (asserted by `chunked_decode_is_bit_exact`). The cache stores
//! the decoded token ids, and [`HadBackend::decode`] resumes only when
//! the resident state is a true prefix of the requested sequence,
//! resetting otherwise.

use std::time::Instant;

use crate::binary::attention::{
    had_attention_paged_scalar_with, had_attention_paged_with, HadAttnConfig, Scratch,
};
use crate::kvcache::{KvCacheConfig, KvGeom, LayeredKv, ValueDtype};
use crate::serve::model::ServeModel;
use crate::serve::{add_assign, affine};
use crate::tensor::{ops, Mat};

/// Which attention implementation scores the decode. `Kernel` is the
/// production blocked engine — its popcount inner step dispatches
/// through the runtime-selected `binary::simd::KernelBackend`
/// (`HAD_KERNEL` override), so serve decode and the generation tick
/// loop ride whatever SIMD backend the host offers. `Scalar` is the
/// retained oracle, exposed so tests can assert the whole decode is
/// bit-identical across the two (the score-path exactness contract,
/// end to end).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnPath {
    Kernel,
    Scalar,
}

/// Logits captured at one requested prefix length during a decode pass.
#[derive(Clone, Debug)]
pub struct CaptureOut {
    /// Prefix length (in tokens) these logits correspond to.
    pub len: usize,
    pub logits: Vec<f32>,
    /// Time spent inside the Hamming attention kernel for the segment
    /// ending at this capture (previous capture, or resume point, up to
    /// `len`).
    pub attn_us: u128,
    /// Wall time of the same segment's full forward work.
    pub decode_us: u128,
}

/// Per-segment stage accumulators behind the tracing gate: promoted into
/// one "decode" trace span per capture segment, with children for embed,
/// Q/K/V projection, per-layer attention (payload = layer index), MLP,
/// and the logit head.
struct StageAcc {
    embed_us: u64,
    qkv_us: u64,
    /// one slot per layer (empty when not tracing)
    attn_us: Vec<u64>,
    mlp_us: u64,
    head_us: u64,
}

impl StageAcc {
    fn new(n_layers: usize) -> StageAcc {
        StageAcc { embed_us: 0, qkv_us: 0, attn_us: vec![0; n_layers], mlp_us: 0, head_us: 0 }
    }

    /// Record the segment's span tree and reset for the next segment.
    /// Child durations are exact per-stage sums over the segment's
    /// tokens; their start offsets are synthetic (laid out sequentially
    /// from the segment start — the real execution interleaves stages
    /// token by token).
    fn emit(&mut self, seg_start: Instant, seg_us: u64, seg_tokens: u64) {
        use crate::obs;
        let parent = obs::record(obs::current(), "decode", seg_start, seg_us, seg_tokens);
        if !parent.is_none() {
            let mut off = std::time::Duration::ZERO;
            let mut child = |name: &'static str, dur: u64, payload: u64,
                             off: &mut std::time::Duration| {
                obs::record(parent, name, seg_start + *off, dur, payload);
                *off += std::time::Duration::from_micros(dur);
            };
            child("embed", self.embed_us, 0, &mut off);
            child("qkv", self.qkv_us, 0, &mut off);
            for (l, &a) in self.attn_us.iter().enumerate() {
                child("attention", a, l as u64, &mut off);
            }
            child("mlp", self.mlp_us, 0, &mut off);
            child("head", self.head_us, 0, &mut off);
        }
        self.embed_us = 0;
        self.qkv_us = 0;
        self.mlp_us = 0;
        self.head_us = 0;
        for a in &mut self.attn_us {
            *a = 0;
        }
    }
}

/// Summary of one decode pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeStats {
    /// Token position decoding resumed from (0 == cold / reset).
    pub resumed_at: usize,
    /// Suffix tokens actually decoded by this pass.
    pub decoded: usize,
    /// Total Hamming-attention time across the pass.
    pub attn_us: u128,
    /// Total forward time across the pass.
    pub decode_us: u128,
}

/// The serving backend: one loaded model plus the KV page geometry it
/// decodes into. Stateless across calls — all sequence state lives in
/// the caller's `LayeredKv`, so one backend serves any number of
/// concurrent sessions from worker threads.
pub struct HadBackend {
    model: ServeModel,
    page_tokens: usize,
    value_dtype: ValueDtype,
}

impl HadBackend {
    pub fn new(model: ServeModel, kv: &KvCacheConfig) -> HadBackend {
        HadBackend { model, page_tokens: kv.page_tokens, value_dtype: kv.value_dtype }
    }

    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    pub fn n_classes(&self) -> usize {
        self.model.cfg.n_classes
    }

    /// Per-layer-per-head page-chain geometry this backend decodes into.
    pub fn geom(&self) -> KvGeom {
        KvGeom {
            n_layers: self.model.cfg.n_layers,
            n_heads: self.model.cfg.n_heads,
            d_head: self.model.cfg.d_head(),
        }
    }

    /// An empty decode state for a new session (or a stateless request).
    pub fn fresh_kv(&self) -> LayeredKv {
        LayeredKv::new(self.geom(), self.page_tokens, self.value_dtype)
    }

    /// Decode `tokens` into `kv`, returning logits at each requested
    /// prefix length (`capture_lens`: strictly ascending, each in
    /// `1..=tokens.len()`).
    ///
    /// If `kv` already holds a decode of a strict prefix of `tokens`
    /// (id-checked) shorter than the first capture, decoding resumes
    /// there — the session warm path that touches only the appended
    /// suffix. Any other resident state is reset and re-decoded, so the
    /// result is independent of what was resident before.
    pub fn decode(
        &self,
        kv: &mut LayeredKv,
        tokens: &[i32],
        capture_lens: &[usize],
    ) -> (Vec<CaptureOut>, DecodeStats) {
        self.decode_with(kv, tokens, capture_lens, AttnPath::Kernel)
    }

    /// `decode` with an explicit attention path (tests drive `Scalar` to
    /// assert kernel/oracle bit-identity of the served logits).
    pub fn decode_with(
        &self,
        kv: &mut LayeredKv,
        tokens: &[i32],
        capture_lens: &[usize],
        path: AttnPath,
    ) -> (Vec<CaptureOut>, DecodeStats) {
        let mut scratch = Scratch::default();
        self.decode_in(kv, tokens, capture_lens, path, &mut scratch)
    }

    /// `decode_with` against caller-owned scratch buffers: the batch
    /// scheduler hands every decode job a buffer from its [`ScratchPool`]
    /// so concurrent jobs within a tick reuse grown allocations instead
    /// of paying `Scratch::default()` each. Scratch contents never affect
    /// results (buffers are fully rewritten per attention call).
    pub fn decode_in(
        &self,
        kv: &mut LayeredKv,
        tokens: &[i32],
        capture_lens: &[usize],
        path: AttnPath,
        scratch: &mut Scratch,
    ) -> (Vec<CaptureOut>, DecodeStats) {
        assert_eq!(kv.geom(), self.geom(), "decode state geometry mismatch");
        for w in capture_lens.windows(2) {
            assert!(w[0] < w[1], "capture lengths must be strictly ascending");
        }
        if let (Some(&first), Some(&last)) = (capture_lens.first(), capture_lens.last()) {
            assert!(first >= 1 && last <= tokens.len(), "capture length out of range");
        }

        // resume only from a true id-checked prefix that still lets the
        // first capture be produced on the way
        let resumable =
            kv.is_prefix_of(tokens) && capture_lens.first().map_or(true, |&c| kv.len() < c);
        if !resumable {
            kv.reset();
        }
        let start = kv.len();

        let m = &self.model;
        let (d, dh, n_heads) = (m.cfg.d_model, m.cfg.d_head(), m.cfg.n_heads);
        // per-layer attention configs hoisted out of the token loop (one
        // temp lookup per decode pass, not per token per layer)
        let acfgs: Vec<HadAttnConfig> = (0..m.layers.len())
            .map(|l| HadAttnConfig { n_top: m.n_top, temp: m.temp(l) })
            .collect();
        let mut captures = Vec::with_capacity(capture_lens.len());
        let mut next_capture = 0usize;
        let mut stats = DecodeStats { resumed_at: start, ..Default::default() };
        let mut seg_start = Instant::now();
        let mut seg_attn = 0u128;
        // Per-stage attribution, promoted into trace spans at each
        // segment boundary. Only accumulated when this decode runs inside
        // a traced scope (a sampled request) — otherwise the extra
        // Instant reads per token/layer are skipped entirely and the
        // pre-existing seg_start/seg_attn timers are all that run.
        let fine = crate::obs::tracing() && !crate::obs::current().is_none();
        let mut seg = StageAcc::new(if fine { m.layers.len() } else { 0 });
        let mut seg_tokens = 0u64;

        for p in start..tokens.len() {
            // embed: token row + (wrapped) learned position
            let t_stage = fine.then(Instant::now);
            let tok = tokens[p].rem_euclid(m.cfg.vocab as i32) as usize;
            let mut h = Mat::from_vec(1, d, m.tok_emb.row(tok).to_vec());
            for (o, &pe) in h.data.iter_mut().zip(m.pos_emb.row(p % m.cfg.n_ctx)) {
                *o += pe;
            }
            if let Some(t) = t_stage {
                seg.embed_us += t.elapsed().as_micros() as u64;
            }

            for (l, lw) in m.layers.iter().enumerate() {
                // pre-LN attention block
                let t_stage = fine.then(Instant::now);
                let x = ops::layernorm_rows(&h, &lw.ln1_g, &lw.ln1_b, 1e-5);
                let q = affine(&x, &lw.wq, &lw.bq);
                let k = affine(&x, &lw.wk, &lw.bk);
                let v = affine(&x, &lw.wv, &lw.bv);
                if let Some(t) = t_stage {
                    seg.qkv_us += t.elapsed().as_micros() as u64;
                }
                let acfg = acfgs[l];
                let mut ctx = Mat::zeros(1, d);
                for head in 0..n_heads {
                    let span = head * dh..(head + 1) * dh;
                    // this token's K/V join the resident pages FIRST, so
                    // the query attends over keys 0..=p (causal decode)
                    kv.chain_mut(l, head).append_row(&k.data[span.clone()], &v.data[span.clone()]);
                    let qh = Mat::from_vec(1, dh, q.data[span.clone()].to_vec());
                    let chain = kv.chain(l, head);
                    let t0 = Instant::now();
                    let o = match path {
                        AttnPath::Kernel => {
                            had_attention_paged_with(&qh, chain, &acfg, scratch)
                        }
                        AttnPath::Scalar => {
                            had_attention_paged_scalar_with(&qh, chain, &acfg, scratch)
                        }
                    };
                    let head_attn = t0.elapsed().as_micros();
                    seg_attn += head_attn;
                    if fine {
                        seg.attn_us[l] += head_attn as u64;
                    }
                    ctx.data[span].copy_from_slice(o.row(0));
                }
                let t_stage = fine.then(Instant::now);
                add_assign(&mut h, &affine(&ctx, &lw.wo, &lw.bo));
                // MLP block
                let y = ops::layernorm_rows(&h, &lw.ln2_g, &lw.ln2_b, 1e-5);
                let mut u = affine(&y, &lw.w1, &lw.b1);
                for xv in &mut u.data {
                    *xv = ops::gelu_tanh(*xv);
                }
                add_assign(&mut h, &affine(&u, &lw.w2, &lw.b2));
                if let Some(t) = t_stage {
                    seg.mlp_us += t.elapsed().as_micros() as u64;
                }
            }
            kv.note_token(tokens[p]);
            seg_tokens += 1;

            if next_capture < capture_lens.len() && capture_lens[next_capture] == p + 1 {
                let t_stage = fine.then(Instant::now);
                let hf = ops::layernorm_rows(&h, &m.lnf_g, &m.lnf_b, 1e-5);
                let logits = affine(&hf, &m.head_w, &m.head_b);
                if let Some(t) = t_stage {
                    seg.head_us += t.elapsed().as_micros() as u64;
                }
                let seg_us = seg_start.elapsed().as_micros();
                captures.push(CaptureOut {
                    len: p + 1,
                    logits: logits.data.into_vec(),
                    attn_us: seg_attn,
                    decode_us: seg_us,
                });
                stats.attn_us += seg_attn;
                stats.decode_us += seg_us;
                if fine {
                    seg.emit(seg_start, seg_us as u64, seg_tokens);
                }
                seg_attn = 0;
                seg_start = Instant::now();
                seg_tokens = 0;
                next_capture += 1;
            }
        }
        // trailing work past the last capture still counts toward totals
        if tokens.len() > start
            && captures.last().map_or(true, |c| c.len < tokens.len())
        {
            stats.attn_us += seg_attn;
            let seg_us = seg_start.elapsed().as_micros();
            stats.decode_us += seg_us;
            if fine {
                seg.emit(seg_start, seg_us as u64, seg_tokens);
            }
        }
        stats.decoded = tokens.len() - start;
        (captures, stats)
    }

    /// Stateless convenience: full forward over `tokens`, logits at the
    /// last position (what a sessionless request receives).
    pub fn forward_logits(&self, tokens: &[i32]) -> Vec<f32> {
        assert!(!tokens.is_empty(), "forward over an empty sequence");
        let mut kv = self.fresh_kv();
        let (mut captures, _) = self.decode(&mut kv, tokens, &[tokens.len()]);
        captures.pop().expect("one capture requested").logits
    }
}

/// A checkout pool of attention [`Scratch`] buffers, shared by every
/// decode job the scheduler runs — batch decodes and generation steps
/// alike — instead of each job allocating its own. Buffers keep their
/// grown capacity across checkins, so steady-state serving reaches a
/// fixed point with no scratch allocation at all; under concurrency the
/// pool simply hands out as many buffers as there are simultaneous jobs.
#[derive(Default)]
pub struct ScratchPool {
    free: std::sync::Mutex<Vec<Scratch>>,
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Take a buffer (a previously-grown one when available). Recovers
    /// from a poisoned pool lock: the buffers are plain grow-on-demand
    /// scratch space, always valid regardless of where a panic landed.
    pub fn checkout(&self) -> Scratch {
        crate::util::lock_or_recover(&self.free).pop().unwrap_or_default()
    }

    /// Return a buffer for the next job to reuse.
    pub fn checkin(&self, scratch: Scratch) {
        crate::util::lock_or_recover(&self.free).push(scratch);
    }

    /// Run `f` with a pooled buffer (checkout/checkin around it).
    pub fn with<R>(&self, f: impl FnOnce(&mut Scratch) -> R) -> R {
        let mut scratch = self.checkout();
        let out = f(&mut scratch);
        self.checkin(scratch);
        out
    }

    /// Buffers currently parked in the pool (introspection/tests).
    pub fn parked(&self) -> usize {
        crate::util::lock_or_recover(&self.free).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ConfigEntry, ModelCfg};
    use crate::serve::model::{token_config_entry, ServeModel};
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ConfigEntry {
        token_config_entry(
            "serve_tiny",
            ModelCfg {
                n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 24,
                n_classes: 3, vocab: 24, input_dim: 0, n_top: 6, block_q: 16,
            },
        )
    }

    fn backend(kv: KvCacheConfig) -> HadBackend {
        let cfg = tiny_cfg();
        let model = ServeModel::random(&cfg, 0xA11CE).unwrap();
        HadBackend::new(model, &kv)
    }

    fn toks(rng: &mut Rng, n: usize) -> Vec<i32> {
        (0..n).map(|_| rng.below(24) as i32).collect()
    }

    #[test]
    fn chunked_decode_is_bit_exact() {
        // a session decoded over three turns must reproduce the one-shot
        // decode exactly — the "suffix-only decode" acceptance property
        let kv_cfg = KvCacheConfig { page_tokens: 4, ..Default::default() };
        let b = backend(kv_cfg);
        let mut rng = Rng::new(10);
        let tokens = toks(&mut rng, 19);

        let mut oneshot_kv = b.fresh_kv();
        let (oneshot, _) = b.decode(&mut oneshot_kv, &tokens, &[7, 12, 19]);

        let mut kv = b.fresh_kv();
        let mut turnwise = Vec::new();
        for (turn_len, resume_at) in [(7usize, 0usize), (12, 7), (19, 12)] {
            let (mut caps, stats) = b.decode(&mut kv, &tokens[..turn_len], &[turn_len]);
            assert_eq!(stats.resumed_at, resume_at, "warm turns resume at the resident length");
            turnwise.push(caps.pop().unwrap());
        }
        for (a, b_) in oneshot.iter().zip(&turnwise) {
            assert_eq!(a.len, b_.len);
            assert_eq!(a.logits, b_.logits, "chunked decode must be bit-exact at len {}", a.len);
        }
        assert_eq!(kv.tokens(), oneshot_kv.tokens());
        // chains hold identical packed keys
        for l in 0..2 {
            for h in 0..2 {
                for i in 0..tokens.len() {
                    assert_eq!(kv.chain(l, h).key(i), oneshot_kv.chain(l, h).key(i));
                }
            }
        }
    }

    #[test]
    fn warm_turns_decode_only_the_suffix() {
        let b = backend(KvCacheConfig { page_tokens: 4, ..Default::default() });
        let mut rng = Rng::new(11);
        let tokens = toks(&mut rng, 16);
        let mut kv = b.fresh_kv();
        let (_, s1) = b.decode(&mut kv, &tokens[..10], &[10]);
        assert_eq!((s1.resumed_at, s1.decoded), (0, 10));
        let (_, s2) = b.decode(&mut kv, &tokens, &[16]);
        assert_eq!((s2.resumed_at, s2.decoded), (10, 6), "only the suffix is re-executed");
        assert!(s2.attn_us <= s2.decode_us, "attention time is a share of decode time");
    }

    #[test]
    fn kernel_and_scalar_paths_serve_identical_logits() {
        // end-to-end bit-exactness of the binarized score path: the whole
        // decode through the blocked kernel equals the scalar oracle
        let b = backend(KvCacheConfig { page_tokens: 3, ..Default::default() });
        let mut rng = Rng::new(12);
        let tokens = toks(&mut rng, 14);
        let mut kv_a = b.fresh_kv();
        let (kernel, _) = b.decode_with(&mut kv_a, &tokens, &[5, 14], AttnPath::Kernel);
        let mut kv_b = b.fresh_kv();
        let (scalar, _) = b.decode_with(&mut kv_b, &tokens, &[5, 14], AttnPath::Scalar);
        for (x, y) in kernel.iter().zip(&scalar) {
            assert_eq!(x.logits, y.logits, "kernel vs scalar at len {}", x.len);
        }
    }

    #[test]
    fn mismatched_resident_state_is_reset() {
        let b = backend(KvCacheConfig::default());
        let mut rng = Rng::new(13);
        let tokens_a = toks(&mut rng, 12);
        let mut tokens_b = toks(&mut rng, 9);
        tokens_b[0] = (tokens_a[0] + 1) % 24; // guarantee divergence at 0
        let mut kv = b.fresh_kv();
        b.decode(&mut kv, &tokens_a, &[12]);
        let (caps, stats) = b.decode(&mut kv, &tokens_b, &[9]);
        assert_eq!(stats.resumed_at, 0, "non-prefix state must reset");
        assert_eq!(kv.tokens(), &tokens_b[..]);
        assert_eq!(caps[0].logits, b.forward_logits(&tokens_b), "reset decode == fresh");
    }

    #[test]
    fn capture_at_resident_length_forces_redecode() {
        // logits AT the already-decoded length can't be produced from
        // resident pages alone (no stored hidden state): backend resets
        let b = backend(KvCacheConfig::default());
        let mut rng = Rng::new(14);
        let tokens = toks(&mut rng, 8);
        let mut kv = b.fresh_kv();
        b.decode(&mut kv, &tokens, &[8]);
        let (caps, stats) = b.decode(&mut kv, &tokens, &[8]);
        assert_eq!(stats.resumed_at, 0);
        assert_eq!(caps[0].logits, b.forward_logits(&tokens));
    }

    #[test]
    fn every_capture_matches_its_prefix_forward() {
        // causality: logits at length c from one long decode equal a
        // fresh forward of exactly c tokens
        let b = backend(KvCacheConfig { page_tokens: 5, ..Default::default() });
        let mut rng = Rng::new(15);
        let tokens = toks(&mut rng, 13);
        let mut kv = b.fresh_kv();
        let (caps, _) = b.decode(&mut kv, &tokens, &[1, 4, 9, 13]);
        assert_eq!(caps.len(), 4);
        for c in &caps {
            assert_eq!(
                c.logits,
                b.forward_logits(&tokens[..c.len]),
                "capture at {} must equal the prefix forward",
                c.len
            );
            assert_eq!(c.logits.len(), b.n_classes());
            assert!(c.logits.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn bf16_values_stay_close_to_f32() {
        let f32_b = backend(KvCacheConfig { page_tokens: 4, ..Default::default() });
        let bf_b = backend(KvCacheConfig {
            page_tokens: 4,
            value_dtype: ValueDtype::Bf16,
            ..Default::default()
        });
        let mut rng = Rng::new(16);
        let tokens = toks(&mut rng, 12);
        let a = f32_b.forward_logits(&tokens);
        let c = bf_b.forward_logits(&tokens);
        let max_diff = a
            .iter()
            .zip(&c)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        // bf16 rounding perturbs each value row by <= 2^-9 relative; the
        // perturbation passes through layernorms and stays O(1e-2) on
        // logits of O(1) at this depth
        assert!(max_diff < 0.05, "bf16 drift too large: {max_diff}");
        assert!(max_diff > 0.0, "bf16 must actually round something");
    }

    #[test]
    fn positions_wrap_beyond_trained_context() {
        // sequences longer than n_ctx reuse positions modulo n_ctx
        // (documented wrap) instead of panicking
        let b = backend(KvCacheConfig::default());
        let mut rng = Rng::new(17);
        let tokens = toks(&mut rng, 30); // n_ctx = 24
        let out = b.forward_logits(&tokens);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn rejects_unsorted_captures() {
        let b = backend(KvCacheConfig::default());
        let mut kv = b.fresh_kv();
        b.decode(&mut kv, &[1, 2, 3], &[3, 2]);
    }

    #[test]
    fn pooled_scratch_decode_is_bit_exact() {
        // reusing a buffer another decode grew must not change results
        let b = backend(KvCacheConfig { page_tokens: 4, ..Default::default() });
        let mut rng = Rng::new(18);
        let long = toks(&mut rng, 17);
        let short = toks(&mut rng, 6);
        let pool = ScratchPool::new();
        assert_eq!(pool.parked(), 0);
        let warm = pool.with(|s| {
            let mut kv = b.fresh_kv();
            b.decode_in(&mut kv, &long, &[17], AttnPath::Kernel, s)
        });
        assert_eq!(pool.parked(), 1, "buffer returned to the pool");
        let reused = pool.with(|s| {
            let mut kv = b.fresh_kv();
            b.decode_in(&mut kv, &short, &[6], AttnPath::Kernel, s)
        });
        assert_eq!(pool.parked(), 1, "grown buffer reused, not duplicated");
        assert_eq!(warm.0[0].logits, b.forward_logits(&long));
        assert_eq!(reused.0[0].logits, b.forward_logits(&short));
    }
}
