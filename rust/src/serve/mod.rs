//! CPU-native bitpacked serving backend: the real HAD transformer decode
//! over the paged KV cache.
//!
//! Until this module, the repo was "fast kernel + cache": the coordinator
//! admitted sessions through an embedding featurizer and the XNOR-popcount
//! kernel pass produced timing-only output while logits came from PJRT
//! full-sequence re-execution. `serve` closes the loop — a distilled
//! [`model::ServeModel`] (checkpoint weights + per-layer calibrated
//! `sigma_q`/`sigma_k`, paper §3.4) executes end to end on the CPU fast
//! path, and `coordinator::Server` in CPU mode returns these logits from
//! `submit`/`submit_session` directly (the PJRT engine demotes to an
//! optional cross-check).
//!
//! ## The layer loop
//!
//! [`engine::HadBackend::decode`] advances one token at a time. For
//! position `p` of a session:
//!
//! 1. **embed** — `tok_emb[token] + pos_emb[p % n_ctx]` (positions wrap
//!    past the trained context).
//! 2. per layer `l`: **pre-LN** then Q/K/V projections from the layer's
//!    de-stacked weights; per head, the new K/V rows are **binarized and
//!    appended** into that (layer, head) page chain FIRST (sign-bit
//!    packing in `kvcache::Page::push`; values at f32 or bf16), then the
//!    query row scores over the chain with
//!    `binary::had_attention_paged` — blocked XNOR-popcount with fused
//!    streaming top-N, softmax temperature `sigma_q[l] * sigma_k[l]` —
//!    which makes the attention causal (`keys 0..=p`) by construction.
//!    Head outputs concatenate, project through `wo`, and join the
//!    residual stream; the GELU MLP block follows.
//! 3. after the last layer, positions whose logits a request asked for
//!    (`capture_lens`) run the final layernorm + classification head.
//!
//! ## Per-layer KV page layout
//!
//! Session state is a [`kvcache::LayeredKv`]: `n_layers * n_heads` page
//! chains (layer-major), each chain a `kvcache::SessionKv` of fixed-size
//! pages with `d_head`-bit packed keys and `d_head` values per token,
//! advancing in lock step one row per decoded token. The decoded token
//! ids ride along, so a later turn resumes incrementally only when the
//! resident state is an id-verified prefix of its sequence — causality
//! makes that resume bit-exact (see `engine` docs) — and any mismatch
//! resets to a cold decode instead of serving stale context.
//!
//! [`reference::reference_forward`] is the naive unbinarized-f32 oracle
//! the parity suite holds the backend to.

pub mod engine;
pub mod model;
pub mod reference;

pub use engine::{AttnPath, CaptureOut, DecodeStats, HadBackend, ScratchPool};
pub use model::{demo_config, token_config_entry, LayerWeights, ServeModel};
pub use reference::reference_forward;

use crate::tensor::Mat;

/// `x @ w + b` with the bias broadcast over rows — the projection shape
/// both the decode engine and the reference forward share (same `Mat`
/// arithmetic, so per-row results are bit-identical between them).
pub(crate) fn affine(x: &Mat, w: &Mat, b: &[f32]) -> Mat {
    assert_eq!(b.len(), w.cols, "bias/width mismatch");
    let mut y = x.matmul(w);
    for r in 0..y.rows {
        for (o, &bv) in y.row_mut(r).iter_mut().zip(b) {
            *o += bv;
        }
    }
    y
}

/// `a += b`, elementwise (residual connections).
pub(crate) fn add_assign(a: &mut Mat, b: &Mat) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "residual shape mismatch");
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affine_matches_manual() {
        let x = Mat::from_vec(2, 3, vec![1.0, 0.0, 2.0, -1.0, 1.0, 0.5]);
        let w = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = [0.5, -0.5];
        let y = affine(&x, &w, &b);
        // row0: [1,0,2]@w = [11,14]; row1: [-1,1,0.5]@w = [4.5,5]; + b
        assert_eq!(y.data, vec![11.5, 13.5, 5.0, 4.5]);
    }

    #[test]
    fn add_assign_is_elementwise() {
        let mut a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![0.5, -2.0, 1.0]);
        add_assign(&mut a, &b);
        assert_eq!(a.data, vec![1.5, 0.0, 4.0]);
    }
}
