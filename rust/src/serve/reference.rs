//! Naive f32 reference forward: the parity oracle for the decode engine.
//!
//! Same architecture, same weights, same causal semantics — but computed
//! the obvious way: whole-sequence dense matrices, ±1.0 f32 sign values
//! instead of packed bits, dense dot-product scores over each query's
//! causal prefix, and `ops::softmax_topn_rows` for the top-N sparse
//! softmax (Eqs. 6-7 oracle). No bit packing, no paging, no streaming
//! selection — if `serve::HadBackend::decode` and this function agree,
//! the entire packed/paged/incremental machinery is wiring-correct.
//!
//! Binary scores of ±1 vectors are exact small integers in f32 and both
//! sides break score ties by lowest key index, so the kept sets match
//! exactly; the remaining divergence is float summation order in softmax
//! and AV accumulation (~1e-6 per attention call at test scale). The
//! parity tests document the tolerance they assert.

use crate::serve::model::ServeModel;
use crate::serve::{add_assign, affine};
use crate::tensor::{dot, ops, Mat};

#[inline]
fn sign(x: f32) -> f32 {
    // bitpack convention: bit = 1 iff x >= 0 (so sign(-0.0) == +1)
    if x >= 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Full-sequence causal forward in f32. Returns per-position logits
/// (`n x n_classes`): row `p` is the model's output after consuming
/// `tokens[..=p]` — comparable one-to-one with decode captures.
pub fn reference_forward(model: &ServeModel, tokens: &[i32]) -> Mat {
    assert!(!tokens.is_empty(), "forward over an empty sequence");
    let m = &model.cfg;
    let (n, d, dh, n_heads) = (tokens.len(), m.d_model, m.d_head(), m.n_heads);

    // embed: token rows + wrapped learned positions
    let mut h = Mat::zeros(n, d);
    for p in 0..n {
        let tok = tokens[p].rem_euclid(m.vocab as i32) as usize;
        let row = h.row_mut(p);
        for (o, (&te, &pe)) in row
            .iter_mut()
            .zip(model.tok_emb.row(tok).iter().zip(model.pos_emb.row(p % m.n_ctx)))
        {
            *o = te + pe;
        }
    }

    for (l, lw) in model.layers.iter().enumerate() {
        let x = ops::layernorm_rows(&h, &lw.ln1_g, &lw.ln1_b, 1e-5);
        let q = affine(&x, &lw.wq, &lw.bq);
        let k = affine(&x, &lw.wk, &lw.bk);
        let v = affine(&x, &lw.wv, &lw.bv);
        let scale = model.temp(l) / (dh as f32).sqrt();
        let mut ctx = Mat::zeros(n, d);
        for head in 0..n_heads {
            let col0 = head * dh;
            // sigma-standardized sign binarization of Q/K (sigma itself
            // only scales, so binarized signs are sign(q); the sigmas
            // act through the softmax temperature)
            let sq = Mat::from_fn(n, dh, |r, c| sign(q.at(r, col0 + c)));
            let sk = Mat::from_fn(n, dh, |r, c| sign(k.at(r, col0 + c)));
            for i in 0..n {
                // causal scores over keys 0..=i (exact integers in f32)
                let scores: Vec<f32> =
                    (0..=i).map(|j| dot(sq.row(i), sk.row(j))).collect();
                let row = Mat::from_vec(1, i + 1, scores);
                let probs = ops::softmax_topn_rows(&row, model.n_top, scale);
                let out = ctx.row_mut(i);
                for j in 0..=i {
                    let w = probs.at(0, j);
                    if w != 0.0 {
                        for (c, o) in out[col0..col0 + dh].iter_mut().enumerate() {
                            *o += w * v.at(j, col0 + c);
                        }
                    }
                }
            }
        }
        add_assign(&mut h, &affine(&ctx, &lw.wo, &lw.bo));
        let y = ops::layernorm_rows(&h, &lw.ln2_g, &lw.ln2_b, 1e-5);
        let mut u = affine(&y, &lw.w1, &lw.b1);
        for xv in &mut u.data {
            *xv = ops::gelu_tanh(*xv);
        }
        add_assign(&mut h, &affine(&u, &lw.w2, &lw.b2));
    }

    let hf = ops::layernorm_rows(&h, &model.lnf_g, &model.lnf_b, 1e-5);
    affine(&hf, &model.head_w, &model.head_b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvCacheConfig;
    use crate::runtime::{ConfigEntry, ModelCfg};
    use crate::serve::engine::HadBackend;
    use crate::serve::model::token_config_entry;
    use crate::util::rng::Rng;

    fn cfg_with_topn(n_top: usize) -> ConfigEntry {
        token_config_entry(
            "serve_ref",
            ModelCfg {
                n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 24,
                n_classes: 3, vocab: 24, input_dim: 0, n_top, block_q: 16,
            },
        )
    }

    fn run_parity(n_top: usize, seed: u64, n_tokens: usize, tol: f32) {
        let cfg = cfg_with_topn(n_top);
        let model = crate::serve::ServeModel::random(&cfg, seed).unwrap();
        let backend = HadBackend::new(
            model.clone(),
            &KvCacheConfig { page_tokens: 4, ..Default::default() },
        );
        let mut rng = Rng::new(seed ^ 0x5EED);
        let tokens: Vec<i32> = (0..n_tokens).map(|_| rng.below(24) as i32).collect();
        let want = reference_forward(&model, &tokens);
        // compare at several prefix lengths, through the session path
        // (two turns) so the parity also covers incremental decode
        let mut kv = backend.fresh_kv();
        let mid = n_tokens / 2;
        let (c1, _) = backend.decode(&mut kv, &tokens[..mid], &[mid]);
        let (c2, _) = backend.decode(&mut kv, &tokens, &[n_tokens]);
        for cap in c1.iter().chain(&c2) {
            let ref_row = want.row(cap.len - 1);
            let diff = cap
                .logits
                .iter()
                .zip(ref_row)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                diff < tol,
                "n_top={n_top} len={}: decode vs reference diff {diff} > {tol}",
                cap.len
            );
        }
    }

    #[test]
    fn decode_matches_reference_dense_softmax() {
        // n_top >= n_ctx: selection keeps everything, so the only
        // divergence is float summation order inside softmax/AV
        // (~1e-6 per attention call; 1e-3 documents a >100x margin).
        // Seed chosen by scripts/validate_serve_parity.py so every
        // binarized activation sits >= 4e-4 from zero — ordering noise
        // cannot flip a sign bit between the two implementations.
        run_parity(64, 35, 18, 1e-3);
    }

    #[test]
    fn decode_matches_reference_sparse_topn() {
        // sparse selection: kept sets are identical by construction
        // (integer scores + shared lowest-index tie-break), so the same
        // ordering-noise tolerance applies. Seed margin-validated like
        // the dense case (>= 2e-4 from every sign boundary).
        run_parity(6, 23, 18, 1e-3);
    }

    #[test]
    fn reference_is_causal() {
        let cfg = cfg_with_topn(8);
        let model = crate::serve::ServeModel::random(&cfg, 23).unwrap();
        let mut rng = Rng::new(99);
        let mut tokens: Vec<i32> = (0..12).map(|_| rng.below(24) as i32).collect();
        let a = reference_forward(&model, &tokens);
        // changing the future must not change the past
        tokens[11] = (tokens[11] + 7) % 24;
        let b = reference_forward(&model, &tokens);
        for p in 0..11 {
            assert_eq!(a.row(p), b.row(p), "position {p} saw the future");
        }
        assert_ne!(a.row(11), b.row(11), "the changed position must change");
    }
}
