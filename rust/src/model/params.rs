//! Parameter and optimizer-state tensors, in manifest order.
//!
//! The order contract: python's `model.param_specs(cfg)` == the manifest's
//! `params` list == `ParamSet::tensors` here. Train-step artifacts take
//! params, then Adam m, then Adam v, then the step counter — `TrainState`
//! packages exactly that.

use anyhow::{ensure, Result};

use crate::runtime::{ConfigEntry, HostTensor, Init};
use crate::util::rng::Rng;

/// One named tensor set in manifest order.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub tensors: Vec<HostTensor>,
}

impl ParamSet {
    /// Initialize per the manifest's init kinds: Normal => N(0, 0.02),
    /// matching the python reference initializer.
    pub fn init(cfg: &ConfigEntry, rng: &mut Rng) -> ParamSet {
        let tensors = cfg
            .params
            .iter()
            .map(|spec| {
                let n = spec.numel();
                let data = match spec.init {
                    Init::Normal => rng.normal_vec(n, 0.02),
                    Init::Zeros => vec![0.0; n],
                    Init::Ones => vec![1.0; n],
                };
                HostTensor::f32(spec.shape.clone(), data)
            })
            .collect();
        ParamSet { tensors }
    }

    /// All-zeros set with the same shapes (Adam moments).
    pub fn zeros_like(cfg: &ConfigEntry) -> ParamSet {
        let tensors = cfg
            .params
            .iter()
            .map(|spec| HostTensor::f32(spec.shape.clone(), vec![0.0; spec.numel()]))
            .collect();
        ParamSet { tensors }
    }

    pub fn from_tensors(cfg: &ConfigEntry, tensors: Vec<HostTensor>) -> Result<ParamSet> {
        ensure!(
            tensors.len() == cfg.params.len(),
            "expected {} tensors, got {}",
            cfg.params.len(),
            tensors.len()
        );
        for (t, spec) in tensors.iter().zip(&cfg.params) {
            ensure!(
                t.shape() == spec.shape.as_slice(),
                "tensor {} shape {:?} != spec {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
        }
        Ok(ParamSet { tensors })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(HostTensor::numel).sum()
    }

    /// Look up a tensor by name (manifest order defines the index).
    pub fn by_name<'a>(&'a self, cfg: &ConfigEntry, name: &str) -> Option<&'a HostTensor> {
        cfg.params
            .iter()
            .position(|p| p.name == name)
            .map(|i| &self.tensors[i])
    }

    /// L2 distance to another set (training-progress diagnostics).
    pub fn l2_distance(&self, other: &ParamSet) -> f32 {
        let mut acc = 0.0f64;
        for (a, b) in self.tensors.iter().zip(&other.tensors) {
            let (Ok(da), Ok(db)) = (a.as_f32(), b.as_f32()) else { continue };
            for (x, y) in da.iter().zip(db) {
                let d = (x - y) as f64;
                acc += d * d;
            }
        }
        acc.sqrt() as f32
    }
}

/// Parameters + Adam state + step counter: the mutable state a train-step
/// artifact consumes and reproduces.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: ParamSet,
    pub m: ParamSet,
    pub v: ParamSet,
    pub t: f32,
}

impl TrainState {
    pub fn new(cfg: &ConfigEntry, rng: &mut Rng) -> TrainState {
        TrainState {
            params: ParamSet::init(cfg, rng),
            m: ParamSet::zeros_like(cfg),
            v: ParamSet::zeros_like(cfg),
            t: 0.0,
        }
    }

    /// Fresh optimizer state around existing parameters (each distillation
    /// run restarts Adam, per the paper's stage transitions keeping only
    /// weights).
    pub fn from_params(cfg: &ConfigEntry, params: ParamSet) -> TrainState {
        TrainState { params, m: ParamSet::zeros_like(cfg), v: ParamSet::zeros_like(cfg), t: 0.0 }
    }

    /// Flatten into artifact input order: params*, m*, v*, t.
    pub fn to_inputs(&self) -> Vec<HostTensor> {
        let mut out = Vec::with_capacity(3 * self.params.len() + 1);
        out.extend(self.params.tensors.iter().cloned());
        out.extend(self.m.tensors.iter().cloned());
        out.extend(self.v.tensors.iter().cloned());
        out.push(HostTensor::scalar_f32(self.t));
        out
    }

    /// Rebuild from artifact outputs laid out params*, m*, v*, t, <aux...>.
    /// Returns (state, aux outputs).
    pub fn from_outputs(
        cfg: &ConfigEntry,
        outputs: Vec<HostTensor>,
    ) -> Result<(TrainState, Vec<HostTensor>)> {
        let p = cfg.params.len();
        ensure!(outputs.len() >= 3 * p + 1, "short output: {}", outputs.len());
        let mut it = outputs.into_iter();
        let params = ParamSet::from_tensors(cfg, it.by_ref().take(p).collect())?;
        let m = ParamSet::from_tensors(cfg, it.by_ref().take(p).collect())?;
        let v = ParamSet::from_tensors(cfg, it.by_ref().take(p).collect())?;
        let t = it.next().unwrap().scalar()?;
        let aux: Vec<HostTensor> = it.collect();
        Ok((TrainState { params, m, v, t }, aux))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelCfg, ParamSpec};

    fn fake_cfg() -> ConfigEntry {
        ConfigEntry {
            name: "fake".into(),
            model: ModelCfg {
                n_layers: 1, d_model: 4, n_heads: 1, d_ff: 8, n_ctx: 4,
                n_classes: 2, vocab: 8, input_dim: 0, n_top: 2, block_q: 4,
            },
            train_batch: 2,
            eval_batch: 2,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![2, 3], init: Init::Normal },
                ParamSpec { name: "b".into(), shape: vec![3], init: Init::Zeros },
                ParamSpec { name: "g".into(), shape: vec![3], init: Init::Ones },
            ],
        }
    }

    #[test]
    fn init_kinds() {
        let cfg = fake_cfg();
        let mut rng = Rng::new(0);
        let p = ParamSet::init(&cfg, &mut rng);
        assert_eq!(p.len(), 3);
        assert!(p.tensors[0].as_f32().unwrap().iter().any(|&x| x != 0.0));
        assert!(p.tensors[1].as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(p.tensors[2].as_f32().unwrap().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn train_state_roundtrip() {
        let cfg = fake_cfg();
        let mut rng = Rng::new(1);
        let st = TrainState::new(&cfg, &mut rng);
        let mut inputs = st.to_inputs();
        assert_eq!(inputs.len(), 10);
        // simulate artifact output: same tensors + 2 aux scalars
        inputs.push(HostTensor::scalar_f32(0.5));
        inputs.push(HostTensor::scalar_f32(0.9));
        let (st2, aux) = TrainState::from_outputs(&cfg, inputs).unwrap();
        assert_eq!(aux.len(), 2);
        assert_eq!(st2.params.tensors[0], st.params.tensors[0]);
        assert_eq!(st2.t, st.t);
    }

    #[test]
    fn by_name_lookup() {
        let cfg = fake_cfg();
        let mut rng = Rng::new(2);
        let p = ParamSet::init(&cfg, &mut rng);
        assert!(p.by_name(&cfg, "b").is_some());
        assert!(p.by_name(&cfg, "nope").is_none());
    }

    #[test]
    fn l2_distance_zero_for_self() {
        let cfg = fake_cfg();
        let mut rng = Rng::new(3);
        let p = ParamSet::init(&cfg, &mut rng);
        assert_eq!(p.l2_distance(&p), 0.0);
    }

    #[test]
    fn from_tensors_rejects_bad_shapes() {
        let cfg = fake_cfg();
        let bad = vec![
            HostTensor::f32(vec![3, 2], vec![0.0; 6]),
            HostTensor::f32(vec![3], vec![0.0; 3]),
            HostTensor::f32(vec![3], vec![0.0; 3]),
        ];
        assert!(ParamSet::from_tensors(&cfg, bad).is_err());
    }
}
