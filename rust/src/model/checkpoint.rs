//! Checkpoint IO: a simple self-describing binary format (no serde in
//! this environment — DESIGN.md §Substrates).
//!
//! Layout (little-endian):
//!   magic  "HADCKPT1"
//!   u32    json header length
//!   bytes  json header: {config, step, sigmas, tensor names+shapes}
//!   f32[]  tensor data back-to-back in header order
//!
//! The JSON header keeps checkpoints debuggable (`head -c 400 file`).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::model::params::ParamSet;
use crate::runtime::{ConfigEntry, HostTensor};
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"HADCKPT1";

/// Everything needed to resume / evaluate a distilled model.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub config: String,
    pub step: f32,
    /// per-layer calibrated standardization coefficients (paper §3.4)
    pub sigma_q: Vec<f32>,
    pub sigma_k: Vec<f32>,
    pub params: ParamSet,
}

pub fn save_checkpoint(path: impl AsRef<Path>, cfg: &ConfigEntry, ckpt: &Checkpoint) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let tensors = Json::arr(cfg.params.iter().map(|p| {
        Json::obj(vec![
            ("name", Json::str(p.name.clone())),
            ("shape", Json::arr(p.shape.iter().map(|&d| Json::num(d as f64)))),
        ])
    }));
    let header = Json::obj(vec![
        ("config", Json::str(ckpt.config.clone())),
        ("step", Json::num(ckpt.step as f64)),
        ("sigma_q", Json::arr(ckpt.sigma_q.iter().map(|&x| Json::num(x as f64)))),
        ("sigma_k", Json::arr(ckpt.sigma_k.iter().map(|&x| Json::num(x as f64)))),
        ("tensors", tensors),
    ])
    .to_string();

    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for t in &ckpt.params.tensors {
        let data = t.as_f32().context("checkpoint tensors must be f32")?;
        // safe byte-level serialization without unsafe: chunked copy
        let mut buf = Vec::with_capacity(data.len() * 4);
        for x in data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    f.flush()?;
    Ok(())
}

pub fn load_checkpoint(path: impl AsRef<Path>, cfg: &ConfigEntry) -> Result<Checkpoint> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "bad checkpoint magic");
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf).context("header utf8")?)
        .context("header json")?;

    let config = header.get("config").and_then(Json::as_str).context("config")?.to_string();
    ensure!(
        config == cfg.name,
        "checkpoint is for config {config:?}, expected {:?}",
        cfg.name
    );
    let step = header.get("step").and_then(Json::as_f64).context("step")? as f32;
    let sig = |k: &str| -> Result<Vec<f32>> {
        Ok(header
            .get(k)
            .and_then(Json::as_arr)
            .with_context(|| k.to_string())?
            .iter()
            .map(|x| x.as_f64().unwrap_or(1.0) as f32)
            .collect())
    };
    let sigma_q = sig("sigma_q")?;
    let sigma_k = sig("sigma_k")?;

    // validate tensor list against the manifest contract
    let tensors_j = header.get("tensors").and_then(Json::as_arr).context("tensors")?;
    ensure!(
        tensors_j.len() == cfg.params.len(),
        "checkpoint has {} tensors, config expects {}",
        tensors_j.len(),
        cfg.params.len()
    );
    let mut tensors = Vec::with_capacity(cfg.params.len());
    for (tj, spec) in tensors_j.iter().zip(&cfg.params) {
        let name = tj.get("name").and_then(Json::as_str).context("tensor name")?;
        if name != spec.name {
            bail!("tensor order mismatch: {name} vs {}", spec.name);
        }
        let n = spec.numel();
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf).with_context(|| format!("reading tensor {name}"))?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push(HostTensor::f32(spec.shape.clone(), data));
    }
    let params = ParamSet::from_tensors(cfg, tensors)?;
    Ok(Checkpoint { config, step, sigma_q, sigma_k, params })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Init, ModelCfg, ParamSpec};
    use crate::util::rng::Rng;

    fn fake_cfg() -> ConfigEntry {
        ConfigEntry {
            name: "fake".into(),
            model: ModelCfg {
                n_layers: 2, d_model: 4, n_heads: 1, d_ff: 8, n_ctx: 4,
                n_classes: 2, vocab: 8, input_dim: 0, n_top: 2, block_q: 4,
            },
            train_batch: 2,
            eval_batch: 2,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![4, 4], init: Init::Normal },
                ParamSpec { name: "b".into(), shape: vec![4], init: Init::Zeros },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let cfg = fake_cfg();
        let mut rng = Rng::new(7);
        let params = ParamSet::init(&cfg, &mut rng);
        let ckpt = Checkpoint {
            config: "fake".into(),
            step: 123.0,
            sigma_q: vec![0.5, 0.6],
            sigma_k: vec![0.7, 0.8],
            params,
        };
        let dir = std::env::temp_dir().join("had_ckpt_test");
        let path = dir.join("test.ckpt");
        save_checkpoint(&path, &cfg, &ckpt).unwrap();
        let loaded = load_checkpoint(&path, &cfg).unwrap();
        assert_eq!(loaded.step, 123.0);
        assert_eq!(loaded.sigma_q, vec![0.5, 0.6]);
        assert_eq!(loaded.params.tensors[0], ckpt.params.tensors[0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_config() {
        let cfg = fake_cfg();
        let mut rng = Rng::new(8);
        let ckpt = Checkpoint {
            config: "fake".into(),
            step: 0.0,
            sigma_q: vec![1.0; 2],
            sigma_k: vec![1.0; 2],
            params: ParamSet::init(&cfg, &mut rng),
        };
        let path = std::env::temp_dir().join("had_ckpt_test2.ckpt");
        save_checkpoint(&path, &cfg, &ckpt).unwrap();
        let mut other = fake_cfg();
        other.name = "other".into();
        assert!(load_checkpoint(&path, &other).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let path = std::env::temp_dir().join("had_ckpt_trunc.ckpt");
        std::fs::write(&path, b"HADCKPT1\x10\x00\x00\x00{}").unwrap();
        assert!(load_checkpoint(&path, &fake_cfg()).is_err());
        std::fs::remove_file(&path).ok();
    }
}
