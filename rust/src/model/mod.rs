//! Model-side state owned by Rust: parameter/optimizer tensors laid out
//! per the manifest contract, initialization, and checkpoint IO.

pub mod checkpoint;
pub mod params;

pub use checkpoint::{load_checkpoint, save_checkpoint, Checkpoint};
pub use params::{ParamSet, TrainState};
