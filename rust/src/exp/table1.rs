//! Table 1: tinyGLUE benchmark — Baseline / HAD / BiT / w-SAB / w-o-AD /
//! w-o-Tanh across the eight task analogs (MNLI reported
//! matched/mismatched like the paper).

use anyhow::Result;

use super::common::{distill_and_eval, make_eval_batches, prepare_teacher, SuiteOptions};
use crate::data::tinyglue::{GlueGen, GlueTask};
use crate::data::token_batch;
use crate::distill::Method;
use crate::runtime::Runtime;
use crate::util::json::Json;

pub const CONFIG: &str = "tinyglue";

/// One table row: task name -> metric per method column.
#[derive(Clone, Debug)]
pub struct Row {
    pub task: String,
    pub cells: Vec<(Method, String, f32)>, // (method, rendered, value)
}

pub fn run(rt: &Runtime, opts: &SuiteOptions, tasks: Option<Vec<GlueTask>>) -> Result<Vec<Row>> {
    let cfg = rt.manifest.config(CONFIG)?;
    let n_ctx = cfg.model.n_ctx;
    let tb = cfg.train_batch;
    let n_top = cfg.model.n_top as f32;
    let tasks = tasks.unwrap_or_else(|| GlueTask::ALL.to_vec());

    let mut rows = Vec::new();
    for task in tasks {
        let gen = GlueGen::new(task);
        let mut train = |rng: &mut crate::util::rng::Rng| token_batch(&gen, rng, tb, n_ctx);
        let teacher = prepare_teacher(rt, CONFIG, opts, &mut train)?;
        let eval_gen = GlueGen::new(task);
        let evals = make_eval_batches(opts, opts.eval_batches, |rng| {
            token_batch(&eval_gen, rng, tb, n_ctx)
        });
        // MNLI also gets a mismatched-domain eval set
        let mm_gen = GlueGen::mismatched(task);
        let evals_mm = if task == GlueTask::Mnli {
            Some(make_eval_batches(opts, opts.eval_batches, |rng| {
                token_batch(&mm_gen, rng, tb, n_ctx)
            }))
        } else {
            None
        };

        let mut cells = Vec::new();
        for method in Method::TABLE_COLUMNS {
            let (ev, ckpt) =
                distill_and_eval(rt, CONFIG, method, &teacher, opts, n_top, &mut train, &evals)?;
            let metric = ev.metric(task.metric());
            let rendered = if let Some(mm) = &evals_mm {
                // matched/mismatched pair, like the paper's MNLI cells
                let ev_mm = crate::distill::evaluate(
                    rt, cfg, method.fwd_artifact(), &ckpt, mm, n_top,
                )?;
                format!("{metric:.2}/{:.2}", ev_mm.metric(task.metric()))
            } else {
                format!("{metric:.2}")
            };
            println!("[table1] {} / {:<12} {} = {rendered}", task.name(), method.label(), task.metric());
            opts.record(
                "table1",
                Json::obj(vec![
                    ("task", Json::str(task.name())),
                    ("method", Json::str(method.label())),
                    ("metric", Json::str(task.metric())),
                    ("value", Json::num(metric as f64)),
                ]),
            )?;
            cells.push((method, rendered, metric));
        }
        rows.push(Row { task: task.name().to_string(), cells });
    }
    print_table(&rows);
    Ok(rows)
}

pub fn print_table(rows: &[Row]) {
    println!("\n=== Table 1 (tinyGLUE analog) ===");
    print!("{:<10}", "Benchmark");
    for m in Method::TABLE_COLUMNS {
        print!(" {:>12}", m.label());
    }
    println!();
    let mut sums = vec![0.0f32; Method::TABLE_COLUMNS.len()];
    for row in rows {
        print!("{:<10}", row.task);
        for (i, (_m, cell, v)) in row.cells.iter().enumerate() {
            print!(" {cell:>12}");
            sums[i] += v;
        }
        println!();
    }
    print!("{:<10}", "Avg");
    for s in &sums {
        print!(" {:>12.2}", s / rows.len().max(1) as f32);
    }
    println!();
}
