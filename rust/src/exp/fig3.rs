//! Figure 3: accuracy while PROGRESSIVELY distilling a full-precision
//! student with decreasing top-N (vision_tiny subject, as in the paper).
//!
//! One continuous run: the student keeps training as N steps down through
//! the sweep; accuracy is measured at the end of each N segment. Runtime
//! n_top makes this a single-artifact experiment.

use anyhow::Result;

use super::common::{make_eval_batches, prepare_teacher, SuiteOptions};
use crate::data::vision::vision_batch;
use crate::distill::{evaluate, Method, Pipeline};
use crate::model::Checkpoint;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::rng::Rng;

pub const CONFIG: &str = "vision_tiny";
/// Decreasing N sweep (context is 65): the paper swept 100 -> ~1 on a
/// 197-token DeiT; scaled to our context.
pub const N_SWEEP: [usize; 9] = [64, 48, 32, 24, 16, 10, 6, 3, 1];

pub fn run(rt: &Runtime, opts: &SuiteOptions) -> Result<Vec<(usize, f32)>> {
    let cfg = rt.manifest.config(CONFIG)?;
    let tb = cfg.train_batch;
    let mut train = |rng: &mut Rng| vision_batch(rng, tb);
    let teacher = prepare_teacher(rt, CONFIG, opts, &mut train)?;
    let evals = make_eval_batches(opts, opts.eval_batches, |rng| vision_batch(rng, tb));

    // Progressive distillation: continue from the previous student.
    let pipeline = Pipeline::new(rt, cfg, opts.schedule());
    let mut rng = Rng::new(opts.seed ^ 0xF16_3);
    let mut params = teacher.params.clone();
    let mut series = Vec::new();
    for n_top in N_SWEEP {
        let outcome = pipeline.distill(
            Method::FpTopn,
            &params,
            &teacher.sigma_q,
            &teacher.sigma_k,
            n_top as f32,
            &mut rng,
            &mut train,
        )?;
        params = outcome.student.params.clone();
        let ckpt = Checkpoint {
            config: CONFIG.into(),
            step: outcome.student.step,
            sigma_q: teacher.sigma_q.clone(),
            sigma_k: teacher.sigma_k.clone(),
            params: params.clone(),
        };
        let ev = evaluate(rt, cfg, Method::FpTopn.fwd_artifact(), &ckpt, &evals, n_top as f32)?;
        let acc = ev.metric("accuracy");
        println!("[fig3] N={n_top:<3} accuracy={acc:.2}");
        opts.record(
            "fig3",
            Json::obj(vec![
                ("n_top", Json::num(n_top as f64)),
                ("accuracy", Json::num(acc as f64)),
            ]),
        )?;
        series.push((n_top, acc));
    }
    println!("\n=== Figure 3 (accuracy vs N, progressive FP distillation) ===");
    for (n, acc) in &series {
        println!("N={n:<4} {acc:6.2}  {}", bar(*acc));
    }
    Ok(series)
}

fn bar(acc: f32) -> String {
    let n = (acc / 2.0).round().max(0.0) as usize;
    "#".repeat(n.min(60))
}
