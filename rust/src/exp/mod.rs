//! Experiment harnesses: one module per table/figure in the paper's
//! evaluation section (DESIGN.md §7 maps each to its workload).
//!
//! Every harness prints the paper-shaped rows to stdout and appends a
//! JSON record under results/ for EXPERIMENTS.md bookkeeping.

pub mod common;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod table1;
pub mod table2;
pub mod table3;

pub use common::SuiteOptions;
