//! Figure 4: for standard-Gaussian softmax inputs of size n, what
//! fraction of the largest outputs is needed to reach a given probability
//! mass? The paper's §3.2 long-context scaling argument: the fraction
//! approaches a constant as n grows, justifying N ∝ n.
//!
//! Pure math — reproduced exactly (no substitution needed).

use anyhow::Result;

use super::common::SuiteOptions;
use crate::util::json::Json;
use crate::util::rng::Rng;

pub const THRESHOLDS: [f64; 3] = [0.50, 0.90, 0.99];

/// (n, per-threshold fraction-of-elements-needed)
pub fn run(opts: &SuiteOptions) -> Result<Vec<(usize, Vec<f64>)>> {
    let mut rng = Rng::new(opts.seed ^ 0xF164);
    let sizes: Vec<usize> = (4..=14).map(|p| 1usize << p).collect();
    let trials = 32;
    let mut out = Vec::new();
    for &n in &sizes {
        let mut fracs = vec![0.0f64; THRESHOLDS.len()];
        for _ in 0..trials {
            // softmax of n standard normals
            let mut logits: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
            let max = logits.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let mut sum = 0.0;
            for x in logits.iter_mut() {
                *x = (*x - max).exp();
                sum += *x;
            }
            for x in logits.iter_mut() {
                *x /= sum;
            }
            logits.sort_by(|a, b| b.partial_cmp(a).unwrap());
            // count largest elements to reach each threshold
            for (ti, &thresh) in THRESHOLDS.iter().enumerate() {
                let mut acc = 0.0;
                let mut count = 0usize;
                for &p in &logits {
                    acc += p;
                    count += 1;
                    if acc >= thresh {
                        break;
                    }
                }
                fracs[ti] += count as f64 / n as f64 / trials as f64;
            }
        }
        opts.record(
            "fig4",
            Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("fractions", Json::arr(fracs.iter().map(|&f| Json::num(f)))),
            ]),
        )?;
        out.push((n, fracs));
    }

    println!("\n=== Figure 4 (softmax mass concentration, Gaussian inputs) ===");
    print!("{:>8}", "n");
    for t in THRESHOLDS {
        print!(" {:>10}", format!("p>={t}"));
    }
    println!();
    for (n, fracs) in &out {
        print!("{n:>8}");
        for f in fracs {
            print!(" {:>9.2}%", 100.0 * f);
        }
        println!();
    }
    println!("(fractions approach a constant: N should scale linearly with n)");
    Ok(out)
}

/// The asymptotic check used by tests and EXPERIMENTS.md: the fraction at
/// the two largest n differ by less than `tol` relative.
pub fn converged(series: &[(usize, Vec<f64>)], ti: usize, tol: f64) -> bool {
    if series.len() < 2 {
        return false;
    }
    let a = series[series.len() - 2].1[ti];
    let b = series[series.len() - 1].1[ti];
    ((a - b) / a).abs() < tol
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_converge_to_constant() {
        let opts = SuiteOptions {
            results_dir: std::env::temp_dir().join("had_fig4_test"),
            ..Default::default()
        };
        let series = run(&opts).unwrap();
        // mass concentrates: 50% threshold needs well under half the
        // elements, and the needed FRACTION stabilizes with n
        let (_, last) = series.last().unwrap();
        assert!(last[0] < 0.25, "50% mass from <25% of elements: {last:?}");
        assert!(converged(&series, 0, 0.15), "p50 fraction converged");
        assert!(converged(&series, 1, 0.15), "p90 fraction converged");
        std::fs::remove_dir_all(std::env::temp_dir().join("had_fig4_test")).ok();
    }
}
