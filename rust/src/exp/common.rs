//! Shared experiment plumbing: options, result persistence, and the
//! teacher→calibrate→distill→evaluate loop reused by the table/figure
//! harnesses.

use anyhow::Result;

use crate::data::Batch;
use crate::distill::{evaluate, Budget, EvalResult, Method, Pipeline, Schedule};
use crate::model::{Checkpoint, ParamSet};
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Knobs shared by all harnesses.
#[derive(Clone, Debug)]
pub struct SuiteOptions {
    /// multiplies every stage budget (1.0 = testbed reference budget;
    /// EXPERIMENTS.md records the scale used per run)
    pub scale: f64,
    /// separate multiplier for teacher pre-training (teachers need more
    /// steps than distillation to actually learn the task; a weak teacher
    /// makes every method column identical)
    pub teacher_scale: f64,
    pub seed: u64,
    /// eval batches per measurement
    pub eval_batches: usize,
    /// sigma-calibration minibatches (paper: 100)
    pub calib_batches: usize,
    /// distillation learning rate (paper: 1e-5 at BERT scale; the testbed
    /// reference is higher because runs are ~100x shorter)
    pub lr: f32,
    pub teacher_lr: f32,
    /// where result JSON lines are appended
    pub results_dir: std::path::PathBuf,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        SuiteOptions {
            scale: 1.0,
            teacher_scale: 1.0,
            seed: 0x4AD,
            eval_batches: 16,
            calib_batches: 20,
            lr: 5e-4,
            teacher_lr: 2e-3,
            results_dir: std::path::PathBuf::from("results"),
        }
    }
}

impl SuiteOptions {
    pub fn budget(&self) -> Budget {
        let mut b = Budget::default().scaled(self.scale);
        b.teacher = ((Budget::default().teacher as f64 * self.teacher_scale).round() as usize).max(1);
        b
    }

    pub fn schedule(&self) -> Schedule {
        Schedule::new(self.budget(), self.lr)
    }

    /// Append one JSON record to results/<name>.jsonl.
    pub fn record(&self, name: &str, payload: Json) -> Result<()> {
        std::fs::create_dir_all(&self.results_dir)?;
        let path = self.results_dir.join(format!("{name}.jsonl"));
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        writeln!(f, "{payload}")?;
        Ok(())
    }
}

/// A trained teacher plus its calibration — the starting point every
/// method distills from (shared across methods for a fair comparison).
pub struct TeacherBundle {
    pub params: ParamSet,
    pub sigma_q: Vec<f32>,
    pub sigma_k: Vec<f32>,
    pub train_acc: f32,
}

/// Train + calibrate a teacher on one task.
pub fn prepare_teacher(
    rt: &Runtime,
    config: &str,
    opts: &SuiteOptions,
    batches: &mut dyn FnMut(&mut Rng) -> Batch,
) -> Result<TeacherBundle> {
    let cfg = rt.manifest.config(config)?;
    let mut pipeline = Pipeline::new(rt, cfg, opts.schedule());
    pipeline.teacher_lr = opts.teacher_lr;
    let mut rng = Rng::new(opts.seed);
    let (params, train_acc) = pipeline.train_teacher(&mut rng, batches)?;
    let (sigma_q, sigma_k) =
        pipeline.calibrate_sigma(&params, &mut rng, batches, opts.calib_batches)?;
    Ok(TeacherBundle { params, sigma_q, sigma_k, train_acc })
}

/// Distill one method from a prepared teacher and evaluate it.
/// Returns (eval result, checkpoint).
#[allow(clippy::too_many_arguments)]
pub fn distill_and_eval(
    rt: &Runtime,
    config: &str,
    method: Method,
    teacher: &TeacherBundle,
    opts: &SuiteOptions,
    n_top: f32,
    train_batches: &mut dyn FnMut(&mut Rng) -> Batch,
    eval_batches: &[Batch],
) -> Result<(EvalResult, Checkpoint)> {
    let cfg = rt.manifest.config(config)?;
    if method == Method::Baseline {
        let ckpt = Checkpoint {
            config: config.to_string(),
            step: 0.0,
            sigma_q: teacher.sigma_q.clone(),
            sigma_k: teacher.sigma_k.clone(),
            params: teacher.params.clone(),
        };
        let ev = evaluate(rt, cfg, method.fwd_artifact(), &ckpt, eval_batches, n_top)?;
        return Ok((ev, ckpt));
    }
    let pipeline = Pipeline::new(rt, cfg, opts.schedule());
    let mut rng = Rng::new(opts.seed ^ ((method as u64) << 8) ^ 0x9E37);
    let outcome = pipeline.distill(
        method,
        &teacher.params,
        &teacher.sigma_q,
        &teacher.sigma_k,
        n_top,
        &mut rng,
        train_batches,
    )?;
    let ev = evaluate(rt, cfg, method.fwd_artifact(), &outcome.student, eval_batches, n_top)?;
    Ok((ev, outcome.student))
}

/// Deterministic eval set, disjoint seed stream from training.
pub fn make_eval_batches(
    opts: &SuiteOptions,
    n: usize,
    mut f: impl FnMut(&mut Rng) -> Batch,
) -> Vec<Batch> {
    let mut rng = Rng::new(opts.seed ^ 0xE7A1);
    (0..n).map(|_| f(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_scale_budget() {
        let mut o = SuiteOptions::default();
        o.scale = 0.5;
        o.teacher_scale = 0.5;
        assert_eq!(o.budget(), Budget::default().scaled(0.5));
        // teacher budget is scaled independently (weak teachers make all
        // method columns identical — DESIGN.md §10)
        o.teacher_scale = 1.0;
        assert_eq!(o.budget().teacher, Budget::default().teacher);
        assert_eq!(o.budget().stage1, Budget::default().scaled(0.5).stage1);
    }
}
