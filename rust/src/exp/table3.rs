//! Table 3: hardware area/power comparison (SA vs HAD attention head),
//! straight from the hwsim component model, plus the context-scaling
//! energy sweep the model enables.

use anyhow::Result;

use super::common::SuiteOptions;
use crate::hwsim::{breakdown, context_sweep, Design, Tech, Workload};
use crate::util::json::Json;

pub fn run(opts: &SuiteOptions) -> Result<()> {
    let tech = Tech::default();
    println!("\n=== Table 3 (hardware: SA vs HAD attention head) ===");
    print!("{}", crate::hwsim::table3_text(&tech));

    let sa = breakdown(Design::Standard, Workload::paper(), &tech);
    let had = breakdown(Design::Had, Workload::paper(), &tech);
    opts.record(
        "table3",
        Json::obj(vec![
            ("sa_area_mm2", Json::num(sa.total_area())),
            ("had_area_mm2", Json::num(had.total_area())),
            ("sa_power_w", Json::num(sa.total_power())),
            ("had_power_w", Json::num(had.total_power())),
            ("sa_energy_nj", Json::num(sa.energy_per_query_nj(&tech))),
            ("had_energy_nj", Json::num(had.energy_per_query_nj(&tech))),
        ]),
    )?;

    println!("\nContext-scaling sweep (N ∝ n, energy per query):");
    println!("{:>8} {:>12} {:>12} {:>10}", "n_ctx", "SA nJ", "HAD nJ", "ratio");
    for (n, sa_nj, had_nj, _) in context_sweep(&tech, &[128, 256, 512, 1024, 2048, 4096]) {
        println!("{n:>8} {sa_nj:>12.2} {had_nj:>12.2} {:>9.1}x", sa_nj / had_nj);
    }
    Ok(())
}
