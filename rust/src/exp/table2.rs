//! Table 2: vision benchmark (ImageNet/DeiT analog) — base and tiny
//! encoder sizes across the six method columns.

use anyhow::Result;

use super::common::{distill_and_eval, make_eval_batches, prepare_teacher, SuiteOptions};
use crate::data::vision::vision_batch;
use crate::distill::Method;
use crate::runtime::Runtime;
use crate::util::json::Json;

pub const CONFIGS: [&str; 2] = ["vision_base", "vision_tiny"];

#[derive(Clone, Debug)]
pub struct Column {
    pub config: String,
    pub accs: Vec<(Method, f32)>,
}

pub fn run(rt: &Runtime, opts: &SuiteOptions, only: Option<&str>) -> Result<Vec<Column>> {
    let mut cols = Vec::new();
    for config in CONFIGS {
        if let Some(f) = only {
            if !config.contains(f) {
                continue;
            }
        }
        let cfg = rt.manifest.config(config)?;
        let tb = cfg.train_batch;
        let n_top = cfg.model.n_top as f32;
        let mut train = |rng: &mut crate::util::rng::Rng| vision_batch(rng, tb);
        let teacher = prepare_teacher(rt, config, opts, &mut train)?;
        let evals = make_eval_batches(opts, opts.eval_batches, |rng| vision_batch(rng, tb));

        let mut accs = Vec::new();
        for method in Method::TABLE_COLUMNS {
            let (ev, _) =
                distill_and_eval(rt, config, method, &teacher, opts, n_top, &mut train, &evals)?;
            let acc = ev.metric("accuracy");
            println!("[table2] {config} / {:<12} acc = {acc:.2}", method.label());
            opts.record(
                "table2",
                Json::obj(vec![
                    ("config", Json::str(config)),
                    ("method", Json::str(method.label())),
                    ("accuracy", Json::num(acc as f64)),
                ]),
            )?;
            accs.push((method, acc));
        }
        cols.push(Column { config: config.to_string(), accs });
    }
    print_table(&cols);
    Ok(cols)
}

pub fn print_table(cols: &[Column]) {
    println!("\n=== Table 2 (vision analog) ===");
    print!("{:<12}", "");
    for c in cols {
        print!(" {:>12}", c.config);
    }
    println!();
    if cols.is_empty() {
        return;
    }
    for (i, (method, _)) in cols[0].accs.iter().enumerate() {
        print!("{:<12}", method.label());
        for c in cols {
            print!(" {:>12.2}", c.accs[i].1);
        }
        println!();
    }
}
