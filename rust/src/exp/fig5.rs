//! Figure 5: long-context QA accuracy vs context length — baseline
//! (full-precision teacher) vs HAD student, with N scaled linearly in
//! context (15 @ 128 ... 120 @ 1024, the paper's rule).

use anyhow::Result;

use super::common::{distill_and_eval, make_eval_batches, prepare_teacher, SuiteOptions};
use crate::data::longqa::{longqa_batch, LongQaGen};
use crate::distill::Method;
use crate::runtime::Runtime;
use crate::util::json::Json;

pub const CONTEXTS: [usize; 4] = [128, 256, 512, 1024];

#[derive(Clone, Debug)]
pub struct Point {
    pub n_ctx: usize,
    pub n_top: usize,
    pub baseline: f32,
    pub had: f32,
}

pub fn run(rt: &Runtime, opts: &SuiteOptions, only: Option<usize>) -> Result<Vec<Point>> {
    let mut points = Vec::new();
    for n_ctx in CONTEXTS {
        if let Some(f) = only {
            if n_ctx != f {
                continue;
            }
        }
        let config = format!("longqa_{n_ctx}");
        let cfg = rt.manifest.config(&config)?;
        let tb = cfg.train_batch;
        let n_top = cfg.model.n_top as f32;
        let gen = LongQaGen::new(n_ctx);
        let mut train = |rng: &mut crate::util::rng::Rng| longqa_batch(&gen, rng, tb);
        let teacher = prepare_teacher(rt, &config, opts, &mut train)?;
        let eval_gen = LongQaGen::new(n_ctx);
        let evals = make_eval_batches(opts, opts.eval_batches, |rng| {
            longqa_batch(&eval_gen, rng, tb)
        });

        let (base_ev, _) = distill_and_eval(
            rt, &config, Method::Baseline, &teacher, opts, n_top, &mut train, &evals,
        )?;
        let (had_ev, _) = distill_and_eval(
            rt, &config, Method::Had, &teacher, opts, n_top, &mut train, &evals,
        )?;
        let p = Point {
            n_ctx,
            n_top: cfg.model.n_top,
            baseline: base_ev.metric("accuracy"),
            had: had_ev.metric("accuracy"),
        };
        println!(
            "[fig5] n_ctx={n_ctx:<5} N={:<4} baseline={:.2} HAD={:.2}",
            p.n_top, p.baseline, p.had
        );
        opts.record(
            "fig5",
            Json::obj(vec![
                ("n_ctx", Json::num(n_ctx as f64)),
                ("n_top", Json::num(p.n_top as f64)),
                ("baseline", Json::num(p.baseline as f64)),
                ("had", Json::num(p.had as f64)),
            ]),
        )?;
        points.push(p);
    }

    println!("\n=== Figure 5 (QuALITY analog: accuracy vs context) ===");
    println!("{:>8} {:>6} {:>10} {:>10} {:>8}", "n_ctx", "N", "Baseline", "HAD", "gap");
    for p in &points {
        println!(
            "{:>8} {:>6} {:>10.2} {:>10.2} {:>8.2}",
            p.n_ctx,
            p.n_top,
            p.baseline,
            p.had,
            p.baseline - p.had
        );
    }
    Ok(points)
}
