//! Figure 1: encoder latency with and without attention vs context
//! length, plus the attention share of total runtime.
//!
//! Measured on the CPU-PJRT executables (fwd_standard_b1 vs fwd_noattn_b1
//! per longqa length), plus an analytic FLOP model extrapolating beyond
//! the compiled lengths. The paper's claim is the SHAPE: attention share
//! grows toward dominance as context rises (O(n^2) vs O(n)).

use std::time::Instant;

use anyhow::Result;

use super::common::SuiteOptions;
use crate::data::longqa::{longqa_batch, LongQaGen};
use crate::model::ParamSet;
use crate::runtime::{HostTensor, Runtime};
use crate::util::json::Json;
use crate::util::rng::Rng;

pub const CONTEXTS: [usize; 4] = [128, 256, 512, 1024];

#[derive(Clone, Debug)]
pub struct Point {
    pub n_ctx: usize,
    pub full_ms: f64,
    pub noattn_ms: f64,
    pub had_ms: f64,
    /// fraction of full-model latency attributable to attention
    pub attn_share: f64,
}

fn bench_artifact(
    rt: &Runtime,
    config: &str,
    artifact: &str,
    x: &HostTensor,
    params: &ParamSet,
    n_layers: usize,
    n_top: f32,
    reps: usize,
) -> Result<f64> {
    let exe = rt.load(&format!("{config}__{artifact}"))?;
    let mut inputs: Vec<HostTensor> = params.tensors.clone();
    inputs.push(x.clone());
    inputs.push(HostTensor::vec_f32(vec![1.0; n_layers]));
    inputs.push(HostTensor::vec_f32(vec![1.0; n_layers]));
    inputs.push(HostTensor::scalar_f32(n_top));
    // warmup
    exe.run(&inputs)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        exe.run(&inputs)?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3 / reps as f64)
}

pub fn run(rt: &Runtime, opts: &SuiteOptions, reps: usize) -> Result<Vec<Point>> {
    let mut rng = Rng::new(opts.seed ^ 0xF161);
    let mut points = Vec::new();
    for n_ctx in CONTEXTS {
        let config = format!("longqa_{n_ctx}");
        let cfg = rt.manifest.config(&config)?;
        let params = ParamSet::init(cfg, &mut rng);
        let gen = LongQaGen::new(n_ctx);
        let batch = longqa_batch(&gen, &mut rng, 1);
        let l = cfg.model.n_layers;
        let n_top = cfg.model.n_top as f32;

        let full_ms = bench_artifact(rt, &config, "fwd_standard_b1", &batch.x, &params, l, n_top, reps)?;
        let noattn_ms = bench_artifact(rt, &config, "fwd_noattn_b1", &batch.x, &params, l, n_top, reps)?;
        let had_ms = bench_artifact(rt, &config, "fwd_had_b1", &batch.x, &params, l, n_top, reps)?;
        let attn_share = ((full_ms - noattn_ms) / full_ms).max(0.0);
        println!(
            "[fig1] n={n_ctx:<5} full={full_ms:.2}ms noattn={noattn_ms:.2}ms had={had_ms:.2}ms attn-share={:.1}%",
            100.0 * attn_share
        );
        opts.record(
            "fig1",
            Json::obj(vec![
                ("n_ctx", Json::num(n_ctx as f64)),
                ("full_ms", Json::num(full_ms)),
                ("noattn_ms", Json::num(noattn_ms)),
                ("had_ms", Json::num(had_ms)),
                ("attn_share", Json::num(attn_share)),
            ]),
        )?;
        points.push(Point { n_ctx, full_ms, noattn_ms, had_ms, attn_share });
    }

    println!("\n=== Figure 1 (latency w/ and w/o attention vs context) ===");
    println!(
        "{:>8} {:>10} {:>12} {:>10} {:>12}",
        "n_ctx", "full ms", "no-attn ms", "HAD ms", "attn share"
    );
    for p in &points {
        println!(
            "{:>8} {:>10.2} {:>12.2} {:>10.2} {:>11.1}%",
            p.n_ctx,
            p.full_ms,
            p.noattn_ms,
            p.had_ms,
            100.0 * p.attn_share
        );
    }
    println!("\nAnalytic FLOP model (per token, d={}, layers as compiled):", 64);
    analytic_model(&points);
    Ok(points)
}

/// O(n^2 d) attention vs O(n d^2 + n d_ff d) rest — the asymptotic story
/// extrapolated to contexts beyond the compiled buckets.
fn analytic_model(points: &[Point]) {
    let d = 64.0f64;
    let dff = 128.0f64;
    println!("{:>8} {:>14} {:>14} {:>12}", "n_ctx", "attn FLOPs", "other FLOPs", "attn share");
    for &n in &[128usize, 256, 512, 1024, 2048, 4096, 8192] {
        let nf = n as f64;
        let attn = 2.0 * nf * nf * d * 2.0; // QK^T + AV per layer
        let other = nf * (8.0 * d * d + 4.0 * d * dff);
        let share = attn / (attn + other);
        println!("{n:>8} {attn:>14.3e} {other:>14.3e} {:>11.1}%", 100.0 * share);
    }
    let _ = points;
}
