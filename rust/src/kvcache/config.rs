//! Sizing knobs for the paged KV cache.

use crate::binary::bitpack::words_for;

/// Configuration of the paged bit-packed KV cache.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Tokens per page. Pages are allocated at full capacity up front so
    /// byte accounting is exact and appends never reallocate.
    pub page_tokens: usize,
    /// Total resident-byte budget of the pool across all sessions; the
    /// pool evicts least-recently-used sessions to stay under it.
    pub byte_budget: usize,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            page_tokens: 64,
            byte_budget: 32 * 1024 * 1024,
        }
    }
}

impl KvCacheConfig {
    /// Payload bytes of one full page for the given head geometry:
    /// packed sign-bit keys (`ceil(d/64)` u64 words/token) + f32 values.
    pub fn page_payload_bytes(&self, d: usize, d_v: usize) -> usize {
        self.page_tokens * (words_for(d) * 8 + d_v * 4)
    }

    /// How many full pages fit the byte budget for one head geometry
    /// (capacity planning for admission control).
    pub fn pages_in_budget(&self, d: usize, d_v: usize) -> usize {
        self.byte_budget / self.page_payload_bytes(d, d_v).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_payload_math() {
        let cfg = KvCacheConfig { page_tokens: 64, byte_budget: 1 << 20 };
        // d=64: one u64 word per key -> 8 B/token; d_v=64 f32 -> 256 B/token
        assert_eq!(cfg.page_payload_bytes(64, 64), 64 * (8 + 256));
        // ragged d=65 needs two words
        assert_eq!(cfg.page_payload_bytes(65, 64), 64 * (16 + 256));
    }

    #[test]
    fn budget_capacity() {
        let cfg = KvCacheConfig { page_tokens: 64, byte_budget: 64 * (8 + 256) * 10 };
        assert_eq!(cfg.pages_in_budget(64, 64), 10);
    }
}
