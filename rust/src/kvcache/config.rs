//! Sizing knobs for the paged KV cache.

use crate::binary::bitpack::words_for;

/// Storage precision of the value rows inside KV pages. Keys are always
/// packed sign bits; values default to f32 and can be halved to bf16
/// (`util::bf16`, round-to-nearest-even on append) — the paper binarizes
/// only Q/K, so value residency is the remaining dense cost the ROADMAP
/// calls out.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ValueDtype {
    #[default]
    F32,
    Bf16,
}

impl ValueDtype {
    /// Bytes one value element occupies at rest.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            ValueDtype::F32 => 4,
            ValueDtype::Bf16 => 2,
        }
    }
}

/// Configuration of the paged bit-packed KV cache.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Tokens per page. Pages are allocated at full capacity up front so
    /// byte accounting is exact and appends never reallocate.
    pub page_tokens: usize,
    /// Total resident-byte budget of the pool across all sessions; the
    /// pool evicts least-recently-used sessions to stay under it.
    pub byte_budget: usize,
    /// Precision of stored value rows (keys are always 1-bit packed).
    pub value_dtype: ValueDtype,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            page_tokens: 64,
            byte_budget: 32 * 1024 * 1024,
            value_dtype: ValueDtype::F32,
        }
    }
}

impl KvCacheConfig {
    /// Payload bytes of one full page for the given head geometry:
    /// packed sign-bit keys (`ceil(d/64)` u64 words/token) plus values at
    /// the configured precision.
    pub fn page_payload_bytes(&self, d: usize, d_v: usize) -> usize {
        self.page_tokens * (words_for(d) * 8 + d_v * self.value_dtype.bytes_per_elem())
    }

    /// How many full pages fit the byte budget for one head geometry
    /// (capacity planning for admission control).
    pub fn pages_in_budget(&self, d: usize, d_v: usize) -> usize {
        self.byte_budget / self.page_payload_bytes(d, d_v).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_payload_math() {
        let cfg = KvCacheConfig { page_tokens: 64, byte_budget: 1 << 20, ..Default::default() };
        // d=64: one u64 word per key -> 8 B/token; d_v=64 f32 -> 256 B/token
        assert_eq!(cfg.page_payload_bytes(64, 64), 64 * (8 + 256));
        // ragged d=65 needs two words
        assert_eq!(cfg.page_payload_bytes(65, 64), 64 * (16 + 256));
    }

    #[test]
    fn bf16_halves_value_payload() {
        let f32_cfg = KvCacheConfig { page_tokens: 64, byte_budget: 1 << 20, ..Default::default() };
        let bf16_cfg = KvCacheConfig { value_dtype: ValueDtype::Bf16, ..f32_cfg };
        assert_eq!(bf16_cfg.page_payload_bytes(64, 64), 64 * (8 + 128));
        // key payload is dtype-independent
        assert_eq!(
            f32_cfg.page_payload_bytes(64, 64) - bf16_cfg.page_payload_bytes(64, 64),
            64 * 128
        );
    }

    #[test]
    fn budget_capacity() {
        let cfg = KvCacheConfig {
            page_tokens: 64,
            byte_budget: 64 * (8 + 256) * 10,
            ..Default::default()
        };
        assert_eq!(cfg.pages_in_budget(64, 64), 10);
    }
}
