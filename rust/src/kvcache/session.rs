//! Per-session KV state: an append-only (plus rollback) chain of pages
//! supporting incremental prefill and decode. Turn N appends only its new
//! tokens; the packed keys of turns 0..N stay resident and are re-scored
//! in place by `binary::attention::had_attention_paged`.

use std::sync::Arc;

use crate::kvcache::config::ValueDtype;
use crate::kvcache::page::{Page, SealedPage};
use crate::tensor::Mat;

/// One session's paged KV cache for a single head geometry.
#[derive(Clone, Debug)]
pub struct SessionKv {
    d: usize,
    d_v: usize,
    page_tokens: usize,
    value_dtype: ValueDtype,
    pages: Vec<Page>,
    len: usize,
    sealed: bool,
}

impl SessionKv {
    pub fn new(d: usize, d_v: usize, page_tokens: usize) -> SessionKv {
        SessionKv::new_with(d, d_v, page_tokens, ValueDtype::F32)
    }

    /// Like `new` with an explicit value precision (bf16 halves value
    /// residency; keys are packed sign bits either way).
    pub fn new_with(d: usize, d_v: usize, page_tokens: usize, dtype: ValueDtype) -> SessionKv {
        assert!(page_tokens > 0, "page_tokens must be positive");
        SessionKv {
            d,
            d_v,
            page_tokens,
            value_dtype: dtype,
            pages: Vec::new(),
            len: 0,
            sealed: false,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn d_v(&self) -> usize {
        self.d_v
    }

    #[inline]
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    #[inline]
    pub fn value_dtype(&self) -> ValueDtype {
        self.value_dtype
    }

    pub fn pages(&self) -> &[Page] {
        &self.pages
    }

    /// Mutable access to page `p` — the spill tier drops and restores
    /// page payloads through this (`LayeredKv` stripe operations).
    pub fn page_mut(&mut self, p: usize) -> &mut Page {
        &mut self.pages[p]
    }

    /// Incremental decode: binarize-pack and append ONE token's key/value
    /// rows (the serving backend's per-token unit of work).
    pub fn append_row(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert!(!self.sealed, "append to sealed session");
        assert_eq!(k_row.len(), self.d, "key dim mismatch");
        assert_eq!(v_row.len(), self.d_v, "value dim mismatch");
        if self.pages.last().map_or(true, Page::is_full) {
            self.pages
                .push(Page::new_with(self.page_tokens, self.d, self.d_v, self.value_dtype));
        }
        self.pages.last_mut().unwrap().push(k_row, v_row);
        self.len += 1;
    }

    /// Incremental prefill/decode: binarize-pack and append `k.rows` new
    /// tokens. Only the appended rows are packed — resident pages are
    /// untouched (the warm-path saving the kvcache bench measures).
    pub fn append(&mut self, k: &Mat, v: &Mat) {
        assert!(!self.sealed, "append to sealed session");
        assert_eq!(k.rows, v.rows, "K/V length mismatch");
        for r in 0..k.rows {
            self.append_row(k.row(r), v.row(r));
        }
    }

    /// Prefix adoption: append one FULL page referencing an
    /// already-sealed shared payload instead of packing its rows. The
    /// chain must sit exactly at a page boundary (a shared page can only
    /// extend a whole-page prefix) and the payload must match the chain's
    /// geometry.
    pub fn adopt_shared_page(&mut self, payload: Arc<SealedPage>) {
        assert!(!self.sealed, "append to sealed session");
        assert_eq!(self.len % self.page_tokens, 0, "adopt off a page boundary");
        assert_eq!(
            self.pages.len(),
            self.len / self.page_tokens,
            "adopt over a partial tail page"
        );
        assert_eq!(payload.capacity(), self.page_tokens, "page_tokens mismatch");
        self.pages.push(Page::adopt_shared(payload));
        self.len += self.page_tokens;
    }

    /// Freeze the session: no further appends (end of conversation; the
    /// pool may still evict it).
    pub fn seal(&mut self) {
        self.sealed = true;
    }

    #[inline]
    pub fn is_sealed(&self) -> bool {
        self.sealed
    }

    /// Roll back to `len` tokens, dropping now-empty pages (speculative
    /// decode rollback; also the bench's warm-turn reset).
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate beyond length");
        let full_pages = len / self.page_tokens;
        let tail = len % self.page_tokens;
        let keep = if tail == 0 { full_pages } else { full_pages + 1 };
        self.pages.truncate(keep);
        if tail != 0 {
            if let Some(last) = self.pages.last_mut() {
                last.truncate(tail);
            }
        }
        self.len = len;
    }

    /// Packed key words of global token `i`.
    #[inline]
    pub fn key(&self, i: usize) -> &[u64] {
        debug_assert!(i < self.len);
        self.pages[i / self.page_tokens].key(i % self.page_tokens)
    }

    /// f32 value row of global token `i` (f32-valued sessions only; see
    /// `accum_value` for the dtype-independent hot path).
    #[inline]
    pub fn value(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        self.pages[i / self.page_tokens].value(i % self.page_tokens)
    }

    /// `orow += w * value_row(i)`, page-resolved, decoding bf16 inline.
    #[inline]
    pub fn accum_value(&self, i: usize, w: f32, orow: &mut [f32]) {
        debug_assert!(i < self.len);
        self.pages[i / self.page_tokens].accum_value(i % self.page_tokens, w, orow);
    }

    /// Decode token `i`'s value row into `out` (tests/oracles).
    pub fn value_into(&self, i: usize, out: &mut [f32]) {
        debug_assert!(i < self.len);
        self.pages[i / self.page_tokens].value_into(i % self.page_tokens, out);
    }

    /// Resident payload bytes across all pages (page-granular: partially
    /// filled pages count at full capacity).
    pub fn bytes(&self) -> usize {
        self.pages.iter().map(Page::bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binary::bitpack::PackedMat;
    use crate::util::bf16::bf16_round;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::random(r, c, rng, 1.0)
    }

    #[test]
    fn chunked_appends_match_contiguous_pack() {
        let mut rng = Rng::new(11);
        let (d, d_v, page_tokens) = (65, 8, 7); // ragged dim, odd page size
        let mut kv = SessionKv::new(d, d_v, page_tokens);
        let k = rand_mat(&mut rng, 23, d);
        let v = rand_mat(&mut rng, 23, d_v);
        // append in uneven chunks: 23 = 5 + 1 + 17
        let chunk = |m: &Mat, lo: usize, hi: usize| {
            Mat::from_vec(hi - lo, m.cols, m.data[lo * m.cols..hi * m.cols].to_vec())
        };
        for (lo, hi) in [(0usize, 5usize), (5, 6), (6, 23)] {
            kv.append(&chunk(&k, lo, hi), &chunk(&v, lo, hi));
        }
        assert_eq!(kv.len(), 23);
        assert_eq!(kv.pages().len(), 23usize.div_ceil(7));
        let reference = PackedMat::pack(23, d, &k.data);
        for i in 0..23 {
            assert_eq!(kv.key(i), reference.row(i), "token {i}");
            assert_eq!(kv.value(i), v.row(i), "token {i}");
        }
    }

    #[test]
    fn append_row_equals_append() {
        let mut rng = Rng::new(12);
        let (d, d_v) = (33, 4);
        let k = rand_mat(&mut rng, 9, d);
        let v = rand_mat(&mut rng, 9, d_v);
        let mut bulk = SessionKv::new(d, d_v, 4);
        bulk.append(&k, &v);
        let mut rowwise = SessionKv::new(d, d_v, 4);
        for r in 0..9 {
            rowwise.append_row(k.row(r), v.row(r));
        }
        assert_eq!(rowwise.len(), bulk.len());
        for i in 0..9 {
            assert_eq!(rowwise.key(i), bulk.key(i));
            assert_eq!(rowwise.value(i), bulk.value(i));
        }
    }

    #[test]
    fn bf16_session_rounds_values_and_shrinks_bytes() {
        let mut rng = Rng::new(13);
        let (d, d_v, page_tokens) = (64, 16, 8);
        let k = rand_mat(&mut rng, 10, d);
        let v = rand_mat(&mut rng, 10, d_v);
        let mut f32_kv = SessionKv::new(d, d_v, page_tokens);
        let mut bf_kv = SessionKv::new_with(d, d_v, page_tokens, ValueDtype::Bf16);
        f32_kv.append(&k, &v);
        bf_kv.append(&k, &v);
        assert_eq!(bf_kv.value_dtype(), ValueDtype::Bf16);
        // same page count, half the value bytes
        assert_eq!(f32_kv.pages().len(), bf_kv.pages().len());
        assert_eq!(f32_kv.bytes() - bf_kv.bytes(), 2 * page_tokens * d_v * 2);
        let mut row = vec![0.0f32; d_v];
        for i in 0..10 {
            assert_eq!(f32_kv.key(i), bf_kv.key(i), "keys are dtype-independent");
            bf_kv.value_into(i, &mut row);
            for (got, &x) in row.iter().zip(v.row(i)) {
                assert_eq!(*got, bf16_round(x), "token {i}");
            }
        }
    }

    #[test]
    fn truncate_drops_pages_and_allows_reappend() {
        let mut rng = Rng::new(3);
        let mut kv = SessionKv::new(32, 4, 8);
        let k = rand_mat(&mut rng, 20, 32);
        let v = rand_mat(&mut rng, 20, 4);
        kv.append(&k, &v);
        assert_eq!(kv.pages().len(), 3);
        kv.truncate(16);
        assert_eq!((kv.len(), kv.pages().len()), (16, 2));
        kv.truncate(5);
        assert_eq!((kv.len(), kv.pages().len()), (5, 1));
        let k2 = rand_mat(&mut rng, 4, 32);
        let v2 = rand_mat(&mut rng, 4, 4);
        kv.append(&k2, &v2);
        assert_eq!(kv.len(), 9);
        assert_eq!(kv.key(5), PackedMat::pack(4, 32, &k2.data).row(0));
        kv.truncate(0);
        assert!(kv.is_empty() && kv.pages().is_empty());
    }

    #[test]
    fn bytes_grow_page_granular() {
        let mut rng = Rng::new(5);
        let mut kv = SessionKv::new(64, 16, 16);
        assert_eq!(kv.bytes(), 0);
        kv.append(&rand_mat(&mut rng, 1, 64), &rand_mat(&mut rng, 1, 16));
        let one_page = 16 * (8 + 16 * 4);
        assert_eq!(kv.bytes(), one_page);
        kv.append(&rand_mat(&mut rng, 15, 64), &rand_mat(&mut rng, 15, 16));
        assert_eq!(kv.bytes(), one_page);
        kv.append(&rand_mat(&mut rng, 1, 64), &rand_mat(&mut rng, 1, 16));
        assert_eq!(kv.bytes(), 2 * one_page);
    }

    #[test]
    fn adopt_shared_page_reads_like_the_private_original() {
        let mut rng = Rng::new(21);
        let (d, d_v, pt) = (32, 4, 4);
        let k = rand_mat(&mut rng, pt, d);
        let v = rand_mat(&mut rng, pt, d_v);
        let mut source = SessionKv::new(d, d_v, pt);
        source.append(&k, &v);
        let payload = source.page_mut(0).seal_shared();

        let mut kv = SessionKv::new(d, d_v, pt);
        kv.adopt_shared_page(Arc::clone(&payload));
        assert_eq!(kv.len(), pt);
        assert_eq!(kv.bytes(), 0, "adopted pages account zero private bytes");
        for i in 0..pt {
            assert_eq!(kv.key(i), source.key(i));
            assert_eq!(kv.value(i), source.value(i));
        }
        // The chain keeps growing privately past the shared page.
        kv.append_row(k.row(0), v.row(0));
        assert_eq!(kv.len(), pt + 1);
        assert!(kv.bytes() > 0);
    }

    #[test]
    #[should_panic(expected = "adopt off a page boundary")]
    fn adopt_rejects_partial_tail() {
        let mut rng = Rng::new(22);
        let (d, d_v, pt) = (16, 2, 4);
        let mut source = SessionKv::new(d, d_v, pt);
        source.append(&rand_mat(&mut rng, pt, d), &rand_mat(&mut rng, pt, d_v));
        let payload = source.page_mut(0).seal_shared();
        let mut kv = SessionKv::new(d, d_v, pt);
        kv.append_row(&vec![1.0; d], &vec![0.5; d_v]);
        kv.adopt_shared_page(payload);
    }

    #[test]
    #[should_panic(expected = "sealed")]
    fn sealed_rejects_append() {
        let mut kv = SessionKv::new(8, 2, 4);
        kv.seal();
        kv.append(&Mat::zeros(1, 8), &Mat::zeros(1, 2));
    }
}
