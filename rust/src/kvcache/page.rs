//! A fixed-size KV page: packed sign-bit keys + values (f32 or bf16) for
//! up to `capacity` tokens. Pages are the unit of pool accounting and of
//! the non-contiguous layout `had_attention_paged` scores over.

use std::sync::Arc;

use crate::binary::bitpack::{pack_vector, words_for};
use crate::kvcache::config::ValueDtype;
use crate::util::bf16::{bf16_bits_to_f32, f32_to_bf16_bits};

/// Value rows at rest. F32 keeps rows borrowable as `&[f32]`; Bf16 halves
/// residency and decodes on the fly in `accum_value`/`value_into` (there
/// is deliberately no borrowable f32 view of a bf16 page — decoding into
/// a hidden buffer would silently double the residency the mode exists
/// to halve).
#[derive(Clone, Debug)]
enum Values {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

/// The immutable payload of one sealed (full) page, shared between
/// sessions behind an `Arc`: N streams over the same prompt reference ONE
/// copy of its packed keys and values instead of N private copies. A
/// sealed page is always full (`capacity` rows) and always resident —
/// spilling a shared entry is the prefix registry's job, done when the
/// last reference drops, never while a session still reads it.
#[derive(Clone, Debug)]
pub struct SealedPage {
    d: usize,
    words_per_key: usize,
    d_v: usize,
    capacity: usize,
    keys: Vec<u64>,
    values: Values,
}

impl SealedPage {
    /// Heap bytes of the shared payload (accounted once, in the registry,
    /// regardless of how many sessions reference it).
    pub fn bytes(&self) -> usize {
        let value_bytes = match &self.values {
            Values::F32(vs) => vs.len() * 4,
            Values::Bf16(vs) => vs.len() * 2,
        };
        self.keys.len() * 8 + value_bytes
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Append the payload to `out`: all key words (u64 LE), then all
    /// value elements in the page's dtype (LE) — the same layout as
    /// [`Page::encode_payload`] for a full page.
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.reserve(self.bytes());
        for w in &self.keys {
            out.extend_from_slice(&w.to_le_bytes());
        }
        match &self.values {
            Values::F32(vs) => {
                for x in vs {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Values::Bf16(vs) => {
                for x in vs {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Rebuild a sealed page from [`SealedPage::encode`] bytes, consuming
    /// exactly its payload from the front of `buf` and returning the
    /// remainder. Geometry comes from the caller (the adopting cache) so
    /// a record can never decode into the wrong shape.
    pub fn decode(
        buf: &[u8],
        capacity: usize,
        d: usize,
        d_v: usize,
        dtype: ValueDtype,
    ) -> Result<(SealedPage, &[u8]), String> {
        let words_per_key = words_for(d);
        let kw = capacity * words_per_key;
        let need = kw * 8 + capacity * d_v * dtype.bytes_per_elem();
        if buf.len() < need {
            return Err(format!("sealed page short: need {need} B, have {}", buf.len()));
        }
        let mut keys = vec![0u64; kw];
        for (slot, c) in keys.iter_mut().zip(buf[..kw * 8].chunks_exact(8)) {
            *slot = u64::from_le_bytes(c.try_into().unwrap());
        }
        let vbytes = &buf[kw * 8..need];
        let values = match dtype {
            ValueDtype::F32 => {
                let mut vs = vec![0.0f32; capacity * d_v];
                for (slot, c) in vs.iter_mut().zip(vbytes.chunks_exact(4)) {
                    *slot = f32::from_le_bytes(c.try_into().unwrap());
                }
                Values::F32(vs)
            }
            ValueDtype::Bf16 => {
                let mut vs = vec![0u16; capacity * d_v];
                for (slot, c) in vs.iter_mut().zip(vbytes.chunks_exact(2)) {
                    *slot = u16::from_le_bytes(c.try_into().unwrap());
                }
                Values::Bf16(vs)
            }
        };
        Ok((SealedPage { d, words_per_key, d_v, capacity, keys, values }, &buf[need..]))
    }
}

/// One page of KV state. Storage is allocated at full capacity on
/// construction, so `bytes()` is constant over the page's lifetime and
/// appends never move memory (slices handed out stay valid).
///
/// A page can be **spilled**: `drop_payload` frees the key/value storage
/// leaving a zero-byte shell (geometry and `len` intact) whose bytes
/// live in the disk spill tier, and `restore_payload` rebuilds it
/// bit-identically. Attention never touches a non-resident page — the
/// pool hydrates at checkout, before any decode.
///
/// A full page can also be **shared**: its payload moves behind an
/// `Arc<SealedPage>` referenced by any number of sessions, reads go
/// through the shared payload bit-identically, and `bytes()` reports 0
/// (the prefix registry accounts shared bytes exactly once). Mutation of
/// a shared page (partial truncate) requires [`Page::make_owned`] first —
/// copy-on-write, driven by `LayeredKv`.
#[derive(Clone, Debug)]
pub struct Page {
    d: usize,
    words_per_key: usize,
    d_v: usize,
    capacity: usize,
    len: usize,
    /// capacity * words_per_key packed sign words, filled up to len rows.
    /// Empty while the payload is shared.
    keys: Vec<u64>,
    /// capacity * d_v value elements, filled up to len rows. Empty while
    /// the payload is shared.
    values: Values,
    /// False while the payload lives only in the spill tier.
    resident: bool,
    /// When set, reads resolve through this shared payload and the owned
    /// vectors above are empty.
    shared: Option<Arc<SealedPage>>,
}

impl Page {
    pub fn new(capacity: usize, d: usize, d_v: usize) -> Page {
        Page::new_with(capacity, d, d_v, ValueDtype::F32)
    }

    pub fn new_with(capacity: usize, d: usize, d_v: usize, dtype: ValueDtype) -> Page {
        assert!(capacity > 0, "page capacity must be positive");
        assert!(d > 0, "key dim must be positive");
        let words_per_key = words_for(d);
        let values = match dtype {
            ValueDtype::F32 => Values::F32(vec![0.0f32; capacity * d_v]),
            ValueDtype::Bf16 => Values::Bf16(vec![0u16; capacity * d_v]),
        };
        Page {
            d,
            words_per_key,
            d_v,
            capacity,
            len: 0,
            keys: vec![0u64; capacity * words_per_key],
            values,
            resident: true,
            shared: None,
        }
    }

    /// A full page referencing an already-sealed shared payload (prefix
    /// adoption: the session gains `capacity` tokens of KV without
    /// packing or copying anything).
    pub fn adopt_shared(payload: Arc<SealedPage>) -> Page {
        let values = match payload.values {
            Values::F32(_) => Values::F32(Vec::new()),
            Values::Bf16(_) => Values::Bf16(Vec::new()),
        };
        Page {
            d: payload.d,
            words_per_key: payload.words_per_key,
            d_v: payload.d_v,
            capacity: payload.capacity,
            len: payload.capacity,
            keys: Vec::new(),
            values,
            resident: true,
            shared: Some(payload),
        }
    }

    /// Move this full, resident page's payload behind an `Arc<SealedPage>`
    /// (publication into the prefix registry). The page keeps reading the
    /// same bits through the shared payload; its owned storage is freed,
    /// so `bytes()` drops to 0 and the registry accounts the copy once.
    pub fn seal_shared(&mut self) -> Arc<SealedPage> {
        assert!(self.resident, "seal of an evicted page");
        assert!(self.shared.is_none(), "page already shared");
        assert!(self.is_full(), "only full pages are sealed for sharing");
        let keys = std::mem::take(&mut self.keys);
        let empty = match &self.values {
            Values::F32(_) => Values::F32(Vec::new()),
            Values::Bf16(_) => Values::Bf16(Vec::new()),
        };
        let values = std::mem::replace(&mut self.values, empty);
        let arc = Arc::new(SealedPage {
            d: self.d,
            words_per_key: self.words_per_key,
            d_v: self.d_v,
            capacity: self.capacity,
            keys,
            values,
        });
        self.shared = Some(Arc::clone(&arc));
        arc
    }

    /// Replace this full, resident page's payload with an existing shared
    /// one (dedup at publication: the bits are identical by construction —
    /// same token prefix, same packing config — so the private copy is
    /// dropped and the registry copy referenced instead).
    pub fn replace_with_shared(&mut self, payload: Arc<SealedPage>) {
        assert!(self.resident, "share of an evicted page");
        assert!(self.shared.is_none(), "page already shared");
        assert!(self.is_full(), "only full pages are shared");
        assert!(
            payload.capacity == self.capacity && payload.d == self.d && payload.d_v == self.d_v,
            "shared payload geometry mismatch"
        );
        self.keys = Vec::new();
        self.values = match &self.values {
            Values::F32(_) => Values::F32(Vec::new()),
            Values::Bf16(_) => Values::Bf16(Vec::new()),
        };
        self.shared = Some(payload);
    }

    /// Copy-on-write: materialize a private copy of the shared payload so
    /// the page can be mutated (divergence/truncate inside a shared
    /// stripe). Bit-identical — reads before and after see the same data.
    /// No-op on an already-owned page.
    pub fn make_owned(&mut self) {
        let Some(s) = self.shared.take() else { return };
        self.keys = s.keys.clone();
        self.values = s.values.clone();
    }

    /// True while the payload is shared with the prefix registry.
    #[inline]
    pub fn is_shared(&self) -> bool {
        self.shared.is_some()
    }

    /// The shared payload, when this page references one.
    pub fn shared_payload(&self) -> Option<&Arc<SealedPage>> {
        self.shared.as_ref()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.len == self.capacity
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn words_per_key(&self) -> usize {
        self.words_per_key
    }

    #[inline]
    pub fn value_dtype(&self) -> ValueDtype {
        match self.values {
            Values::F32(_) => ValueDtype::F32,
            Values::Bf16(_) => ValueDtype::Bf16,
        }
    }

    /// The packed key words reads resolve against — the shared payload's
    /// when one is referenced, the page's own otherwise.
    #[inline]
    fn keys_buf(&self) -> &[u64] {
        match &self.shared {
            Some(s) => &s.keys,
            None => &self.keys,
        }
    }

    /// The value storage reads resolve against (shared or owned).
    #[inline]
    fn values_buf(&self) -> &Values {
        match &self.shared {
            Some(s) => &s.values,
            None => &self.values,
        }
    }

    /// Append one token's key (continuous f32, binarized here) and value
    /// (rounded to the page's value dtype).
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert!(self.resident, "push into an evicted page");
        assert!(self.shared.is_none(), "push into a shared page (make_owned first)");
        assert!(!self.is_full(), "page overflow");
        assert_eq!(k_row.len(), self.d, "key dim mismatch");
        assert_eq!(v_row.len(), self.d_v, "value dim mismatch");
        let w = self.words_per_key;
        pack_vector(k_row, &mut self.keys[self.len * w..(self.len + 1) * w]);
        let (lo, hi) = (self.len * self.d_v, (self.len + 1) * self.d_v);
        match &mut self.values {
            Values::F32(vs) => vs[lo..hi].copy_from_slice(v_row),
            Values::Bf16(vs) => {
                for (slot, &x) in vs[lo..hi].iter_mut().zip(v_row) {
                    *slot = f32_to_bf16_bits(x);
                }
            }
        }
        self.len += 1;
    }

    /// Packed sign words of token `i`'s key.
    #[inline]
    pub fn key(&self, i: usize) -> &[u64] {
        debug_assert!(i < self.len);
        debug_assert!(self.resident, "key read from an evicted page");
        &self.keys_buf()[i * self.words_per_key..(i + 1) * self.words_per_key]
    }

    /// All packed key words of the filled rows as one contiguous block
    /// (`len * words_per_key` words) — the tile the blocked kernel
    /// streams so a resident page is touched once per query block.
    #[inline]
    pub fn keys_packed(&self) -> &[u64] {
        debug_assert!(self.resident, "keys_packed on an evicted page");
        &self.keys_buf()[..self.len * self.words_per_key]
    }

    /// f32 value row of token `i`. Only f32 pages have borrowable rows —
    /// use `accum_value`/`value_into` for dtype-independent access.
    #[inline]
    pub fn value(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        match self.values_buf() {
            Values::F32(vs) => &vs[i * self.d_v..(i + 1) * self.d_v],
            Values::Bf16(_) => panic!("bf16 pages have no borrowable f32 rows"),
        }
    }

    /// `orow += w * value_row(i)` — the AV-accumulation primitive every
    /// attention path uses, decoding bf16 inline. For a given (i, w,
    /// orow) the f32 path performs exactly the arithmetic the old
    /// slice-based loop did, so f32 outputs are unchanged.
    #[inline]
    pub fn accum_value(&self, i: usize, w: f32, orow: &mut [f32]) {
        debug_assert!(i < self.len);
        let (lo, hi) = (i * self.d_v, (i + 1) * self.d_v);
        match self.values_buf() {
            Values::F32(vs) => {
                for (o, &v) in orow.iter_mut().zip(&vs[lo..hi]) {
                    *o += w * v;
                }
            }
            Values::Bf16(vs) => {
                for (o, &bits) in orow.iter_mut().zip(&vs[lo..hi]) {
                    *o += w * bf16_bits_to_f32(bits);
                }
            }
        }
    }

    /// Decode token `i`'s value row into `out` (tests/oracles).
    pub fn value_into(&self, i: usize, out: &mut [f32]) {
        debug_assert!(i < self.len);
        assert_eq!(out.len(), self.d_v, "value dim mismatch");
        let (lo, hi) = (i * self.d_v, (i + 1) * self.d_v);
        match self.values_buf() {
            Values::F32(vs) => out.copy_from_slice(&vs[lo..hi]),
            Values::Bf16(vs) => {
                for (o, &bits) in out.iter_mut().zip(&vs[lo..hi]) {
                    *o = bf16_bits_to_f32(bits);
                }
            }
        }
    }

    /// Roll back to `len` tokens (decode rollback / bench reset).
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate beyond length");
        assert!(
            self.resident || len == 0,
            "partial truncate of an evicted page (hydrate first, or drop the whole stripe)"
        );
        assert!(
            self.shared.is_none() || len == self.len,
            "partial truncate of a shared page (make_owned first, or drop the whole page)"
        );
        self.len = len;
    }

    /// Resident payload bytes (full capacity — allocation, not fill).
    /// Zero while the payload is spilled to disk, and zero while it is
    /// shared (the prefix registry accounts the shared copy once).
    pub fn bytes(&self) -> usize {
        if !self.resident || self.shared.is_some() {
            return 0;
        }
        let value_bytes = match &self.values {
            Values::F32(vs) => vs.len() * 4,
            Values::Bf16(vs) => vs.len() * 2,
        };
        self.keys.len() * 8 + value_bytes
    }

    /// True unless the payload has been spilled to disk.
    #[inline]
    pub fn is_resident(&self) -> bool {
        self.resident
    }

    fn value_elem_bytes(&self) -> usize {
        match self.values {
            Values::F32(_) => 4,
            Values::Bf16(_) => 2,
        }
    }

    /// Exact size of this page's spill payload (filled rows only).
    pub fn payload_len(&self) -> usize {
        self.len * self.words_per_key * 8 + self.len * self.d_v * self.value_elem_bytes()
    }

    /// Append the filled rows' payload to `out`: `len * words_per_key`
    /// key words (u64 LE), then `len * d_v` value elements in the page's
    /// dtype (LE). Geometry is not encoded — the shell keeps it, so
    /// restore is shape-checked against the page itself.
    pub fn encode_payload(&self, out: &mut Vec<u8>) {
        assert!(self.resident, "encode of an evicted page");
        out.reserve(self.payload_len());
        for w in &self.keys_buf()[..self.len * self.words_per_key] {
            out.extend_from_slice(&w.to_le_bytes());
        }
        match self.values_buf() {
            Values::F32(vs) => {
                for x in &vs[..self.len * self.d_v] {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Values::Bf16(vs) => {
                for x in &vs[..self.len * self.d_v] {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Free the key/value storage, leaving a zero-byte shell. The caller
    /// owns the spilled bytes (see `store::SpillStore`).
    pub fn drop_payload(&mut self) {
        assert!(self.resident, "double spill of a page");
        assert!(self.shared.is_none(), "spill of a shared page (the registry owns its spill)");
        self.resident = false;
        self.keys = Vec::new();
        self.values = match self.values {
            Values::F32(_) => Values::F32(Vec::new()),
            Values::Bf16(_) => Values::Bf16(Vec::new()),
        };
    }

    /// Rebuild the payload from bytes produced by [`Page::encode_payload`],
    /// consuming exactly [`Page::payload_len`] bytes from the front of
    /// `buf` and returning the remainder. Bit-identical: pushes after
    /// restore behave as if the page never left RAM.
    pub fn restore_payload<'a>(&mut self, buf: &'a [u8]) -> Result<&'a [u8], String> {
        if self.resident {
            return Err("restore into a resident page".to_string());
        }
        let need = self.payload_len();
        if buf.len() < need {
            return Err(format!("stripe payload short: need {need} B, have {}", buf.len()));
        }
        let kw = self.len * self.words_per_key;
        let mut keys = vec![0u64; self.capacity * self.words_per_key];
        for (slot, c) in keys[..kw].iter_mut().zip(buf[..kw * 8].chunks_exact(8)) {
            *slot = u64::from_le_bytes(c.try_into().unwrap());
        }
        let vbytes = &buf[kw * 8..need];
        let values = match self.values {
            Values::F32(_) => {
                let mut vs = vec![0.0f32; self.capacity * self.d_v];
                for (slot, c) in vs[..self.len * self.d_v].iter_mut().zip(vbytes.chunks_exact(4)) {
                    *slot = f32::from_le_bytes(c.try_into().unwrap());
                }
                Values::F32(vs)
            }
            Values::Bf16(_) => {
                let mut vs = vec![0u16; self.capacity * self.d_v];
                for (slot, c) in vs[..self.len * self.d_v].iter_mut().zip(vbytes.chunks_exact(2)) {
                    *slot = u16::from_le_bytes(c.try_into().unwrap());
                }
                Values::Bf16(vs)
            }
        };
        self.keys = keys;
        self.values = values;
        self.resident = true;
        Ok(&buf[need..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binary::bitpack::PackedMat;
    use crate::util::bf16::bf16_round;
    use crate::util::rng::Rng;

    #[test]
    fn push_then_read_matches_packedmat() {
        let mut rng = Rng::new(1);
        for d in [3usize, 32, 64, 65, 100, 128] {
            let d_v = 8;
            let n = 5;
            let ks = rng.normal_vec(n * d, 1.0);
            let vs = rng.normal_vec(n * d_v, 1.0);
            let mut page = Page::new(8, d, d_v);
            for i in 0..n {
                page.push(&ks[i * d..(i + 1) * d], &vs[i * d_v..(i + 1) * d_v]);
            }
            assert_eq!(page.len(), n);
            assert!(!page.is_full());
            let reference = PackedMat::pack(n, d, &ks);
            for i in 0..n {
                assert_eq!(page.key(i), reference.row(i), "d={d} token {i}");
                assert_eq!(page.value(i), &vs[i * d_v..(i + 1) * d_v]);
            }
        }
    }

    #[test]
    fn keys_packed_is_the_concatenation_of_rows() {
        let mut rng = Rng::new(2);
        for d in [16usize, 64, 65] {
            let n = 6;
            let ks = rng.normal_vec(n * d, 1.0);
            let mut page = Page::new(8, d, 4);
            for i in 0..n {
                page.push(&ks[i * d..(i + 1) * d], &[0.0; 4]);
            }
            let block = page.keys_packed();
            assert_eq!(block.len(), n * page.words_per_key());
            for i in 0..n {
                let w = page.words_per_key();
                assert_eq!(&block[i * w..(i + 1) * w], page.key(i), "d={d} row {i}");
            }
        }
    }

    #[test]
    fn bytes_constant_over_fill() {
        let mut page = Page::new(16, 64, 32);
        let before = page.bytes();
        assert_eq!(before, 16 * 8 + 16 * 32 * 4);
        page.push(&[1.0; 64], &[0.5; 32]);
        assert_eq!(page.bytes(), before);
    }

    #[test]
    fn bf16_page_halves_value_bytes_and_rounds_rows() {
        let mut rng = Rng::new(3);
        let (d, d_v) = (64usize, 16usize);
        let mut f32_page = Page::new(8, d, d_v);
        let mut bf_page = Page::new_with(8, d, d_v, ValueDtype::Bf16);
        assert_eq!(bf_page.value_dtype(), ValueDtype::Bf16);
        assert_eq!(f32_page.bytes() - bf_page.bytes(), 8 * d_v * 2);
        let k = rng.normal_vec(d, 1.0);
        let v = rng.normal_vec(d_v, 1.0);
        f32_page.push(&k, &v);
        bf_page.push(&k, &v);
        // keys are identical; values round-trip through bf16
        assert_eq!(f32_page.key(0), bf_page.key(0));
        let mut row = vec![0.0f32; d_v];
        bf_page.value_into(0, &mut row);
        for (got, &x) in row.iter().zip(&v) {
            assert_eq!(*got, bf16_round(x));
        }
        // accum_value accumulates the rounded row
        let mut acc = vec![0.0f32; d_v];
        bf_page.accum_value(0, 0.5, &mut acc);
        for (a, &r) in acc.iter().zip(&row) {
            assert_eq!(*a, 0.5 * r);
        }
    }

    #[test]
    fn f32_accum_value_matches_slice_loop() {
        let mut rng = Rng::new(4);
        let (d, d_v) = (32usize, 8usize);
        let mut page = Page::new(4, d, d_v);
        let k = rng.normal_vec(d, 1.0);
        let v = rng.normal_vec(d_v, 1.0);
        page.push(&k, &v);
        let w = 0.37f32;
        let mut via_accum = vec![0.25f32; d_v];
        page.accum_value(0, w, &mut via_accum);
        let mut via_slice = vec![0.25f32; d_v];
        for (o, &x) in via_slice.iter_mut().zip(page.value(0)) {
            *o += w * x;
        }
        assert_eq!(via_accum, via_slice);
    }

    #[test]
    #[should_panic(expected = "no borrowable f32 rows")]
    fn bf16_page_rejects_f32_borrow() {
        let mut page = Page::new_with(2, 8, 2, ValueDtype::Bf16);
        page.push(&[1.0; 8], &[0.5; 2]);
        let _ = page.value(0);
    }

    #[test]
    fn fills_to_capacity() {
        let mut page = Page::new(3, 16, 4);
        for _ in 0..3 {
            page.push(&[-1.0; 16], &[0.0; 4]);
        }
        assert!(page.is_full());
        page.truncate(1);
        assert_eq!(page.len(), 1);
        page.push(&[1.0; 16], &[1.0; 4]);
        assert_eq!(page.len(), 2);
    }

    #[test]
    #[should_panic(expected = "page overflow")]
    fn overflow_panics() {
        let mut page = Page::new(1, 8, 2);
        page.push(&[1.0; 8], &[0.0; 2]);
        page.push(&[1.0; 8], &[0.0; 2]);
    }

    #[test]
    fn spill_roundtrip_is_bit_identical() {
        let mut rng = Rng::new(5);
        for dtype in [ValueDtype::F32, ValueDtype::Bf16] {
            let (d, d_v, cap) = (65usize, 16usize, 4usize);
            let mut page = Page::new_with(cap, d, d_v, dtype);
            for _ in 0..cap {
                page.push(&rng.normal_vec(d, 1.0), &rng.normal_vec(d_v, 1.0));
            }
            let before = page.clone();
            let mut payload = Vec::new();
            page.encode_payload(&mut payload);
            assert_eq!(payload.len(), page.payload_len());

            page.drop_payload();
            assert!(!page.is_resident());
            assert_eq!(page.bytes(), 0, "evicted shell accounts zero bytes");
            assert_eq!(page.len(), cap, "shell keeps its length");

            let rest = page.restore_payload(&payload).unwrap();
            assert!(rest.is_empty());
            assert!(page.is_resident());
            assert_eq!(page.bytes(), before.bytes());
            for i in 0..cap {
                assert_eq!(page.key(i), before.key(i), "{dtype:?} key {i}");
                let (mut a, mut b) = (vec![0.0; d_v], vec![0.0; d_v]);
                page.value_into(i, &mut a);
                before.value_into(i, &mut b);
                assert_eq!(a, b, "{dtype:?} value {i}");
            }
        }
    }

    fn filled_page(rng: &mut Rng, dtype: ValueDtype, cap: usize, d: usize, d_v: usize) -> Page {
        let mut page = Page::new_with(cap, d, d_v, dtype);
        for _ in 0..cap {
            page.push(&rng.normal_vec(d, 1.0), &rng.normal_vec(d_v, 1.0));
        }
        page
    }

    fn assert_same_rows(a: &Page, b: &Page, tag: &str) {
        assert_eq!(a.len(), b.len(), "{tag} len");
        for i in 0..a.len() {
            assert_eq!(a.key(i), b.key(i), "{tag} key {i}");
        }
    }

    #[test]
    fn seal_adopt_reads_are_bit_identical_and_account_zero() {
        let mut rng = Rng::new(7);
        for dtype in [ValueDtype::F32, ValueDtype::Bf16] {
            let (cap, d, d_v) = (4usize, 65usize, 8usize);
            let mut page = filled_page(&mut rng, dtype, cap, d, d_v);
            let owned = page.clone();
            let owned_bytes = page.bytes();
            assert!(owned_bytes > 0);

            let arc = page.seal_shared();
            assert!(page.is_shared());
            assert_eq!(page.bytes(), 0, "shared page accounts zero bytes");
            assert_eq!(arc.bytes(), owned_bytes, "registry accounts the payload once");

            let adopted = Page::adopt_shared(Arc::clone(&arc));
            assert!(adopted.is_full());
            assert_eq!(adopted.bytes(), 0);
            for p in [&page, &adopted] {
                assert_same_rows(p, &owned, "shared read");
                for i in 0..cap {
                    let (mut a, mut b) = (vec![0.0; d_v], vec![0.0; d_v]);
                    p.value_into(i, &mut a);
                    owned.value_into(i, &mut b);
                    assert_eq!(a, b, "{dtype:?} value {i}");
                }
                assert_eq!(p.keys_packed(), owned.keys_packed());
            }
        }
    }

    #[test]
    fn make_owned_is_cow_and_restores_mutability() {
        let mut rng = Rng::new(8);
        let (cap, d, d_v) = (4usize, 32usize, 4usize);
        let mut page = filled_page(&mut rng, ValueDtype::F32, cap, d, d_v);
        let owned = page.clone();
        let arc = page.seal_shared();
        assert_eq!(Arc::strong_count(&arc), 2);

        page.make_owned();
        assert!(!page.is_shared());
        assert_eq!(Arc::strong_count(&arc), 1, "COW drops the shared reference");
        assert_eq!(page.bytes(), owned.bytes(), "owned copy accounts its bytes again");
        assert_same_rows(&page, &owned, "post-COW read");

        // The private copy diverges without touching the sealed payload.
        page.truncate(1);
        page.push(&rng.normal_vec(d, 1.0), &rng.normal_vec(d_v, 1.0));
        assert_eq!(arc.capacity(), cap);
        let reread = Page::adopt_shared(Arc::clone(&arc));
        assert_same_rows(&reread, &owned, "sealed payload untouched by divergence");
    }

    #[test]
    #[should_panic(expected = "make_owned first")]
    fn shared_page_rejects_partial_truncate() {
        let mut rng = Rng::new(9);
        let mut page = filled_page(&mut rng, ValueDtype::F32, 2, 16, 4);
        page.seal_shared();
        page.truncate(1);
    }

    #[test]
    #[should_panic(expected = "registry owns its spill")]
    fn shared_page_rejects_spill() {
        let mut rng = Rng::new(10);
        let mut page = filled_page(&mut rng, ValueDtype::F32, 2, 16, 4);
        page.seal_shared();
        page.drop_payload();
    }

    #[test]
    fn sealed_page_encode_decode_roundtrip() {
        let mut rng = Rng::new(11);
        for dtype in [ValueDtype::F32, ValueDtype::Bf16] {
            let (cap, d, d_v) = (4usize, 65usize, 8usize);
            let mut page = filled_page(&mut rng, dtype, cap, d, d_v);
            let arc = page.seal_shared();
            let mut buf = Vec::new();
            arc.encode(&mut buf);
            buf.extend_from_slice(b"tail");
            let (decoded, rest) = SealedPage::decode(&buf, cap, d, d_v, dtype).unwrap();
            assert_eq!(rest, b"tail");
            assert_eq!(decoded.bytes(), arc.bytes());
            let a = Page::adopt_shared(Arc::new(decoded));
            let b = Page::adopt_shared(Arc::clone(&arc));
            assert_same_rows(&a, &b, "decode");
            for i in 0..cap {
                let (mut x, mut y) = (vec![0.0; d_v], vec![0.0; d_v]);
                a.value_into(i, &mut x);
                b.value_into(i, &mut y);
                assert_eq!(x, y, "{dtype:?} value {i}");
            }
            assert!(SealedPage::decode(&buf[..8], cap, d, d_v, dtype).is_err());
        }
    }

    #[test]
    fn replace_with_shared_dedupes_to_the_registry_copy() {
        let mut rng = Rng::new(12);
        let (cap, d, d_v) = (4usize, 32usize, 4usize);
        let ks: Vec<f32> = rng.normal_vec(cap * d, 1.0);
        let vs: Vec<f32> = rng.normal_vec(cap * d_v, 1.0);
        let build = |ks: &[f32], vs: &[f32]| {
            let mut p = Page::new(cap, d, d_v);
            for i in 0..cap {
                p.push(&ks[i * d..(i + 1) * d], &vs[i * d_v..(i + 1) * d_v]);
            }
            p
        };
        let mut first = build(&ks, &vs);
        let mut second = build(&ks, &vs);
        let arc = first.seal_shared();
        second.replace_with_shared(Arc::clone(&arc));
        assert_eq!(second.bytes(), 0);
        assert_eq!(Arc::strong_count(&arc), 3);
        assert_same_rows(&second, &first, "dedup");
    }

    #[test]
    fn restore_rejects_short_payload() {
        let mut page = Page::new(2, 16, 4);
        page.push(&[1.0; 16], &[0.5; 4]);
        let mut payload = Vec::new();
        page.encode_payload(&mut payload);
        page.drop_payload();
        assert!(page.restore_payload(&payload[..payload.len() - 1]).is_err());
        // A failed restore leaves the shell evicted; a full payload works.
        assert!(!page.is_resident());
        page.restore_payload(&payload).unwrap();
        assert!(page.is_resident());
    }
}
