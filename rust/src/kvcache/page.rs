//! A fixed-size KV page: packed sign-bit keys + f32 values for up to
//! `capacity` tokens. Pages are the unit of pool accounting and of the
//! non-contiguous layout `had_attention_paged` scores over.

use crate::binary::bitpack::{pack_vector, words_for};

/// One page of KV state. Storage is allocated at full capacity on
/// construction, so `bytes()` is constant over the page's lifetime and
/// appends never move memory (slices handed out stay valid).
#[derive(Clone, Debug)]
pub struct Page {
    d: usize,
    words_per_key: usize,
    d_v: usize,
    capacity: usize,
    len: usize,
    /// capacity * words_per_key packed sign words, filled up to len rows.
    keys: Vec<u64>,
    /// capacity * d_v f32 values, filled up to len rows.
    values: Vec<f32>,
}

impl Page {
    pub fn new(capacity: usize, d: usize, d_v: usize) -> Page {
        assert!(capacity > 0, "page capacity must be positive");
        assert!(d > 0, "key dim must be positive");
        let words_per_key = words_for(d);
        Page {
            d,
            words_per_key,
            d_v,
            capacity,
            len: 0,
            keys: vec![0u64; capacity * words_per_key],
            values: vec![0.0f32; capacity * d_v],
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.len == self.capacity
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn words_per_key(&self) -> usize {
        self.words_per_key
    }

    /// Append one token's key (continuous f32, binarized here) and value.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert!(!self.is_full(), "page overflow");
        assert_eq!(k_row.len(), self.d, "key dim mismatch");
        assert_eq!(v_row.len(), self.d_v, "value dim mismatch");
        let w = self.words_per_key;
        pack_vector(k_row, &mut self.keys[self.len * w..(self.len + 1) * w]);
        self.values[self.len * self.d_v..(self.len + 1) * self.d_v].copy_from_slice(v_row);
        self.len += 1;
    }

    /// Packed sign words of token `i`'s key.
    #[inline]
    pub fn key(&self, i: usize) -> &[u64] {
        debug_assert!(i < self.len);
        &self.keys[i * self.words_per_key..(i + 1) * self.words_per_key]
    }

    /// All packed key words of the filled rows as one contiguous block
    /// (`len * words_per_key` words) — the tile the blocked kernel
    /// streams so a resident page is touched once per query block.
    #[inline]
    pub fn keys_packed(&self) -> &[u64] {
        &self.keys[..self.len * self.words_per_key]
    }

    /// f32 value row of token `i`.
    #[inline]
    pub fn value(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        &self.values[i * self.d_v..(i + 1) * self.d_v]
    }

    /// Roll back to `len` tokens (decode rollback / bench reset).
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate beyond length");
        self.len = len;
    }

    /// Resident payload bytes (full capacity — allocation, not fill).
    pub fn bytes(&self) -> usize {
        self.keys.len() * 8 + self.values.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binary::bitpack::PackedMat;
    use crate::util::rng::Rng;

    #[test]
    fn push_then_read_matches_packedmat() {
        let mut rng = Rng::new(1);
        for d in [3usize, 32, 64, 65, 100, 128] {
            let d_v = 8;
            let n = 5;
            let ks = rng.normal_vec(n * d, 1.0);
            let vs = rng.normal_vec(n * d_v, 1.0);
            let mut page = Page::new(8, d, d_v);
            for i in 0..n {
                page.push(&ks[i * d..(i + 1) * d], &vs[i * d_v..(i + 1) * d_v]);
            }
            assert_eq!(page.len(), n);
            assert!(!page.is_full());
            let reference = PackedMat::pack(n, d, &ks);
            for i in 0..n {
                assert_eq!(page.key(i), reference.row(i), "d={d} token {i}");
                assert_eq!(page.value(i), &vs[i * d_v..(i + 1) * d_v]);
            }
        }
    }

    #[test]
    fn keys_packed_is_the_concatenation_of_rows() {
        let mut rng = Rng::new(2);
        for d in [16usize, 64, 65] {
            let n = 6;
            let ks = rng.normal_vec(n * d, 1.0);
            let mut page = Page::new(8, d, 4);
            for i in 0..n {
                page.push(&ks[i * d..(i + 1) * d], &[0.0; 4]);
            }
            let block = page.keys_packed();
            assert_eq!(block.len(), n * page.words_per_key());
            for i in 0..n {
                let w = page.words_per_key();
                assert_eq!(&block[i * w..(i + 1) * w], page.key(i), "d={d} row {i}");
            }
        }
    }

    #[test]
    fn bytes_constant_over_fill() {
        let mut page = Page::new(16, 64, 32);
        let before = page.bytes();
        assert_eq!(before, 16 * 8 + 16 * 32 * 4);
        page.push(&[1.0; 64], &[0.5; 32]);
        assert_eq!(page.bytes(), before);
    }

    #[test]
    fn fills_to_capacity() {
        let mut page = Page::new(3, 16, 4);
        for _ in 0..3 {
            page.push(&[-1.0; 16], &[0.0; 4]);
        }
        assert!(page.is_full());
        page.truncate(1);
        assert_eq!(page.len(), 1);
        page.push(&[1.0; 16], &[1.0; 4]);
        assert_eq!(page.len(), 2);
    }

    #[test]
    #[should_panic(expected = "page overflow")]
    fn overflow_panics() {
        let mut page = Page::new(1, 8, 2);
        page.push(&[1.0; 8], &[0.0; 2]);
        page.push(&[1.0; 8], &[0.0; 2]);
    }
}
