//! Cross-session prefix registry: content-hashed, refcounted shared KV
//! stripes.
//!
//! ## Identity
//!
//! A shared entry is one **stripe** — page index `p` of every chain, the
//! same unit the spill tier moves — keyed by an FNV-1a-64 hash of the
//! FULL token prefix through the stripe's end, seeded from the packing
//! configuration ([`StripeGeom::seed`]). The hash is an index hint, not
//! the identity: every entry stores the prefix token ids and adoption /
//! dedup verify token equality, so a hash collision degrades to a miss,
//! never to serving another prompt's KV. Because prefill is
//! deterministic, equal token prefixes under equal packing config have
//! bit-identical stripes — dedup is exact, not approximate.
//!
//! ## Lifecycle
//!
//! - **Publish** (checkin / per-tick during generation): a session's
//!   full, private stripes are sealed behind `Arc<SealedPage>`s and
//!   entered here. If the hash is present with matching tokens the
//!   session *adopts the registry copy instead* (dedup — the duplicate
//!   bytes are freed); otherwise its sealed pages become the entry.
//! - **Adopt** (prefix resolution at admit): a new prompt walks its
//!   stripe hashes; each hit extends the session's cache by a whole
//!   stripe without re-running prefill.
//! - **Release**: every referencing session dropped its stripe. With a
//!   spill store the entry's bytes move to disk once (`spill_tag`,
//!   resident bytes drain to zero) and hydrate once on the next adopt;
//!   without one the entry is removed outright.
//!
//! Resident registry bytes are accounted here exactly once however many
//! sessions reference an entry; `PagePool` reports them alongside its
//! private bytes.

use std::collections::HashMap;
use std::sync::Arc;

use crate::kvcache::config::ValueDtype;
use crate::kvcache::page::SealedPage;
use crate::store::format::{fnv1a64, fnv1a64_extend};
use crate::store::SpillStore;

/// Bytes of geometry header on a spilled registry record: chains,
/// page_tokens, d_head (u32 LE each) + value element width + 3 reserved.
const ENTRY_HEADER: usize = 16;

/// The packing configuration a stripe's bits depend on. Seeds every
/// prefix hash so caches with different geometry or precision can never
/// alias an entry (one registry serves one model's server, so geometry +
/// tokens pin the content).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeGeom {
    pub chains: usize,
    pub page_tokens: usize,
    pub d_head: usize,
    pub dtype: ValueDtype,
}

impl StripeGeom {
    /// Hash seed binding prefix hashes to this packing configuration.
    pub fn seed(&self) -> u64 {
        let mut h = fnv1a64(b"had-prefix-v1");
        for x in [self.chains, self.page_tokens, self.d_head, self.dtype.bytes_per_elem()] {
            h = fnv1a64_extend(h, &(x as u32).to_le_bytes());
        }
        h
    }
}

/// Fold token ids (i32 LE) into an FNV-1a-64 state.
pub fn extend_tokens(mut h: u64, toks: &[i32]) -> u64 {
    for &t in toks {
        h = fnv1a64_extend(h, &t.to_le_bytes());
    }
    h
}

/// Content hash of every full stripe of `tokens`: element `p` covers the
/// whole prefix `tokens[..(p+1)*page_tokens]`, computed incrementally so
/// hashing N stripes walks the prompt once.
pub fn stripe_hashes(geom: &StripeGeom, tokens: &[i32]) -> Vec<u64> {
    let mut h = geom.seed();
    let mut out = Vec::with_capacity(tokens.len() / geom.page_tokens);
    for stripe in tokens.chunks_exact(geom.page_tokens) {
        h = extend_tokens(h, stripe);
        out.push(h);
    }
    out
}

/// Claim key for a whole prompt: identical-prompt followers park on this
/// while one stream runs the shared prefill. Domain-separated from the
/// stripe-hash space (separate map, but keep the keys distinct anyway).
pub fn prompt_claim_key(geom: &StripeGeom, tokens: &[i32]) -> u64 {
    extend_tokens(geom.seed() ^ 0x9e37_79b9_7f4a_7c15, tokens)
}

/// One shared stripe: the token prefix it encodes, its pages (one
/// `Arc<SealedPage>` per chain; `None` while spilled), and how many live
/// session stripes reference it.
struct SharedEntry {
    tokens: Vec<i32>,
    pages: Option<Vec<Arc<SealedPage>>>,
    spill_tag: Option<u64>,
    refs: usize,
    /// Payload bytes when resident (counted once in the registry).
    bytes: usize,
}

/// What a publisher should do with a full private stripe.
pub enum Publish {
    /// No entry (or a spilled one was displaced): seal the stripe's pages
    /// and hand them to [`SharedIndex::complete_publish`].
    Adopt,
    /// An identical resident entry exists: swap the private pages for
    /// these registry copies (the ref was already taken).
    Dedupe(Vec<Arc<SealedPage>>),
    /// Hash collision (tokens differ) — leave the stripe private.
    Skip,
}

/// Result of a prefix lookup at admit time.
pub enum Acquire {
    /// Entry found (hydrated from the spill tier if needed) and a
    /// reference taken. `hydrated_pages` > 0 when it came off disk.
    Hit { pages: Vec<Arc<SealedPage>>, hydrated_pages: usize },
    /// No matching entry. `failed_reads` = 1 when a spilled entry's
    /// record was unreadable (the entry is dropped; caller prefills).
    Miss { failed_reads: usize },
}

/// The registry. Owned by `PagePool` when prefix sharing is enabled.
#[derive(Default)]
pub struct SharedIndex {
    entries: HashMap<u64, SharedEntry>,
    /// full-prompt claim key -> stream id running that prompt's prefill.
    claims: HashMap<u64, u64>,
    /// Resident bytes across all entries (each counted once).
    bytes: usize,
}

impl SharedIndex {
    pub fn new() -> SharedIndex {
        SharedIndex::default()
    }

    /// Resident registry bytes (spilled entries count zero).
    #[inline]
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Entries in the index, resident or spilled.
    #[inline]
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Is an identical prefix registered (resident or spilled)?
    pub fn has(&self, hash: u64, tokens: &[i32]) -> bool {
        self.entries.get(&hash).is_some_and(|e| e.tokens == tokens)
    }

    /// Are stripes `0..n` of `tokens` all registered? (The waiting
    /// follower's wake condition.)
    pub fn covers(&self, geom: &StripeGeom, tokens: &[i32], n: usize) -> bool {
        stripe_hashes(geom, tokens)
            .iter()
            .take(n)
            .enumerate()
            .filter(|&(p, &h)| self.has(h, &tokens[..(p + 1) * geom.page_tokens]))
            .count()
            == n
    }

    /// Look up the stripe covering `tokens` and take a reference,
    /// hydrating a spilled entry from `store` first. Token equality is
    /// verified — a colliding hash is a miss.
    pub fn acquire(
        &mut self,
        hash: u64,
        tokens: &[i32],
        geom: &StripeGeom,
        store: Option<&SpillStore>,
    ) -> Acquire {
        let miss = |failed_reads| Acquire::Miss { failed_reads };
        let Some(e) = self.entries.get_mut(&hash) else { return miss(0) };
        if e.tokens != tokens {
            return miss(0);
        }
        let mut hydrated_pages = 0;
        if e.pages.is_none() {
            let Some(store) = store else { return miss(0) };
            let tag = e.spill_tag.expect("spilled entry without a tag");
            let pages = store.get(tag).ok().and_then(|buf| decode_entry(&buf, geom).ok());
            store.release(tag);
            match pages {
                Some(pages) => {
                    e.bytes = pages.iter().map(|p| p.bytes()).sum();
                    self.bytes += e.bytes;
                    hydrated_pages = pages.len();
                    e.pages = Some(pages);
                    e.spill_tag = None;
                }
                None => {
                    // Unreadable record: drop the entry; the caller
                    // prefills and likely republishes it fresh.
                    self.entries.remove(&hash);
                    return miss(1);
                }
            }
        }
        e.refs += 1;
        Acquire::Hit { pages: e.pages.clone().unwrap(), hydrated_pages }
    }

    /// Decide how to publish a full private stripe. `Dedupe` already took
    /// the reference; `Adopt` expects a follow-up
    /// [`SharedIndex::complete_publish`] with the sealed pages. A spilled
    /// identical entry is displaced (its record released) so the
    /// publisher's already-resident copy becomes the registry copy
    /// instead of paying a disk round-trip.
    pub fn prepare_publish(
        &mut self,
        hash: u64,
        tokens: &[i32],
        store: Option<&SpillStore>,
    ) -> Publish {
        match self.entries.get_mut(&hash) {
            None => Publish::Adopt,
            Some(e) if e.tokens != tokens => Publish::Skip,
            Some(e) => match &e.pages {
                Some(pages) => {
                    e.refs += 1;
                    Publish::Dedupe(pages.clone())
                }
                None => {
                    if let (Some(tag), Some(store)) = (e.spill_tag.take(), store) {
                        store.release(tag);
                    }
                    self.entries.remove(&hash);
                    Publish::Adopt
                }
            },
        }
    }

    /// Enter a freshly sealed stripe under `hash` with one reference (the
    /// publisher's own).
    pub fn complete_publish(&mut self, hash: u64, tokens: &[i32], pages: Vec<Arc<SealedPage>>) {
        let bytes = pages.iter().map(|p| p.bytes()).sum();
        self.bytes += bytes;
        let prev = self.entries.insert(
            hash,
            SharedEntry {
                tokens: tokens.to_vec(),
                pages: Some(pages),
                spill_tag: None,
                refs: 1,
                bytes,
            },
        );
        debug_assert!(prev.is_none(), "publish over a live entry");
    }

    /// Drop one reference to `hash`. At zero the entry's bytes leave
    /// residency: spilled once to `store` (hydrated once on the next
    /// adopt, refcount picking up where it left off) or removed outright
    /// without one. Returns `(pages_spilled, bytes_spilled)` for the
    /// pool's counters. A refused spill write keeps the entry resident —
    /// degraded, never wedged.
    pub fn release(&mut self, hash: u64, store: Option<&SpillStore>) -> (usize, usize) {
        let Some(e) = self.entries.get_mut(&hash) else { return (0, 0) };
        debug_assert!(e.refs > 0, "release of an unreferenced entry");
        e.refs = e.refs.saturating_sub(1);
        if e.refs > 0 || e.pages.is_none() {
            return (0, 0);
        }
        match store {
            Some(store) => {
                let pages = e.pages.as_ref().unwrap();
                let Ok(tag) = store.put(&encode_entry(pages)) else { return (0, 0) };
                let (n, freed) = (pages.len(), e.bytes);
                e.pages = None;
                e.spill_tag = Some(tag);
                e.bytes = 0;
                self.bytes -= freed;
                (n, freed)
            }
            None => {
                let freed = e.bytes;
                self.bytes -= freed;
                self.entries.remove(&hash);
                (0, freed)
            }
        }
    }

    /// Claim `key`'s prefill for `stream`. `None` = claimed (or already
    /// held by this stream); `Some(holder)` = another stream holds it.
    pub fn try_claim(&mut self, key: u64, stream: u64) -> Option<u64> {
        match self.claims.get(&key) {
            Some(&holder) if holder != stream => Some(holder),
            _ => {
                self.claims.insert(key, stream);
                None
            }
        }
    }

    /// Is `key` claimed by a stream other than `stream`?
    pub fn claim_held_by_other(&self, key: u64, stream: u64) -> bool {
        self.claims.get(&key).is_some_and(|&h| h != stream)
    }

    /// Release `key` if `stream` holds it (unconditional at stream
    /// retirement, so a dead claimer can never park followers forever).
    pub fn release_claim(&mut self, key: u64, stream: u64) {
        if self.claims.get(&key) == Some(&stream) {
            self.claims.remove(&key);
        }
    }
}

/// Serialize a registry entry for the spill tier: geometry header then
/// every chain's sealed page.
fn encode_entry(pages: &[Arc<SealedPage>]) -> Vec<u8> {
    let payload: usize = pages.iter().map(|p| p.bytes()).sum();
    let mut out = Vec::with_capacity(ENTRY_HEADER + payload);
    out.extend_from_slice(&(pages.len() as u32).to_le_bytes());
    out.extend_from_slice(&(pages[0].capacity() as u32).to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // reserved (d_head checked at decode)
    out.push(0);
    out.extend_from_slice(&[0u8; 3]);
    for p in pages {
        p.encode(&mut out);
    }
    out
}

/// Rebuild a registry entry, shape-checking the header against the
/// adopting cache's geometry so a record can never hydrate into the
/// wrong shape.
fn decode_entry(buf: &[u8], geom: &StripeGeom) -> Result<Vec<Arc<SealedPage>>, String> {
    if buf.len() < ENTRY_HEADER {
        return Err(format!("entry header short: {} B", buf.len()));
    }
    let word = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().unwrap()) as usize;
    if word(0) != geom.chains || word(4) != geom.page_tokens {
        return Err("entry geometry mismatch".to_string());
    }
    let mut rest = &buf[ENTRY_HEADER..];
    let mut pages = Vec::with_capacity(geom.chains);
    for _ in 0..geom.chains {
        let (page, r) =
            SealedPage::decode(rest, geom.page_tokens, geom.d_head, geom.d_head, geom.dtype)?;
        pages.push(Arc::new(page));
        rest = r;
    }
    if !rest.is_empty() {
        return Err(format!("{} trailing bytes after entry decode", rest.len()));
    }
    Ok(pages)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::page::Page;
    use crate::util::rng::Rng;

    fn geom() -> StripeGeom {
        StripeGeom { chains: 2, page_tokens: 4, d_head: 16, dtype: ValueDtype::F32 }
    }

    fn sealed_pages(rng: &mut Rng, g: &StripeGeom) -> Vec<Arc<SealedPage>> {
        (0..g.chains)
            .map(|_| {
                let mut p = Page::new(g.page_tokens, g.d_head, g.d_head);
                for _ in 0..g.page_tokens {
                    p.push(&rng.normal_vec(g.d_head, 1.0), &rng.normal_vec(g.d_head, 1.0));
                }
                p.seal_shared()
            })
            .collect()
    }

    #[test]
    fn stripe_hashes_are_incremental_over_the_prefix() {
        let g = geom();
        let toks: Vec<i32> = (0..13).collect();
        let hs = stripe_hashes(&g, &toks);
        assert_eq!(hs.len(), 3, "13 tokens / 4 per page = 3 full stripes");
        // Each element hashes the whole prefix, independent of chunking.
        for (p, &h) in hs.iter().enumerate() {
            let direct = extend_tokens(g.seed(), &toks[..(p + 1) * g.page_tokens]);
            assert_eq!(h, direct, "stripe {p}");
        }
        // A different prompt or geometry never reuses a hash.
        let other = stripe_hashes(&g, &[9, 9, 9, 9]);
        assert_ne!(other[0], hs[0]);
        let wider = StripeGeom { d_head: 32, ..g };
        assert_ne!(stripe_hashes(&wider, &toks)[0], hs[0]);
    }

    #[test]
    fn publish_dedupe_acquire_release_lifecycle() {
        let mut rng = Rng::new(31);
        let g = geom();
        let toks: Vec<i32> = (0..4).collect();
        let h = stripe_hashes(&g, &toks)[0];
        let mut idx = SharedIndex::new();

        assert!(matches!(idx.prepare_publish(h, &toks, None), Publish::Adopt));
        let pages = sealed_pages(&mut rng, &g);
        let entry_bytes: usize = pages.iter().map(|p| p.bytes()).sum();
        idx.complete_publish(h, &toks, pages.clone());
        assert_eq!(idx.bytes(), entry_bytes, "entry accounted once");
        assert!(idx.has(h, &toks));
        assert!(idx.covers(&g, &toks, 1));

        // Second publisher of the identical stripe dedupes onto the copy.
        let Publish::Dedupe(dup) = idx.prepare_publish(h, &toks, None) else {
            panic!("identical stripe must dedupe");
        };
        assert!(Arc::ptr_eq(&dup[0], &pages[0]));
        assert_eq!(idx.bytes(), entry_bytes, "dedup adds no bytes");

        // A colliding publish (same hash, different tokens) is skipped.
        assert!(matches!(idx.prepare_publish(h, &[7, 7, 7, 7], None), Publish::Skip));

        // Adoption takes a third reference.
        let Acquire::Hit { pages: got, hydrated_pages } = idx.acquire(h, &toks, &g, None) else {
            panic!("resident entry must hit");
        };
        assert_eq!(hydrated_pages, 0);
        assert!(Arc::ptr_eq(&got[0], &pages[0]));
        // Token equality is the identity, not the hash.
        assert!(matches!(
            idx.acquire(h, &[7, 7, 7, 7], &g, None),
            Acquire::Miss { failed_reads: 0 }
        ));

        // Three refs: entry survives two releases, drains on the third.
        assert_eq!(idx.release(h, None), (0, 0));
        assert_eq!(idx.release(h, None), (0, 0));
        assert_eq!(idx.bytes(), entry_bytes);
        assert_eq!(idx.release(h, None), (0, entry_bytes));
        assert_eq!(idx.bytes(), 0, "registry drains to zero with no store");
        assert_eq!(idx.entries(), 0);
    }

    #[test]
    fn zero_ref_entry_spills_once_and_hydrates_once() {
        let store =
            SpillStore::create(&std::env::temp_dir().join("had-spill-test"), None).unwrap();
        let mut rng = Rng::new(32);
        let g = geom();
        let toks: Vec<i32> = (10..14).collect();
        let h = stripe_hashes(&g, &toks)[0];
        let mut idx = SharedIndex::new();
        let pages = sealed_pages(&mut rng, &g);
        let entry_bytes: usize = pages.iter().map(|p| p.bytes()).sum();
        idx.complete_publish(h, &toks, pages.clone());

        let (spilled, freed) = idx.release(h, Some(&store));
        assert_eq!((spilled, freed), (g.chains, entry_bytes));
        assert_eq!(idx.bytes(), 0, "spilled entry leaves residency");
        assert_eq!(idx.entries(), 1, "…but stays indexed");
        assert!(idx.has(h, &toks));
        assert_eq!(store.live_records(), 1);

        let Acquire::Hit { pages: back, hydrated_pages } =
            idx.acquire(h, &toks, &g, Some(&store))
        else {
            panic!("spilled entry must hydrate");
        };
        assert_eq!(hydrated_pages, g.chains);
        assert_eq!(idx.bytes(), entry_bytes);
        assert_eq!(store.live_records(), 0, "hydrate releases the record");
        for (a, b) in back.iter().zip(&pages) {
            let (pa, pb) = (Page::adopt_shared(Arc::clone(a)), Page::adopt_shared(Arc::clone(b)));
            for i in 0..g.page_tokens {
                assert_eq!(pa.key(i), pb.key(i), "hydrated keys bit-identical");
                let (mut x, mut y) = (vec![0.0; g.d_head], vec![0.0; g.d_head]);
                pa.value_into(i, &mut x);
                pb.value_into(i, &mut y);
                assert_eq!(x, y, "hydrated values bit-identical");
            }
        }
        // The ref taken by the hydrate keeps it resident until released.
        assert_eq!(idx.release(h, Some(&store)).0, g.chains, "re-spills at zero");
    }

    #[test]
    fn corrupt_spilled_entry_degrades_to_a_miss() {
        let store =
            SpillStore::create(&std::env::temp_dir().join("had-spill-test"), None).unwrap();
        let mut rng = Rng::new(33);
        let g = geom();
        let toks: Vec<i32> = (0..4).collect();
        let h = stripe_hashes(&g, &toks)[0];
        let mut idx = SharedIndex::new();
        idx.complete_publish(h, &toks, sealed_pages(&mut rng, &g));
        idx.release(h, Some(&store));
        {
            use std::io::{Seek, SeekFrom, Write};
            let mut f = std::fs::File::options().write(true).open(store.path()).unwrap();
            f.seek(SeekFrom::Start(16 + 16 + 3)).unwrap();
            f.write_all(&[0xAA]).unwrap();
        }
        assert!(matches!(
            idx.acquire(h, &toks, &g, Some(&store)),
            Acquire::Miss { failed_reads: 1 }
        ));
        assert_eq!(idx.entries(), 0, "unreadable entry is dropped");
        assert_eq!(store.live_records(), 0, "…and its record released");
    }

    #[test]
    fn claims_park_followers_until_released() {
        let g = geom();
        let key = prompt_claim_key(&g, &[1, 2, 3, 4, 5]);
        let mut idx = SharedIndex::new();
        assert_eq!(idx.try_claim(key, 7), None, "first stream wins the claim");
        assert_eq!(idx.try_claim(key, 7), None, "re-claim by the holder is a no-op");
        assert_eq!(idx.try_claim(key, 8), Some(7), "follower sees the holder");
        assert!(idx.claim_held_by_other(key, 8));
        assert!(!idx.claim_held_by_other(key, 7));
        idx.release_claim(key, 8);
        assert!(idx.claim_held_by_other(key, 8), "only the holder can release");
        idx.release_claim(key, 7);
        assert_eq!(idx.try_claim(key, 8), None, "freed claim transfers");
    }
}
