//! Per-layer KV state for the CPU serving backend: one page chain per
//! (layer, head) pair, advancing in lock step one token at a time.
//!
//! ## Layout
//!
//! `SessionKv` is a single page chain — the right shape for one attention
//! head's geometry. A transformer decode produces K/V for EVERY layer and
//! head per token, so a served session holds `n_layers * n_heads` chains,
//! each with key dim = value dim = `d_head`, all at the same token length.
//! Chains are stored layer-major (`layer * n_heads + head`), and the
//! decoded token ids are kept alongside so a later request can be checked
//! against the resident state (prefix identity) before the backend
//! resumes an incremental decode instead of re-executing the sequence.
//!
//! One token of residency costs
//! `n_layers * n_heads * (ceil(d_head/64) * 8 + d_head * value_bytes)` —
//! packed sign-bit keys per layer per head plus values at the configured
//! precision (`ValueDtype::Bf16` halves the value half).

use crate::binary::bitpack::words_for;
use crate::kvcache::config::ValueDtype;
use crate::kvcache::session::SessionKv;

/// Head geometry of a layered cache (one chain per (layer, head)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvGeom {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
}

impl KvGeom {
    pub fn chains(&self) -> usize {
        self.n_layers * self.n_heads
    }
}

/// One served session's full per-layer KV state plus the token ids it was
/// decoded from.
#[derive(Clone, Debug)]
pub struct LayeredKv {
    geom: KvGeom,
    /// layer-major: chains[layer * n_heads + head]
    chains: Vec<SessionKv>,
    /// Ids of the tokens whose K/V are resident, in decode order. The
    /// chains hold exactly `tokens.len()` entries each once a token's
    /// forward completes (`note_token` asserts it).
    tokens: Vec<i32>,
}

impl LayeredKv {
    pub fn new(geom: KvGeom, page_tokens: usize, dtype: ValueDtype) -> LayeredKv {
        assert!(geom.n_layers > 0 && geom.n_heads > 0 && geom.d_head > 0, "empty geometry");
        let chains = (0..geom.chains())
            .map(|_| SessionKv::new_with(geom.d_head, geom.d_head, page_tokens, dtype))
            .collect();
        LayeredKv { geom, chains, tokens: Vec::new() }
    }

    #[inline]
    pub fn geom(&self) -> KvGeom {
        self.geom
    }

    /// Decoded tokens resident in every chain.
    #[inline]
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Ids of the resident tokens (decode-order prefix of the session).
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Is the resident state exactly a decode of `tokens[..self.len()]`?
    /// The backend resumes from `len()` when true and resets otherwise.
    pub fn is_prefix_of(&self, tokens: &[i32]) -> bool {
        tokens.len() >= self.tokens.len() && tokens[..self.tokens.len()] == self.tokens[..]
    }

    #[inline]
    pub fn chain(&self, layer: usize, head: usize) -> &SessionKv {
        &self.chains[layer * self.geom.n_heads + head]
    }

    #[inline]
    pub fn chain_mut(&mut self, layer: usize, head: usize) -> &mut SessionKv {
        &mut self.chains[layer * self.geom.n_heads + head]
    }

    /// Complete one decoded token: every chain must have received exactly
    /// one appended row since the previous call.
    pub fn note_token(&mut self, token: i32) {
        let want = self.tokens.len() + 1;
        debug_assert!(
            self.chains.iter().all(|c| c.len() == want),
            "every (layer, head) chain must advance one row per token"
        );
        self.tokens.push(token);
    }

    /// Roll every chain (and the token record) back to `len` tokens.
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.tokens.len(), "truncate beyond length");
        for c in &mut self.chains {
            c.truncate(len);
        }
        self.tokens.truncate(len);
    }

    /// Drop all resident state (context restart).
    pub fn reset(&mut self) {
        self.truncate(0);
    }

    /// Resident payload bytes across all chains' pages.
    pub fn bytes(&self) -> usize {
        self.chains.iter().map(SessionKv::bytes).sum()
    }

    /// Exact resident bytes a decode of `n_tokens` tokens will occupy in
    /// this geometry (pages allocate at full capacity, so residency is
    /// page-granular and independent of current fill). The generation
    /// loop budget-checks `bytes_at(len)` BEFORE decoding, so a stream
    /// retires with a `Budget` stop instead of ever allocating past the
    /// pool's byte budget.
    pub fn bytes_at(&self, n_tokens: usize) -> usize {
        self.chains
            .iter()
            .map(|c| {
                let per_token =
                    words_for(c.d()) * 8 + c.d_v() * c.value_dtype().bytes_per_elem();
                n_tokens.div_ceil(c.page_tokens()) * c.page_tokens() * per_token
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_token(kv: &mut LayeredKv, tok: i32, fill: f32) {
        let g = kv.geom();
        for l in 0..g.n_layers {
            for h in 0..g.n_heads {
                kv.chain_mut(l, h).append_row(&vec![fill; g.d_head], &vec![fill; g.d_head]);
            }
        }
        kv.note_token(tok);
    }

    #[test]
    fn tokens_advance_in_lock_step() {
        let geom = KvGeom { n_layers: 2, n_heads: 3, d_head: 16 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert!(kv.is_empty());
        assert_eq!(kv.geom().chains(), 6);
        for (i, tok) in [5i32, 7, 9].iter().enumerate() {
            push_token(&mut kv, *tok, i as f32);
            assert_eq!(kv.len(), i + 1);
        }
        assert_eq!(kv.tokens(), &[5, 7, 9]);
        for l in 0..2 {
            for h in 0..3 {
                assert_eq!(kv.chain(l, h).len(), 3);
            }
        }
    }

    #[test]
    fn prefix_identity() {
        let geom = KvGeom { n_layers: 1, n_heads: 2, d_head: 8 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert!(kv.is_prefix_of(&[1, 2, 3]), "empty state is a prefix of anything");
        push_token(&mut kv, 1, 0.0);
        push_token(&mut kv, 2, 1.0);
        assert!(kv.is_prefix_of(&[1, 2]));
        assert!(kv.is_prefix_of(&[1, 2, 3]));
        assert!(!kv.is_prefix_of(&[1, 9, 3]), "mismatched id");
        assert!(!kv.is_prefix_of(&[1]), "resident state longer than the request");
    }

    #[test]
    fn truncate_and_reset_roll_back_every_chain() {
        let geom = KvGeom { n_layers: 2, n_heads: 2, d_head: 8 };
        let mut kv = LayeredKv::new(geom, 2, ValueDtype::Bf16);
        for t in 0..5 {
            push_token(&mut kv, t, t as f32);
        }
        let full = kv.bytes();
        assert!(full > 0);
        kv.truncate(2);
        assert_eq!(kv.len(), 2);
        assert_eq!(kv.tokens(), &[0, 1]);
        assert!(kv.bytes() < full, "dropping pages releases bytes");
        assert!(kv.chains.iter().all(|c| c.len() == 2));
        kv.reset();
        assert!(kv.is_empty());
        assert_eq!(kv.bytes(), 0);
    }

    #[test]
    fn bytes_are_the_sum_of_chain_pages() {
        let geom = KvGeom { n_layers: 2, n_heads: 2, d_head: 64 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        push_token(&mut kv, 3, 0.5);
        // 4 chains x one page x 4 tokens x (8 B key + 64*4 B value)
        assert_eq!(kv.bytes(), 4 * 4 * (8 + 256));
    }

    #[test]
    fn bytes_at_predicts_actual_residency() {
        let geom = KvGeom { n_layers: 2, n_heads: 3, d_head: 16 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert_eq!(kv.bytes_at(0), 0);
        for t in 0..9 {
            push_token(&mut kv, t, 0.25);
            assert_eq!(
                kv.bytes(),
                kv.bytes_at(kv.len()),
                "projection must equal residency at {} tokens",
                kv.len()
            );
        }
        // page-granular: 5..=8 tokens all cost two pages
        assert_eq!(kv.bytes_at(5), kv.bytes_at(8));
        assert!(kv.bytes_at(9) > kv.bytes_at(8));
    }
}
