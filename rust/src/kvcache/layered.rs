//! Per-layer KV state for the CPU serving backend: one page chain per
//! (layer, head) pair, advancing in lock step one token at a time.
//!
//! ## Layout
//!
//! `SessionKv` is a single page chain — the right shape for one attention
//! head's geometry. A transformer decode produces K/V for EVERY layer and
//! head per token, so a served session holds `n_layers * n_heads` chains,
//! each with key dim = value dim = `d_head`, all at the same token length.
//! Chains are stored layer-major (`layer * n_heads + head`), and the
//! decoded token ids are kept alongside so a later request can be checked
//! against the resident state (prefix identity) before the backend
//! resumes an incremental decode instead of re-executing the sequence.
//!
//! One token of residency costs
//! `n_layers * n_heads * (ceil(d_head/64) * 8 + d_head * value_bytes)` —
//! packed sign-bit keys per layer per head plus values at the configured
//! precision (`ValueDtype::Bf16` halves the value half).

use std::sync::Arc;

use crate::binary::bitpack::words_for;
use crate::kvcache::config::ValueDtype;
use crate::kvcache::page::SealedPage;
use crate::kvcache::session::SessionKv;
use crate::kvcache::shared::StripeGeom;
use crate::store::SpillStore;

/// Bytes of stripe-geometry header prepended to every spill record:
/// chains, page_tokens, d_head (u32 LE each) + value element width +
/// 3 reserved bytes. Restore shape-checks the header against the live
/// geometry so a record can never hydrate into the wrong cache.
const STRIPE_HEADER: usize = 16;

/// Head geometry of a layered cache (one chain per (layer, head)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvGeom {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
}

impl KvGeom {
    pub fn chains(&self) -> usize {
        self.n_layers * self.n_heads
    }
}

/// One served session's full per-layer KV state plus the token ids it was
/// decoded from.
#[derive(Clone, Debug)]
pub struct LayeredKv {
    geom: KvGeom,
    /// layer-major: chains[layer * n_heads + head]
    chains: Vec<SessionKv>,
    /// Ids of the tokens whose K/V are resident, in decode order. The
    /// chains hold exactly `tokens.len()` entries each once a token's
    /// forward completes (`note_token` asserts it).
    tokens: Vec<i32>,
    /// Spilled stripes, sorted by stripe index: `(stripe, spill tag)`.
    /// A stripe is page index `p` of EVERY chain — one lock-step token
    /// range `[p*page_tokens, (p+1)*page_tokens)` — spilled and hydrated
    /// as a unit. Only full (sealed) stripes ever spill.
    spilled: Vec<(usize, u64)>,
    /// Spill tags whose stripes were dropped without store access
    /// (truncate/reset) — the owner must `drain_released` and release
    /// them against the spill store, or the records leak until teardown.
    released: Vec<u64>,
    /// Stripes whose pages are shared with the prefix registry, sorted by
    /// stripe index: `(stripe, content hash)`. A stripe is exactly one of
    /// owned, spilled, or shared.
    shared: Vec<(usize, u64)>,
    /// Content hashes whose shared stripes were dropped without registry
    /// access (truncate/reset) — the owner must `drain_released_shared`
    /// and release the references, or the registry refcounts leak.
    released_shared: Vec<u64>,
    /// Copy-on-write page materializations since the last `take_cow`
    /// (truncate landing inside a shared stripe).
    cow_copies: u64,
}

impl LayeredKv {
    pub fn new(geom: KvGeom, page_tokens: usize, dtype: ValueDtype) -> LayeredKv {
        assert!(geom.n_layers > 0 && geom.n_heads > 0 && geom.d_head > 0, "empty geometry");
        let chains = (0..geom.chains())
            .map(|_| SessionKv::new_with(geom.d_head, geom.d_head, page_tokens, dtype))
            .collect();
        LayeredKv {
            geom,
            chains,
            tokens: Vec::new(),
            spilled: Vec::new(),
            released: Vec::new(),
            shared: Vec::new(),
            released_shared: Vec::new(),
            cow_copies: 0,
        }
    }

    #[inline]
    pub fn geom(&self) -> KvGeom {
        self.geom
    }

    /// Decoded tokens resident in every chain.
    #[inline]
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Ids of the resident tokens (decode-order prefix of the session).
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Is the resident state exactly a decode of `tokens[..self.len()]`?
    /// The backend resumes from `len()` when true and resets otherwise.
    pub fn is_prefix_of(&self, tokens: &[i32]) -> bool {
        tokens.len() >= self.tokens.len() && tokens[..self.tokens.len()] == self.tokens[..]
    }

    #[inline]
    pub fn chain(&self, layer: usize, head: usize) -> &SessionKv {
        &self.chains[layer * self.geom.n_heads + head]
    }

    #[inline]
    pub fn chain_mut(&mut self, layer: usize, head: usize) -> &mut SessionKv {
        &mut self.chains[layer * self.geom.n_heads + head]
    }

    /// Complete one decoded token: every chain must have received exactly
    /// one appended row since the previous call.
    pub fn note_token(&mut self, token: i32) {
        let want = self.tokens.len() + 1;
        debug_assert!(
            self.chains.iter().all(|c| c.len() == want),
            "every (layer, head) chain must advance one row per token"
        );
        self.tokens.push(token);
    }

    /// Roll every chain (and the token record) back to `len` tokens.
    ///
    /// Spill interaction: a cut that lands INSIDE a spilled stripe is
    /// clamped down to that stripe's start (keeping the partial page
    /// would require hydrating it here, without store access — callers
    /// re-prefill the few clamped tokens instead). Spilled stripes at or
    /// beyond the cut are dropped and their tags buffered for
    /// [`LayeredKv::drain_released`].
    /// Shared-stripe interaction: a cut INSIDE a shared stripe first
    /// materializes a private copy of its pages (copy-on-write — the
    /// registry copy and every other referencing session are untouched,
    /// so bit-identity holds on both sides of the divergence); shared
    /// stripes wholly at or beyond the cut just drop their pages. Either
    /// way the stripe's registry reference is buffered for
    /// [`LayeredKv::drain_released_shared`].
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.tokens.len(), "truncate beyond length");
        let pt = self.page_tokens();
        let len = match self.spilled.iter().find(|&&(p, _)| p * pt < len && len < (p + 1) * pt) {
            Some(&(p, _)) => p * pt,
            None => len,
        };
        let mut kept = Vec::with_capacity(self.spilled.len());
        for &(p, tag) in &self.spilled {
            if (p + 1) * pt <= len {
                kept.push((p, tag));
            } else {
                self.released.push(tag);
            }
        }
        self.spilled = kept;
        let mut kept_shared = Vec::with_capacity(self.shared.len());
        for &(p, hash) in &self.shared {
            if (p + 1) * pt <= len {
                kept_shared.push((p, hash));
            } else {
                if p * pt < len {
                    // The cut lands inside this shared stripe: COW so the
                    // surviving partial page is privately mutable.
                    for c in &mut self.chains {
                        c.page_mut(p).make_owned();
                    }
                    self.cow_copies += self.chains.len() as u64;
                }
                self.released_shared.push(hash);
            }
        }
        self.shared = kept_shared;
        for c in &mut self.chains {
            c.truncate(len);
        }
        self.tokens.truncate(len);
    }

    /// Drop all resident state (context restart).
    pub fn reset(&mut self) {
        self.truncate(0);
    }

    /// Resident payload bytes across all chains' pages.
    pub fn bytes(&self) -> usize {
        self.chains.iter().map(SessionKv::bytes).sum()
    }

    /// Exact resident bytes a decode of `n_tokens` tokens will occupy in
    /// this geometry (pages allocate at full capacity, so residency is
    /// page-granular and independent of current fill). The generation
    /// loop budget-checks `bytes_at(len)` BEFORE decoding, so a stream
    /// retires with a `Budget` stop instead of ever allocating past the
    /// pool's byte budget.
    pub fn bytes_at(&self, n_tokens: usize) -> usize {
        self.chains
            .iter()
            .map(|c| {
                let per_token =
                    words_for(c.d()) * 8 + c.d_v() * c.value_dtype().bytes_per_elem();
                n_tokens.div_ceil(c.page_tokens()) * c.page_tokens() * per_token
            })
            .sum()
    }

    // ---- disk spill tier ------------------------------------------------

    /// Tokens per page (uniform across chains).
    #[inline]
    pub fn page_tokens(&self) -> usize {
        self.chains[0].page_tokens()
    }

    /// Full (sealed) stripes — the only spill candidates. The partial
    /// tail page, if any, always stays resident.
    #[inline]
    pub fn full_stripes(&self) -> usize {
        self.tokens.len() / self.page_tokens()
    }

    /// Number of stripes currently living in the spill tier.
    #[inline]
    pub fn spilled_stripes(&self) -> usize {
        self.spilled.len()
    }

    /// True when every page is resident (the decode precondition —
    /// `SessionStore::checkout` hydrates before handing the cache out).
    #[inline]
    pub fn fully_resident(&self) -> bool {
        self.spilled.is_empty()
    }

    fn stripe_spilled(&self, p: usize) -> bool {
        self.spilled.iter().any(|&(s, _)| s == p)
    }

    fn stripe_shared(&self, p: usize) -> bool {
        self.shared.iter().any(|&(s, _)| s == p)
    }

    /// Is there a resident PRIVATE full stripe left to spill? Shared
    /// stripes never spill from a session — the registry owns their
    /// payload and spills it once, when the last reference drops.
    pub fn has_spillable(&self) -> bool {
        (0..self.full_stripes()).any(|p| !self.stripe_spilled(p) && !self.stripe_shared(p))
    }

    /// Serialize stripe `p`: geometry header, then every chain's page `p`
    /// payload in chain order.
    fn encode_stripe(&self, p: usize) -> Vec<u8> {
        let payload: usize = self.chains.iter().map(|c| c.pages()[p].payload_len()).sum();
        let mut out = Vec::with_capacity(STRIPE_HEADER + payload);
        out.extend_from_slice(&(self.chains.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.page_tokens() as u32).to_le_bytes());
        out.extend_from_slice(&(self.geom.d_head as u32).to_le_bytes());
        out.push(self.chains[0].value_dtype().bytes_per_elem() as u8);
        out.extend_from_slice(&[0u8; 3]);
        for c in &self.chains {
            c.pages()[p].encode_payload(&mut out);
        }
        out
    }

    /// Restore stripe `p` from a spill record, shape-checking the header
    /// against the live geometry.
    fn restore_stripe(&mut self, p: usize, buf: &[u8]) -> Result<usize, String> {
        if buf.len() < STRIPE_HEADER {
            return Err(format!("stripe header short: {} B", buf.len()));
        }
        let word = |o: usize| {
            u32::from_le_bytes(buf[o..o + 4].try_into().unwrap()) as usize
        };
        let elem = self.chains[0].value_dtype().bytes_per_elem();
        if word(0) != self.chains.len()
            || word(4) != self.page_tokens()
            || word(8) != self.geom.d_head
            || buf[12] as usize != elem
        {
            return Err("stripe geometry mismatch".to_string());
        }
        let mut rest = &buf[STRIPE_HEADER..];
        for c in &mut self.chains {
            rest = c.page_mut(p).restore_payload(rest)?;
        }
        if !rest.is_empty() {
            return Err(format!("{} trailing bytes after stripe restore", rest.len()));
        }
        Ok(self.chains.len())
    }

    /// Spill the oldest resident full stripe to `store`, dropping its
    /// pages to zero-byte shells. Returns `(bytes freed, pages spilled)`,
    /// or `None` when nothing is spillable or the store refused the write
    /// (fault injection / IO error) — the caller falls back to plain
    /// eviction, it never wedges.
    pub fn spill_one(&mut self, store: &SpillStore) -> Option<(usize, usize)> {
        let p = (0..self.full_stripes())
            .find(|&p| !self.stripe_spilled(p) && !self.stripe_shared(p))?;
        let tag = store.put(&self.encode_stripe(p)).ok()?;
        let mut freed = 0;
        for c in &mut self.chains {
            let page = c.page_mut(p);
            freed += page.bytes();
            page.drop_payload();
        }
        let at = self.spilled.partition_point(|&(s, _)| s < p);
        self.spilled.insert(at, (p, tag));
        Some((freed, self.chains.len()))
    }

    /// Hydrate every spilled stripe back from `store`, oldest first,
    /// releasing each record once its bytes are resident again. On a
    /// failed read (fault injection, corruption) the cache is truncated
    /// to the resident prefix before the failed stripe — the scheduler's
    /// existing resume path re-prefills the difference; corrupt KV is
    /// never served. Returns `(pages restored, failed reads)`.
    pub fn hydrate(&mut self, store: &SpillStore) -> (usize, usize) {
        let spilled = std::mem::take(&mut self.spilled);
        let mut pages_in = 0;
        for (i, &(p, tag)) in spilled.iter().enumerate() {
            let restored = match store.get(tag) {
                Ok(buf) => self.restore_stripe(p, &buf).is_ok(),
                Err(_) => false,
            };
            if restored {
                pages_in += self.chains.len();
                store.release(tag);
                continue;
            }
            // Drop the failed stripe and everything after it (later
            // tokens attend to these keys, so they are unusable too).
            for &(_, later) in &spilled[i..] {
                store.release(later);
            }
            let keep = p * self.page_tokens();
            for c in &mut self.chains {
                c.truncate(keep);
            }
            self.tokens.truncate(keep);
            return (pages_in, 1);
        }
        (pages_in, 0)
    }

    /// Tags of every spilled stripe (released by the pool when the whole
    /// session is evicted or removed).
    pub fn spill_tags(&self) -> Vec<u64> {
        self.spilled.iter().map(|&(_, tag)| tag).collect()
    }

    /// Take the tags buffered by [`LayeredKv::truncate`] for release
    /// against the spill store.
    pub fn drain_released(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.released)
    }

    // ---- cross-session prefix sharing -----------------------------------

    /// The packing configuration a stripe's bits depend on — the seed of
    /// every prefix content hash for this cache.
    pub fn stripe_geom(&self) -> StripeGeom {
        StripeGeom {
            chains: self.chains.len(),
            page_tokens: self.page_tokens(),
            d_head: self.geom.d_head,
            dtype: self.chains[0].value_dtype(),
        }
    }

    /// Stripes currently referencing shared registry payloads.
    #[inline]
    pub fn shared_stripes(&self) -> usize {
        self.shared.len()
    }

    /// Content hashes of every shared stripe — the references the pool
    /// releases when the whole session is evicted or removed.
    pub fn shared_hashes(&self) -> Vec<u64> {
        self.shared.iter().map(|&(_, hash)| hash).collect()
    }

    /// Take the hashes buffered by [`LayeredKv::truncate`] for release
    /// against the prefix registry.
    pub fn drain_released_shared(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.released_shared)
    }

    /// Take the copy-on-write page count since the last call (drained
    /// into `CacheStats` at pool boundaries).
    pub fn take_cow(&mut self) -> u64 {
        std::mem::take(&mut self.cow_copies)
    }

    /// Prefix adoption: extend every chain by one already-sealed shared
    /// stripe (the registry's copy of `toks`' K/V — no prefill runs). The
    /// cache must sit exactly at a fully-resident stripe boundary.
    pub fn adopt_stripe(&mut self, toks: &[i32], pages: Vec<Arc<SealedPage>>, hash: u64) {
        let pt = self.page_tokens();
        assert_eq!(toks.len(), pt, "adopt of a partial stripe");
        assert_eq!(self.tokens.len() % pt, 0, "adopt off a stripe boundary");
        assert_eq!(pages.len(), self.chains.len(), "one shared page per chain");
        let p = self.tokens.len() / pt;
        for (c, page) in self.chains.iter_mut().zip(pages) {
            c.adopt_shared_page(page);
        }
        self.tokens.extend_from_slice(toks);
        let at = self.shared.partition_point(|&(s, _)| s < p);
        self.shared.insert(at, (p, hash));
    }

    /// Full stripes eligible for publication: resident, private, not yet
    /// shared.
    pub fn publishable_stripes(&self) -> Vec<usize> {
        (0..self.full_stripes())
            .filter(|&p| !self.stripe_spilled(p) && !self.stripe_shared(p))
            .collect()
    }

    /// Publish stripe `p`: move every chain's page `p` payload behind an
    /// `Arc<SealedPage>` (reads continue through the shared copy,
    /// bit-identical; the session's private bytes for the stripe drop to
    /// zero) and record the stripe as shared under `hash`.
    pub fn seal_stripe(&mut self, p: usize, hash: u64) -> Vec<Arc<SealedPage>> {
        assert!(!self.stripe_spilled(p) && !self.stripe_shared(p), "stripe not publishable");
        let arcs: Vec<Arc<SealedPage>> =
            self.chains.iter_mut().map(|c| c.page_mut(p).seal_shared()).collect();
        let at = self.shared.partition_point(|&(s, _)| s < p);
        self.shared.insert(at, (p, hash));
        arcs
    }

    /// Dedup at publication: an identical stripe already lives in the
    /// registry, so drop stripe `p`'s private pages and reference the
    /// registry copies instead (bit-identical by construction — same
    /// token prefix, same packing config).
    pub fn share_stripe(&mut self, p: usize, pages: &[Arc<SealedPage>], hash: u64) {
        assert!(!self.stripe_spilled(p) && !self.stripe_shared(p), "stripe not publishable");
        assert_eq!(pages.len(), self.chains.len(), "one shared page per chain");
        for (c, arc) in self.chains.iter_mut().zip(pages) {
            c.page_mut(p).replace_with_shared(Arc::clone(arc));
        }
        let at = self.shared.partition_point(|&(s, _)| s < p);
        self.shared.insert(at, (p, hash));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_token(kv: &mut LayeredKv, tok: i32, fill: f32) {
        let g = kv.geom();
        for l in 0..g.n_layers {
            for h in 0..g.n_heads {
                kv.chain_mut(l, h).append_row(&vec![fill; g.d_head], &vec![fill; g.d_head]);
            }
        }
        kv.note_token(tok);
    }

    #[test]
    fn tokens_advance_in_lock_step() {
        let geom = KvGeom { n_layers: 2, n_heads: 3, d_head: 16 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert!(kv.is_empty());
        assert_eq!(kv.geom().chains(), 6);
        for (i, tok) in [5i32, 7, 9].iter().enumerate() {
            push_token(&mut kv, *tok, i as f32);
            assert_eq!(kv.len(), i + 1);
        }
        assert_eq!(kv.tokens(), &[5, 7, 9]);
        for l in 0..2 {
            for h in 0..3 {
                assert_eq!(kv.chain(l, h).len(), 3);
            }
        }
    }

    #[test]
    fn prefix_identity() {
        let geom = KvGeom { n_layers: 1, n_heads: 2, d_head: 8 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert!(kv.is_prefix_of(&[1, 2, 3]), "empty state is a prefix of anything");
        push_token(&mut kv, 1, 0.0);
        push_token(&mut kv, 2, 1.0);
        assert!(kv.is_prefix_of(&[1, 2]));
        assert!(kv.is_prefix_of(&[1, 2, 3]));
        assert!(!kv.is_prefix_of(&[1, 9, 3]), "mismatched id");
        assert!(!kv.is_prefix_of(&[1]), "resident state longer than the request");
    }

    #[test]
    fn truncate_and_reset_roll_back_every_chain() {
        let geom = KvGeom { n_layers: 2, n_heads: 2, d_head: 8 };
        let mut kv = LayeredKv::new(geom, 2, ValueDtype::Bf16);
        for t in 0..5 {
            push_token(&mut kv, t, t as f32);
        }
        let full = kv.bytes();
        assert!(full > 0);
        kv.truncate(2);
        assert_eq!(kv.len(), 2);
        assert_eq!(kv.tokens(), &[0, 1]);
        assert!(kv.bytes() < full, "dropping pages releases bytes");
        assert!(kv.chains.iter().all(|c| c.len() == 2));
        kv.reset();
        assert!(kv.is_empty());
        assert_eq!(kv.bytes(), 0);
    }

    #[test]
    fn bytes_are_the_sum_of_chain_pages() {
        let geom = KvGeom { n_layers: 2, n_heads: 2, d_head: 64 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        push_token(&mut kv, 3, 0.5);
        // 4 chains x one page x 4 tokens x (8 B key + 64*4 B value)
        assert_eq!(kv.bytes(), 4 * 4 * (8 + 256));
    }

    fn spill_store() -> SpillStore {
        SpillStore::create(&std::env::temp_dir().join("had-spill-test"), None).unwrap()
    }

    fn filled(tokens: usize, page_tokens: usize) -> LayeredKv {
        let geom = KvGeom { n_layers: 2, n_heads: 2, d_head: 16 };
        let mut kv = LayeredKv::new(geom, page_tokens, ValueDtype::F32);
        for t in 0..tokens {
            // vary sign and magnitude per (token, chain) so stripes differ
            push_token(&mut kv, t as i32, (t as f32 - 3.5) * 0.4);
        }
        kv
    }

    fn assert_same_kv(a: &LayeredKv, b: &LayeredKv) {
        assert_eq!(a.tokens(), b.tokens());
        let g = a.geom();
        let mut ra = vec![0.0f32; g.d_head];
        let mut rb = vec![0.0f32; g.d_head];
        for l in 0..g.n_layers {
            for h in 0..g.n_heads {
                let (ca, cb) = (a.chain(l, h), b.chain(l, h));
                assert_eq!(ca.len(), cb.len());
                for i in 0..ca.len() {
                    assert_eq!(ca.key(i), cb.key(i), "chain ({l},{h}) key {i}");
                    ca.value_into(i, &mut ra);
                    cb.value_into(i, &mut rb);
                    assert_eq!(ra, rb, "chain ({l},{h}) value {i}");
                }
            }
        }
    }

    #[test]
    fn spill_hydrate_roundtrip_is_bit_identical() {
        let store = spill_store();
        let mut kv = filled(10, 4); // 2 full stripes + 2-token tail
        let oracle = kv.clone();
        let resident = kv.bytes();
        assert_eq!(kv.full_stripes(), 2);
        assert!(kv.has_spillable());

        let (freed1, pages1) = kv.spill_one(&store).expect("first stripe spills");
        assert_eq!(pages1, kv.geom().chains());
        let (freed2, _) = kv.spill_one(&store).expect("second stripe spills");
        assert!(kv.spill_one(&store).is_none(), "tail page never spills");
        assert_eq!(kv.spilled_stripes(), 2);
        assert!(!kv.fully_resident());
        assert_eq!(kv.bytes(), resident - freed1 - freed2);
        assert_eq!(kv.len(), 10, "spill does not change the token record");
        assert_eq!(store.live_records(), 2);

        let (pages_in, failures) = kv.hydrate(&store);
        assert_eq!((pages_in, failures), (2 * kv.geom().chains(), 0));
        assert!(kv.fully_resident());
        assert_eq!(kv.bytes(), resident);
        assert_eq!(store.live_records(), 0, "hydrate releases the records");
        assert_same_kv(&kv, &oracle);
    }

    #[test]
    fn truncate_inside_spilled_stripe_clamps_to_stripe_start() {
        let store = spill_store();
        let mut kv = filled(8, 4);
        let oracle = kv.clone();
        kv.spill_one(&store).unwrap();
        kv.spill_one(&store).unwrap();

        kv.truncate(6); // cuts inside spilled stripe 1 -> clamps to 4
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.spilled_stripes(), 1);
        let released = kv.drain_released();
        assert_eq!(released.len(), 1);
        for tag in released {
            store.release(tag);
        }
        assert_eq!(store.live_records(), 1);

        let (pages_in, failures) = kv.hydrate(&store);
        assert_eq!((pages_in, failures), (kv.geom().chains(), 0));
        let mut expect = oracle;
        expect.truncate(4);
        assert_same_kv(&kv, &expect);
        assert_eq!(store.live_records(), 0);
    }

    #[test]
    fn failed_hydrate_truncates_to_resident_prefix_and_releases() {
        let store = spill_store();
        let mut kv = filled(9, 4);
        kv.spill_one(&store).unwrap();
        kv.spill_one(&store).unwrap();
        // Re-open the spill file behind the index and corrupt stripe 0's
        // record so its hydrating read fails the checksum.
        {
            use std::io::{Seek, SeekFrom, Write};
            let mut f = std::fs::File::options().write(true).open(store.path()).unwrap();
            f.seek(SeekFrom::Start(16 + 16 + 3)).unwrap();
            f.write_all(&[0xAA]).unwrap();
        }
        let (pages_in, failures) = kv.hydrate(&store);
        assert_eq!(failures, 1);
        assert_eq!(pages_in, 0, "stripe 0 failed; stripe 1 is dropped, not read");
        assert!(kv.is_empty(), "everything at or after the bad stripe is gone");
        assert!(kv.fully_resident());
        assert_eq!(store.live_records(), 0, "failed hydrate still releases records");
        // The cache remains usable: re-prefill from scratch.
        push_token(&mut kv, 42, 0.5);
        assert_eq!(kv.tokens(), &[42]);
    }

    #[test]
    fn spill_write_fault_degrades_to_none() {
        let plan = std::sync::Arc::new(crate::util::fault::FaultPlan::parse("spill_write").unwrap());
        let store =
            SpillStore::create(&std::env::temp_dir().join("had-spill-test"), Some(plan)).unwrap();
        let mut kv = filled(4, 4);
        let before = kv.bytes();
        assert!(kv.spill_one(&store).is_none(), "refused write degrades, never wedges");
        assert!(kv.fully_resident());
        assert_eq!(kv.bytes(), before);
    }

    #[test]
    fn seal_then_adopt_stripe_is_bit_identical_across_sessions() {
        let mut leader = filled(10, 4); // 2 full stripes + tail
        let oracle = leader.clone();
        let geom = leader.stripe_geom();
        let hashes = crate::kvcache::shared::stripe_hashes(&geom, leader.tokens());
        assert_eq!(hashes.len(), 2);
        let full_bytes = leader.bytes();

        let mut follower = LayeredKv::new(leader.geom(), 4, ValueDtype::F32);
        for (p, &h) in hashes.iter().enumerate() {
            let toks: Vec<i32> = oracle.tokens()[p * 4..(p + 1) * 4].to_vec();
            let arcs = leader.seal_stripe(p, h);
            follower.adopt_stripe(&toks, arcs, h);
        }
        // Leader still reads its own bits through the shared payloads.
        assert_same_kv(&leader, &oracle);
        assert!(leader.bytes() < full_bytes, "sealed stripes leave private accounting");
        assert_eq!(leader.shared_stripes(), 2);
        assert_eq!(leader.shared_hashes(), hashes);

        // Follower holds the first 8 tokens without any prefill...
        assert_eq!(follower.len(), 8);
        assert_eq!(follower.bytes(), 0, "adopted stripes cost no private bytes");
        let mut expect = oracle.clone();
        expect.truncate(8);
        assert_same_kv(&follower, &expect);
        // ...and keeps decoding privately past them.
        push_token(&mut follower, 99, 0.7);
        assert_eq!(follower.len(), 9);
        assert!(follower.bytes() > 0);
    }

    #[test]
    fn truncate_inside_shared_stripe_is_copy_on_write() {
        let mut kv = filled(8, 4);
        let oracle = kv.clone();
        let geom = kv.stripe_geom();
        let hashes = crate::kvcache::shared::stripe_hashes(&geom, kv.tokens());
        let arcs: Vec<_> = hashes.iter().enumerate().map(|(p, &h)| kv.seal_stripe(p, h)).collect();
        assert_eq!(kv.bytes(), 0, "fully shared cache has no private bytes");

        // Cut inside stripe 0: its pages COW to private copies; stripe 1
        // is wholly dropped. Both references are buffered for release.
        kv.truncate(2);
        assert_eq!(kv.len(), 2, "shared cuts do not clamp — COW keeps the partial page");
        assert_eq!(kv.take_cow(), kv.geom().chains() as u64);
        assert_eq!(kv.take_cow(), 0, "take_cow drains");
        let mut released = kv.drain_released_shared();
        released.sort_unstable();
        let mut want = hashes.clone();
        want.sort_unstable();
        assert_eq!(released, want);
        assert_eq!(kv.shared_stripes(), 0);
        assert!(kv.bytes() > 0, "the COW copy is private residency again");
        let mut expect = oracle.clone();
        expect.truncate(2);
        assert_same_kv(&kv, &expect);

        // The registry copies were never touched by the divergence.
        let mut reread = LayeredKv::new(oracle.geom(), 4, ValueDtype::F32);
        reread.adopt_stripe(&oracle.tokens()[..4], arcs[0].clone(), hashes[0]);
        let mut first = oracle;
        first.truncate(4);
        assert_same_kv(&reread, &first);
    }

    #[test]
    fn shared_stripes_never_spill_from_a_session() {
        let store = spill_store();
        let mut kv = filled(8, 4);
        let geom = kv.stripe_geom();
        let hashes = crate::kvcache::shared::stripe_hashes(&geom, kv.tokens());
        kv.seal_stripe(0, hashes[0]);
        assert!(kv.has_spillable(), "stripe 1 is still private");
        let (_, pages) = kv.spill_one(&store).expect("private stripe spills");
        assert_eq!(pages, kv.geom().chains());
        assert!(!kv.has_spillable(), "shared stripe 0 is not a spill candidate");
        assert!(kv.spill_one(&store).is_none());
        assert_eq!(kv.publishable_stripes(), Vec::<usize>::new());
    }

    #[test]
    fn bytes_at_predicts_actual_residency() {
        let geom = KvGeom { n_layers: 2, n_heads: 3, d_head: 16 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert_eq!(kv.bytes_at(0), 0);
        for t in 0..9 {
            push_token(&mut kv, t, 0.25);
            assert_eq!(
                kv.bytes(),
                kv.bytes_at(kv.len()),
                "projection must equal residency at {} tokens",
                kv.len()
            );
        }
        // page-granular: 5..=8 tokens all cost two pages
        assert_eq!(kv.bytes_at(5), kv.bytes_at(8));
        assert!(kv.bytes_at(9) > kv.bytes_at(8));
    }
}
