//! Paged bit-packed KV cache with session-aware incremental serving.
//!
//! The paper's central efficiency claim for long-context inference is
//! **packed-K residency**: binarized keys cost 1 bit per element, so the
//! score-side state of a sequence is 32x smaller than f32 keys and can
//! stay resident across queries instead of being rebuilt per request.
//! This module turns that claim into a serving subsystem:
//!
//! * [`page::Page`] — fixed-size pages holding `page_tokens` tokens of
//!   packed sign-bit keys (`ceil(d/64)` u64 words per token) plus values
//!   in f32 or, config-gated, bf16 ([`ValueDtype`], halving the dense
//!   half of residency; keys are 1-bit either way).
//! * [`session::SessionKv`] — a per-session chain of pages with
//!   append/seal/truncate handles: turn N packs only its new tokens
//!   (incremental prefill and decode), resident pages are never copied.
//! * [`layered::LayeredKv`] — the serving backend's unit of residency:
//!   one chain per (layer, head) pair advancing in lock step per decoded
//!   token, plus the decoded token ids so a later turn can verify prefix
//!   identity and resume instead of re-executing the sequence.
//! * [`pool::PagePool`] — a global byte-budgeted pool with LRU eviction
//!   at session granularity and hit/miss/eviction accounting; generic
//!   over the entry kind (`PagePool<SessionKv>` for flat chains,
//!   `PagePool<LayeredKv>` for full decode states, which the coordinator
//!   checks out per batch with `take` and back in with `insert`).
//! * [`config::KvCacheConfig`] — sizing knobs and capacity math.
//! * [`shared::SharedIndex`] — the cross-session prefix registry:
//!   sealed full stripes gain a content-hash identity (FNV-64 over the
//!   token prefix, seeded by the packing config), are deduped into
//!   refcounted shared entries ([`page::SealedPage`] behind an `Arc`),
//!   and are adopted by later identical prompts so N streams over one
//!   prompt pay its prefill once; divergence copies-on-write.
//!
//! `binary::attention::had_attention_paged` scores XNOR-popcount directly
//! over the non-contiguous pages, bit-identical to the contiguous
//! `had_attention` fast path (property-tested in rust/tests).
//!
//! ## Residency math
//!
//! For head dim `d = 64` and `page_tokens = 64`, one page's keys cost
//! `64 tokens x 8 B = 512 B` versus `64 x 64 x 4 B = 16 KiB` for f32 keys
//! — the 32x reduction (64x vs bf16 would be 2 B/element, 16x). Values
//! stay dense (the paper binarizes only Q and K) at f32 by default —
//! 16 KiB/page at `d_v = 64` — or 8 KiB/page under `ValueDtype::Bf16`,
//! while the *scoring* working set shrinks 32x and values are touched
//! just `n_top` times per query after selection. A 32 MiB default budget
//! therefore holds ~2000 pages (~128k tokens) of full f32 KV state,
//! ~2x that with bf16 values — and at 8 B/token of packed keys, ~4M
//! tokens of key-only scoring state.

pub mod config;
pub mod layered;
pub mod page;
pub mod pool;
pub mod session;
pub mod shared;

pub use config::{KvCacheConfig, ValueDtype};
pub use layered::{KvGeom, LayeredKv};
pub use page::{Page, SealedPage};
pub use pool::{Admission, CacheStats, PagePool, PooledKv};
pub use session::SessionKv;
pub use shared::{prompt_claim_key, SharedIndex, StripeGeom};
