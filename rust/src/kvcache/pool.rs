//! Global byte-budgeted page pool: owns every resident session's pages,
//! evicts least-recently-used sessions when the budget is exceeded, and
//! keeps hit/miss/eviction accounting for the serving metrics.
//!
//! The pool is generic over what a "session" holds: the default
//! `PagePool<SessionKv>` is the single-chain pool the admission benches
//! and flat scoring paths use, and `PagePool<LayeredKv>` is the serving
//! backend's pool of full per-layer decode states (checked out for a
//! batch's decode with [`PagePool::take`], checked back in with
//! [`PagePool::insert`]).

use std::collections::HashMap;
use std::sync::Arc;

use crate::kvcache::config::KvCacheConfig;
use crate::kvcache::layered::LayeredKv;
use crate::kvcache::session::SessionKv;
use crate::kvcache::shared::{stripe_hashes, Acquire, Publish, SharedIndex, StripeGeom};
use crate::store::SpillStore;
use crate::tensor::Mat;

/// What the pool needs from a resident entry: byte accounting, a token
/// count for `cached_tokens`, rollback support, and (optionally) a
/// page-granular spill tier. The spill methods default to "no spill"
/// so single-chain pools keep their destroy-on-evict behavior.
pub trait PooledKv {
    fn bytes(&self) -> usize;
    fn tokens(&self) -> usize;
    fn truncate(&mut self, len: usize);
    /// Move one cold full stripe to `store`, returning
    /// `(bytes freed, pages spilled)`; `None` when nothing is spillable
    /// or the store refused the write.
    fn spill_one(&mut self, _store: &SpillStore) -> Option<(usize, usize)> {
        None
    }
    /// Is there a resident full stripe left to spill?
    fn has_spillable(&self) -> bool {
        false
    }
    /// Spill tags this entry still references (released when the entry
    /// is dropped wholesale).
    fn spill_tags(&self) -> Vec<u64> {
        Vec::new()
    }
    /// Tags buffered by a truncate, to release against the store.
    fn drain_released(&mut self) -> Vec<u64> {
        Vec::new()
    }
    /// Content hashes of shared prefix stripes this entry references
    /// (released against the registry when the entry is dropped
    /// wholesale).
    fn shared_refs(&self) -> Vec<u64> {
        Vec::new()
    }
    /// Hashes buffered by a truncate, to release against the registry.
    fn drain_released_shared(&mut self) -> Vec<u64> {
        Vec::new()
    }
    /// Copy-on-write page materializations since the last call.
    fn take_cow(&mut self) -> u64 {
        0
    }
}

impl PooledKv for SessionKv {
    fn bytes(&self) -> usize {
        SessionKv::bytes(self)
    }
    fn tokens(&self) -> usize {
        self.len()
    }
    fn truncate(&mut self, len: usize) {
        SessionKv::truncate(self, len)
    }
}

impl PooledKv for LayeredKv {
    fn bytes(&self) -> usize {
        LayeredKv::bytes(self)
    }
    fn tokens(&self) -> usize {
        self.len()
    }
    fn truncate(&mut self, len: usize) {
        LayeredKv::truncate(self, len)
    }
    fn spill_one(&mut self, store: &SpillStore) -> Option<(usize, usize)> {
        LayeredKv::spill_one(self, store)
    }
    fn has_spillable(&self) -> bool {
        LayeredKv::has_spillable(self)
    }
    fn spill_tags(&self) -> Vec<u64> {
        LayeredKv::spill_tags(self)
    }
    fn drain_released(&mut self) -> Vec<u64> {
        LayeredKv::drain_released(self)
    }
    fn shared_refs(&self) -> Vec<u64> {
        LayeredKv::shared_hashes(self)
    }
    fn drain_released_shared(&mut self) -> Vec<u64> {
        LayeredKv::drain_released_shared(self)
    }
    fn take_cow(&mut self) -> u64 {
        LayeredKv::take_cow(self)
    }
}

/// Cumulative cache counters (monotone; snapshot and diff as needed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// admissions that found the session resident
    pub hits: u64,
    /// admissions that had to start (or restart) a session cold
    pub misses: u64,
    /// sessions evicted to honor the byte budget
    pub evictions: u64,
    /// bytes released by evictions
    pub evicted_bytes: u64,
    /// chain-pages moved to the disk spill tier instead of destroyed
    pub spill_pages_out: u64,
    /// chain-pages hydrated back from the spill tier at checkout
    pub spill_pages_in: u64,
    /// resident bytes freed by moving stripes to the spill tier
    pub spill_bytes: u64,
    /// checkouts that hydrated at least one page (re-prefill avoided)
    pub hydrate_hits: u64,
    /// store reads that failed verification (fault, IO, checksum)
    pub store_checksum_failures: u64,
    /// chain-pages published into (or deduped against) the prefix
    /// registry — each one is a page whose bytes are accounted once
    /// however many sessions reference it
    pub shared_pages: u64,
    /// admissions that adopted at least one shared prefix stripe
    pub prefix_hits: u64,
    /// prompt tokens whose prefill was skipped via shared-prefix adoption
    pub prefix_tokens_reused: u64,
    /// pages privately re-materialized by copy-on-write when a session
    /// diverged inside a shared stripe
    pub cow_copies: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Outcome of one admission: how much of the sequence was already
/// resident vs. newly packed.
#[derive(Clone, Copy, Debug)]
pub struct Admission {
    pub hit: bool,
    /// tokens already resident before this admission (reused work)
    pub reused_tokens: usize,
    /// tokens packed by this admission (new work)
    pub appended_tokens: usize,
}

struct Entry<T> {
    kv: T,
    last_used: u64,
}

/// The pool. Not internally synchronized — the coordinator wraps it in a
/// Mutex (admission is cheap next to model execution).
pub struct PagePool<T: PooledKv = SessionKv> {
    cfg: KvCacheConfig,
    sessions: HashMap<u64, Entry<T>>,
    clock: u64,
    bytes: usize,
    stats: CacheStats,
    /// Disk spill tier. When set, `enforce_budget` spills cold full
    /// stripes page-granularly before falling back to whole-session
    /// eviction.
    spill: Option<Arc<SpillStore>>,
    /// Cross-session prefix registry. When set, identical prompt prefixes
    /// share one refcounted copy of their packed pages (`self.bytes`
    /// keeps tracking private bytes only; `bytes()` adds the registry's).
    shared: Option<SharedIndex>,
}

impl<T: PooledKv> PagePool<T> {
    pub fn new(cfg: KvCacheConfig) -> PagePool<T> {
        PagePool {
            cfg,
            sessions: HashMap::new(),
            clock: 0,
            bytes: 0,
            stats: CacheStats::default(),
            spill: None,
            shared: None,
        }
    }

    /// Attach (or detach) the disk spill tier.
    pub fn set_spill(&mut self, store: Option<Arc<SpillStore>>) {
        self.spill = store;
    }

    pub fn spill_store(&self) -> Option<&Arc<SpillStore>> {
        self.spill.as_ref()
    }

    /// Enable (or disable) cross-session prefix sharing. Off by default;
    /// with it off every path behaves exactly as before the registry
    /// existed.
    pub fn set_prefix_sharing(&mut self, on: bool) {
        self.shared = if on { Some(SharedIndex::new()) } else { None };
    }

    /// Is the prefix registry attached?
    #[inline]
    pub fn prefix_sharing(&self) -> bool {
        self.shared.is_some()
    }

    /// The prefix registry (tests/metrics introspection).
    pub fn shared_index(&self) -> Option<&SharedIndex> {
        self.shared.as_ref()
    }

    /// Release registry references, spilling (or dropping) entries whose
    /// refcount hits zero and counting the spilled pages like any other
    /// spill traffic.
    fn release_shared_all(&mut self, hashes: Vec<u64>) {
        if hashes.is_empty() {
            return;
        }
        let Some(shared) = self.shared.as_mut() else { return };
        let spill = self.spill.as_deref();
        let (mut pages, mut bytes) = (0u64, 0u64);
        for h in hashes {
            let (p, b) = shared.release(h, spill);
            if p > 0 {
                pages += p as u64;
                bytes += b as u64;
            }
        }
        self.stats.spill_pages_out += pages;
        self.stats.spill_bytes += bytes;
    }

    /// Claim `key`'s prefill for `stream`: `None` = this stream runs it,
    /// `Some(holder)` = park behind the holder. Always `None` with
    /// sharing off (nobody ever waits).
    pub fn try_claim(&mut self, key: u64, stream: u64) -> Option<u64> {
        self.shared.as_mut().and_then(|s| s.try_claim(key, stream))
    }

    /// Is `key` still claimed by a stream other than `stream`?
    pub fn claim_held_by_other(&self, key: u64, stream: u64) -> bool {
        self.shared.as_ref().is_some_and(|s| s.claim_held_by_other(key, stream))
    }

    /// Release `key` if `stream` holds it (unconditional at retirement).
    pub fn release_claim(&mut self, key: u64, stream: u64) {
        if let Some(s) = self.shared.as_mut() {
            s.release_claim(key, stream);
        }
    }

    /// Release `tags` against the spill store, if one is attached.
    fn release_all(&self, tags: Vec<u64>) {
        if let Some(store) = &self.spill {
            for tag in tags {
                store.release(tag);
            }
        }
    }

    /// Record a checkout-time hydration (the coordinator hydrates taken
    /// sessions before decode; the pool owns the counters).
    pub fn note_hydrate(&mut self, pages_in: usize, failures: usize) {
        self.stats.spill_pages_in += pages_in as u64;
        if pages_in > 0 {
            self.stats.hydrate_hits += 1;
        }
        self.stats.store_checksum_failures += failures as u64;
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Resident payload bytes: every session's private pages plus the
    /// prefix registry's shared pages, each shared page counted exactly
    /// once however many sessions reference it.
    pub fn bytes(&self) -> usize {
        self.bytes + self.shared.as_ref().map_or(0, SharedIndex::bytes)
    }

    pub fn budget(&self) -> usize {
        self.cfg.byte_budget
    }

    /// Number of resident sessions.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Count one admission-style lookup whose hit/miss outcome is decided
    /// by the caller (the layered checkout path: resident-and-reusable is
    /// a hit, absent or reset is a miss).
    pub fn record_lookup(&mut self, hit: bool) {
        if hit {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
        }
    }

    /// Tokens resident for a session (0 when absent). Does not touch LRU.
    pub fn cached_tokens(&self, session_id: u64) -> usize {
        self.sessions.get(&session_id).map_or(0, |e| e.kv.tokens())
    }

    /// Borrow a resident session for scoring; refreshes its LRU position.
    pub fn get(&mut self, session_id: u64) -> Option<&T> {
        let now = self.tick();
        let entry = self.sessions.get_mut(&session_id)?;
        entry.last_used = now;
        Some(&entry.kv)
    }

    /// Borrow without touching LRU (introspection/tests).
    pub fn peek(&self, session_id: u64) -> Option<&T> {
        self.sessions.get(&session_id).map(|e| &e.kv)
    }

    /// Check a session OUT of the pool (its bytes leave the accounting):
    /// the serving backend takes ownership for a batch's decode so appends
    /// run without holding the pool lock, then returns it via `insert`.
    pub fn take(&mut self, session_id: u64) -> Option<T> {
        let entry = self.sessions.remove(&session_id)?;
        self.bytes -= entry.kv.bytes();
        Some(entry.kv)
    }

    /// Check a session IN (back, or newly created): replaces any resident
    /// entry, refreshes LRU, then enforces the byte budget — never
    /// evicting the session just inserted. Returns the ids evicted to
    /// make room, so the caller can drop any per-session state of its own
    /// (the coordinator's token histories).
    pub fn insert(&mut self, session_id: u64, mut kv: T) -> Vec<u64> {
        let now = self.tick();
        let released = kv.drain_released();
        self.release_all(released);
        self.release_shared_all(kv.drain_released_shared());
        self.stats.cow_copies += kv.take_cow();
        if let Some(mut old) = self.sessions.remove(&session_id) {
            self.bytes -= old.kv.bytes();
            let tags = old.kv.spill_tags();
            self.release_all(tags);
            let mut hashes = old.kv.shared_refs();
            hashes.extend(old.kv.drain_released_shared());
            self.release_shared_all(hashes);
        }
        self.bytes += kv.bytes();
        self.sessions.insert(session_id, Entry { kv, last_used: now });
        self.enforce_budget(session_id)
    }

    /// Roll a session back to `len` tokens, releasing now-empty pages
    /// (admission rollback, speculative-decode rewind). Removes the
    /// session entirely at `len == 0`. No-op when absent or already at
    /// or below `len`.
    pub fn truncate_session(&mut self, session_id: u64, len: usize) {
        if len == 0 {
            self.remove(session_id);
            return;
        }
        let mut tags = Vec::new();
        let mut hashes = Vec::new();
        let mut cow = 0;
        if let Some(e) = self.sessions.get_mut(&session_id) {
            if e.kv.tokens() > len {
                let before = e.kv.bytes();
                e.kv.truncate(len);
                // COW off a shared stripe can GROW private bytes, so this
                // must be a signed adjustment, not a subtraction.
                self.bytes = self.bytes - before + e.kv.bytes();
                tags = e.kv.drain_released();
                hashes = e.kv.drain_released_shared();
                cow = e.kv.take_cow();
            }
        }
        self.release_all(tags);
        self.release_shared_all(hashes);
        self.stats.cow_copies += cow;
    }

    /// Discard a checked-out cache WITHOUT checking it back in (poisoned
    /// stream, stale history — the KV is dropped), releasing its spill
    /// records and registry references so neither leaks.
    pub fn discard(&mut self, mut kv: T) {
        let mut tags = kv.spill_tags();
        tags.extend(kv.drain_released());
        self.release_all(tags);
        let mut hashes = kv.shared_refs();
        hashes.extend(kv.drain_released_shared());
        self.stats.cow_copies += kv.take_cow();
        self.release_shared_all(hashes);
    }

    /// Drop a session outright (client disconnect). Not counted as an
    /// eviction. Returns true if it was resident.
    pub fn remove(&mut self, session_id: u64) -> bool {
        match self.sessions.remove(&session_id) {
            Some(mut e) => {
                self.bytes -= e.kv.bytes();
                let mut tags = e.kv.spill_tags();
                tags.extend(e.kv.drain_released());
                self.release_all(tags);
                let mut hashes = e.kv.shared_refs();
                hashes.extend(e.kv.drain_released_shared());
                self.stats.cow_copies += e.kv.take_cow();
                self.release_shared_all(hashes);
                true
            }
            None => false,
        }
    }

    /// Bring the pool back under its byte budget, in two passes.
    ///
    /// Pass 1 (only with a spill tier attached) is **page-granular**:
    /// the coldest session's oldest full stripes move to disk, one at a
    /// time, re-picking the coldest spillable session each step — the
    /// session stays resident and hydrates at its next checkout instead
    /// of paying re-prefill. A refused write (fault injection, IO error)
    /// falls straight through to pass 2; spilling degrades, it never
    /// wedges.
    ///
    /// Pass 2 is the original session-granular LRU eviction. `protect`
    /// (the session just admitted) is never spilled or evicted, so one
    /// session larger than the whole budget stays resident — admission
    /// control is the router's job, not the pool's. Returns the evicted
    /// ids so the caller can drop its own per-session state.
    fn enforce_budget(&mut self, protect: u64) -> Vec<u64> {
        if let Some(store) = self.spill.clone() {
            while self.bytes > self.cfg.byte_budget {
                let victim = self
                    .sessions
                    .iter()
                    .filter(|(&id, e)| id != protect && e.kv.has_spillable())
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(&id, _)| id);
                let Some(id) = victim else { break };
                let spilled = self.sessions.get_mut(&id).and_then(|e| e.kv.spill_one(&store));
                let Some((freed, pages)) = spilled else { break };
                self.bytes -= freed;
                self.stats.spill_pages_out += pages as u64;
                self.stats.spill_bytes += freed as u64;
            }
        }
        let mut evicted = Vec::new();
        while self.bytes > self.cfg.byte_budget {
            let victim = self
                .sessions
                .iter()
                .filter(|(&id, _)| id != protect)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&id, _)| id);
            let Some(id) = victim else { break };
            if let Some(mut e) = self.sessions.remove(&id) {
                let freed = e.kv.bytes();
                self.bytes -= freed;
                self.stats.evictions += 1;
                self.stats.evicted_bytes += freed as u64;
                let mut tags = e.kv.spill_tags();
                tags.extend(e.kv.drain_released());
                self.release_all(tags);
                let mut hashes = e.kv.shared_refs();
                hashes.extend(e.kv.drain_released_shared());
                self.stats.cow_copies += e.kv.take_cow();
                self.release_shared_all(hashes);
                evicted.push(id);
            }
        }
        evicted
    }
}

impl PagePool<LayeredKv> {
    /// Prefix resolution at admit: extend `kv` with every contiguous
    /// registry stripe matching `tokens`, up to (whole stripes within)
    /// `max_tokens` — the caller caps at `tokens.len() - 1` so the
    /// generation loop always has at least one token left to prefill
    /// (its logits seed the first sample). Spilled entries hydrate once,
    /// through the normal hydrate counters. Returns the tokens adopted;
    /// prefill for them never runs.
    pub fn seed_prefix(&mut self, kv: &mut LayeredKv, tokens: &[i32], max_tokens: usize) -> usize {
        if self.shared.is_none() {
            return 0;
        }
        let geom = kv.stripe_geom();
        let pt = geom.page_tokens;
        if kv.len() % pt != 0 || !kv.is_prefix_of(tokens) {
            return 0;
        }
        let hashes = stripe_hashes(&geom, tokens);
        let start = kv.len() / pt;
        let mut adopted = 0;
        let (mut pages_in, mut failed) = (0usize, 0usize);
        for p in start..hashes.len() {
            let end = (p + 1) * pt;
            if end > max_tokens {
                break;
            }
            let (shared, spill) = (self.shared.as_mut().unwrap(), self.spill.as_deref());
            match shared.acquire(hashes[p], &tokens[..end], &geom, spill) {
                Acquire::Hit { pages, hydrated_pages } => {
                    pages_in += hydrated_pages;
                    kv.adopt_stripe(&tokens[p * pt..end], pages, hashes[p]);
                    adopted += 1;
                }
                Acquire::Miss { failed_reads } => {
                    failed += failed_reads;
                    break; // adopted stripes must stay contiguous
                }
            }
        }
        if pages_in > 0 || failed > 0 {
            self.note_hydrate(pages_in, failed);
        }
        if adopted > 0 {
            self.stats.prefix_hits += 1;
            self.stats.prefix_tokens_reused += (adopted * pt) as u64;
        }
        adopted * pt
    }

    /// Publish every full, private, resident stripe of `kv` into the
    /// registry (called on checked-out caches: at checkin and per tick
    /// during generation, so followers can adopt a long prefill while it
    /// is still running). An identical registered stripe dedupes — the
    /// private copy is dropped and the registry copy referenced,
    /// bit-identical by construction. No-op with sharing off, and cheap
    /// when everything already published.
    pub fn publish_prefix(&mut self, kv: &mut LayeredKv) {
        if self.shared.is_none() {
            return;
        }
        let stripes = kv.publishable_stripes();
        if stripes.is_empty() {
            return;
        }
        let geom = kv.stripe_geom();
        let pt = geom.page_tokens;
        let toks = kv.tokens().to_vec();
        let hashes = stripe_hashes(&geom, &toks);
        for p in stripes {
            let end = (p + 1) * pt;
            let (shared, spill) = (self.shared.as_mut().unwrap(), self.spill.as_deref());
            match shared.prepare_publish(hashes[p], &toks[..end], spill) {
                Publish::Dedupe(pages) => {
                    kv.share_stripe(p, &pages, hashes[p]);
                    self.stats.shared_pages += geom.chains as u64;
                }
                Publish::Adopt => {
                    let arcs = kv.seal_stripe(p, hashes[p]);
                    self.shared.as_mut().unwrap().complete_publish(hashes[p], &toks[..end], arcs);
                    self.stats.shared_pages += geom.chains as u64;
                }
                Publish::Skip => {}
            }
        }
    }

    /// Are all full stripes of `tokens` within `max_tokens` registered?
    /// The parked follower's wake condition; trivially true with sharing
    /// off or when the prompt has no full stripe below the cap (such a
    /// stream never waits).
    pub fn prefix_covered(&self, geom: &StripeGeom, tokens: &[i32], max_tokens: usize) -> bool {
        let Some(shared) = self.shared.as_ref() else { return true };
        let target = max_tokens.min(tokens.len()) / geom.page_tokens;
        shared.covers(geom, tokens, target)
    }
}

impl PagePool<SessionKv> {
    /// Admit `k`/`v` rows for a session (head geometry is `k.cols` /
    /// `v.cols`): appends to the resident pages on a hit, starts a cold
    /// session on a miss, then enforces the byte budget by evicting LRU
    /// sessions (never the one just admitted).
    pub fn append(&mut self, session_id: u64, k: &Mat, v: &Mat) -> Admission {
        let (d, d_v) = (k.cols, v.cols);
        let now = self.tick();
        let page_tokens = self.cfg.page_tokens;
        let dtype = self.cfg.value_dtype;
        // A geometry change is a protocol error from the same session id;
        // treat it as a cold restart rather than corrupting pages.
        let stale = self
            .sessions
            .get(&session_id)
            .map_or(false, |e| e.kv.d() != d || e.kv.d_v() != d_v);
        if stale {
            self.remove(session_id);
        }
        let hit = self.sessions.contains_key(&session_id);
        let entry = self.sessions.entry(session_id).or_insert_with(|| Entry {
            kv: SessionKv::new_with(d, d_v, page_tokens, dtype),
            last_used: now,
        });
        entry.last_used = now;
        let before = entry.kv.bytes();
        let reused_tokens = entry.kv.len();
        entry.kv.append(k, v);
        let after = entry.kv.bytes();
        self.bytes += after - before;
        if hit {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
        }
        self.enforce_budget(session_id);
        Admission { hit, reused_tokens, appended_tokens: k.rows }
    }

    /// Seal a session (no further appends accepted by SessionKv).
    pub fn seal(&mut self, session_id: u64) {
        if let Some(e) = self.sessions.get_mut(&session_id) {
            e.kv.seal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::layered::KvGeom;
    use crate::kvcache::ValueDtype;
    use crate::util::rng::Rng;

    const D: usize = 64;
    const DV: usize = 16;

    fn kvmats(rng: &mut Rng, rows: usize) -> (Mat, Mat) {
        (Mat::random(rows, D, rng, 1.0), Mat::random(rows, DV, rng, 1.0))
    }

    /// page payload for the test geometry: 8 tokens * (8 B key + 64 B val)
    fn page_bytes() -> usize {
        8 * (8 + DV * 4)
    }

    fn pool(budget_pages: usize) -> PagePool {
        PagePool::new(KvCacheConfig {
            page_tokens: 8,
            byte_budget: budget_pages * page_bytes(),
            ..Default::default()
        })
    }

    #[test]
    fn hit_miss_accounting() {
        let mut rng = Rng::new(1);
        let mut p = pool(100);
        let (k, v) = kvmats(&mut rng, 8);
        let a = p.append(1, &k, &v);
        assert!(!a.hit);
        assert_eq!((a.reused_tokens, a.appended_tokens), (0, 8));
        let (k2, v2) = kvmats(&mut rng, 4);
        let b = p.append(1, &k2, &v2);
        assert!(b.hit);
        assert_eq!((b.reused_tokens, b.appended_tokens), (8, 4));
        let stats = p.stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(p.cached_tokens(1), 12);
        assert_eq!(p.cached_tokens(2), 0);
    }

    #[test]
    fn byte_budget_enforced() {
        let mut rng = Rng::new(2);
        let mut p = pool(3); // room for 3 pages total
        for id in 0..5u64 {
            let (k, v) = kvmats(&mut rng, 8); // one page per session
            p.append(id, &k, &v);
            assert!(p.bytes() <= p.budget(), "over budget after session {id}");
        }
        assert_eq!(p.len(), 3);
        assert_eq!(p.stats().evictions, 2);
        assert_eq!(p.stats().evicted_bytes, 2 * page_bytes() as u64);
        // oldest sessions 0 and 1 are gone; 2..=4 resident
        assert!(p.peek(0).is_none() && p.peek(1).is_none());
        assert!(p.peek(2).is_some() && p.peek(4).is_some());
    }

    #[test]
    fn lru_order_respects_touch() {
        let mut rng = Rng::new(3);
        let mut p = pool(3);
        for id in 0..3u64 {
            let (k, v) = kvmats(&mut rng, 8);
            p.append(id, &k, &v);
        }
        // touch 0 so 1 becomes LRU
        assert!(p.get(0).is_some());
        let (k, v) = kvmats(&mut rng, 8);
        p.append(3, &k, &v);
        assert!(p.peek(1).is_none(), "LRU victim must be the untouched session");
        assert!(p.peek(0).is_some() && p.peek(2).is_some() && p.peek(3).is_some());
    }

    #[test]
    fn admitted_session_never_evicted_even_oversized() {
        let mut rng = Rng::new(4);
        let mut p = pool(2);
        let (k, v) = kvmats(&mut rng, 5 * 8); // 5 pages > 2-page budget
        p.append(7, &k, &v);
        assert!(p.peek(7).is_some());
        assert_eq!(p.len(), 1);
        assert!(p.bytes() > p.budget(), "oversized single session stays");
        // next admission of another session evicts the oversized one
        let (k2, v2) = kvmats(&mut rng, 8);
        p.append(8, &k2, &v2);
        assert!(p.peek(7).is_none() && p.peek(8).is_some());
        assert!(p.bytes() <= p.budget());
    }

    #[test]
    fn truncate_session_releases_page_bytes() {
        let mut rng = Rng::new(7);
        let mut p = pool(10);
        let (k, v) = kvmats(&mut rng, 20); // 3 pages at 8 tokens/page
        p.append(1, &k, &v);
        assert_eq!(p.bytes(), 3 * page_bytes());
        p.truncate_session(1, 8);
        assert_eq!(p.cached_tokens(1), 8);
        assert_eq!(p.bytes(), page_bytes());
        p.truncate_session(1, 64); // above current length: no-op
        assert_eq!(p.cached_tokens(1), 8);
        p.truncate_session(1, 0);
        assert_eq!((p.bytes(), p.len()), (0, 0));
        p.truncate_session(99, 5); // absent session: no-op
        assert_eq!(p.stats().evictions, 0);
    }

    #[test]
    fn remove_releases_bytes_without_eviction_count() {
        let mut rng = Rng::new(5);
        let mut p = pool(10);
        let (k, v) = kvmats(&mut rng, 8);
        p.append(1, &k, &v);
        assert_eq!(p.bytes(), page_bytes());
        assert!(p.remove(1));
        assert!(!p.remove(1));
        assert_eq!(p.bytes(), 0);
        assert_eq!(p.stats().evictions, 0);
    }

    #[test]
    fn geometry_change_restarts_cold() {
        let mut rng = Rng::new(6);
        let mut p = pool(10);
        let (k, v) = kvmats(&mut rng, 8);
        p.append(1, &k, &v);
        let k2 = Mat::random(4, 32, &mut rng, 1.0);
        let v2 = Mat::random(4, 8, &mut rng, 1.0);
        let a = p.append(1, &k2, &v2);
        assert!(!a.hit);
        assert_eq!(p.cached_tokens(1), 4);
    }

    #[test]
    fn bf16_config_flows_into_new_sessions() {
        let mut rng = Rng::new(8);
        let mut p: PagePool = PagePool::new(KvCacheConfig {
            page_tokens: 8,
            byte_budget: 1 << 20,
            value_dtype: ValueDtype::Bf16,
        });
        let (k, v) = kvmats(&mut rng, 8);
        p.append(1, &k, &v);
        assert_eq!(p.peek(1).unwrap().value_dtype(), ValueDtype::Bf16);
        assert_eq!(p.bytes(), 8 * (8 + DV * 2));
    }

    fn layered(tokens: usize) -> LayeredKv {
        let geom = KvGeom { n_layers: 2, n_heads: 2, d_head: 16 };
        let mut kv = LayeredKv::new(geom, 4, ValueDtype::F32);
        for t in 0..tokens {
            for l in 0..2 {
                for h in 0..2 {
                    kv.chain_mut(l, h).append_row(&[0.5; 16], &[0.5; 16]);
                }
            }
            kv.note_token(t as i32);
        }
        kv
    }

    #[test]
    fn layered_take_insert_roundtrip_keeps_accounting() {
        let mut p: PagePool<LayeredKv> =
            PagePool::new(KvCacheConfig { page_tokens: 4, byte_budget: 1 << 20, ..Default::default() });
        assert!(p.take(1).is_none());
        let kv = layered(6);
        let kv_bytes = PooledKv::bytes(&kv);
        assert!(p.insert(1, kv).is_empty());
        assert_eq!(p.bytes(), kv_bytes);
        assert_eq!(p.cached_tokens(1), 6);
        let out = p.take(1).expect("resident");
        assert_eq!(out.len(), 6);
        assert_eq!((p.bytes(), p.len()), (0, 0));
        // re-inserting a replacement does not double count
        p.insert(1, layered(2));
        p.insert(1, layered(6));
        assert_eq!(p.bytes(), kv_bytes);
    }

    fn spill_store() -> Arc<SpillStore> {
        Arc::new(
            SpillStore::create(&std::env::temp_dir().join("had-spill-test"), None).unwrap(),
        )
    }

    #[test]
    fn budget_pressure_spills_pages_before_evicting_sessions() {
        let one = PooledKv::bytes(&layered(4)); // exactly one full stripe
        let mut p: PagePool<LayeredKv> = PagePool::new(KvCacheConfig {
            page_tokens: 4,
            byte_budget: 2 * one,
            ..Default::default()
        });
        let store = spill_store();
        p.set_spill(Some(Arc::clone(&store)));
        p.insert(1, layered(4));
        p.insert(2, layered(4));
        let evicted = p.insert(3, layered(4));
        assert!(evicted.is_empty(), "spill absorbed the pressure, nobody evicted");
        assert_eq!(p.len(), 3, "all sessions stay resident");
        assert!(p.bytes() <= p.budget());
        let s = p.stats();
        assert_eq!(s.evictions, 0);
        assert_eq!(s.spill_pages_out, 4, "one stripe = n_layers * n_heads pages");
        assert_eq!(s.spill_bytes, one as u64);
        assert_eq!(store.live_records(), 1);
        let coldest = p.peek(1).unwrap();
        assert_eq!(coldest.spilled_stripes(), 1, "the LRU session's stripe spilled");
        assert!(p.peek(2).unwrap().fully_resident());
    }

    #[test]
    fn spill_write_fault_degrades_to_plain_eviction() {
        use crate::util::fault::FaultPlan;
        let one = PooledKv::bytes(&layered(4));
        let mut p: PagePool<LayeredKv> = PagePool::new(KvCacheConfig {
            page_tokens: 4,
            byte_budget: 2 * one,
            ..Default::default()
        });
        let plan = Arc::new(FaultPlan::parse("spill_write").unwrap());
        let store = Arc::new(
            SpillStore::create(&std::env::temp_dir().join("had-spill-test"), Some(plan)).unwrap(),
        );
        p.set_spill(Some(Arc::clone(&store)));
        p.insert(1, layered(4));
        p.insert(2, layered(4));
        let evicted = p.insert(3, layered(4));
        assert_eq!(evicted, vec![1], "refused writes fall back to LRU eviction");
        assert!(p.bytes() <= p.budget());
        assert_eq!(p.stats().spill_pages_out, 0);
        assert!(store.stats().write_failures > 0);
        assert_eq!(store.live_records(), 0);
    }

    #[test]
    fn dropping_spilled_sessions_releases_their_records() {
        let one = PooledKv::bytes(&layered(4));
        let mut p: PagePool<LayeredKv> = PagePool::new(KvCacheConfig {
            page_tokens: 4,
            byte_budget: one,
            ..Default::default()
        });
        let store = spill_store();
        p.set_spill(Some(Arc::clone(&store)));
        p.insert(1, layered(4));
        p.insert(2, layered(4)); // spills session 1's only stripe
        assert_eq!(store.live_records(), 1);
        assert!(p.remove(1), "session 1 still resident (as a shell)");
        assert_eq!(store.live_records(), 0, "remove releases the spill record");
        // truncate-to-zero goes through remove and releases too
        p.insert(3, layered(4)); // spills session 2
        assert_eq!(store.live_records(), 1);
        p.truncate_session(2, 0);
        assert_eq!(store.live_records(), 0);
        // replacing a spilled entry wholesale releases the old records
        p.insert(4, layered(4)); // spills session 3
        assert_eq!(store.live_records(), 1);
        p.insert(3, layered(4)); // replaces session 3, spills someone
        assert!(store.live_records() <= 2);
        let removed: Vec<u64> = vec![3, 4];
        for id in removed {
            p.remove(id);
        }
        assert_eq!(store.live_records(), 0);
    }

    #[test]
    fn hydrate_counters_flow_through_note_hydrate() {
        let mut p: PagePool<LayeredKv> =
            PagePool::new(KvCacheConfig { page_tokens: 4, byte_budget: 1 << 20, ..Default::default() });
        p.note_hydrate(8, 0);
        p.note_hydrate(0, 1);
        let s = p.stats();
        assert_eq!(s.spill_pages_in, 8);
        assert_eq!(s.hydrate_hits, 1, "only checkouts that restored pages count");
        assert_eq!(s.store_checksum_failures, 1);
    }

    fn sharing_pool(budget: usize) -> PagePool<LayeredKv> {
        let mut p: PagePool<LayeredKv> = PagePool::new(KvCacheConfig {
            page_tokens: 4,
            byte_budget: budget,
            ..Default::default()
        });
        p.set_prefix_sharing(true);
        p
    }

    #[test]
    fn prefix_publish_adopt_dedupe_and_drain_to_zero() {
        let mut p = sharing_pool(1 << 20);
        let mut leader = layered(8); // 2 full stripes
        p.publish_prefix(&mut leader);
        let registry = p.shared_index().unwrap().bytes();
        assert!(registry > 0);
        assert_eq!(p.stats().shared_pages, 2 * 4, "2 stripes x 4 chains published");
        assert_eq!(PooledKv::bytes(&leader), 0, "published pages leave private accounting");
        p.insert(1, leader);
        assert_eq!(p.bytes(), registry, "shared bytes counted exactly once");

        // A follower adopts both stripes without running prefill.
        let toks: Vec<i32> = (0..8).collect();
        let geom = KvGeom { n_layers: 2, n_heads: 2, d_head: 16 };
        let mut follower = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert_eq!(p.seed_prefix(&mut follower, &toks, 8), 8);
        assert_eq!(follower.len(), 8);
        let s = p.stats();
        assert_eq!((s.prefix_hits, s.prefix_tokens_reused), (1, 8));
        p.insert(2, follower);
        assert_eq!(p.bytes(), registry, "two referencing sessions, bytes once");

        // The cap stops adoption at whole stripes below it.
        let mut capped = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert_eq!(p.seed_prefix(&mut capped, &toks, 7), 4, "only stripe 0 fits under 7");

        // An identical private cache republished dedupes onto the copy.
        let mut dup = layered(8);
        p.publish_prefix(&mut dup);
        assert_eq!(PooledKv::bytes(&dup), 0);
        assert_eq!(p.shared_index().unwrap().bytes(), registry, "dedup adds no bytes");
        p.insert(3, dup);

        // Dropping every referencing session drains pool AND registry.
        p.truncate_session(2, 0);
        p.remove(1);
        p.remove(3);
        p.discard(capped); // never checked in: discard releases its references
        assert_eq!(p.shared_index().unwrap().bytes(), 0, "registry drains");
        assert_eq!(p.bytes(), 0, "pool + registry drain to zero");
    }

    #[test]
    fn shared_entry_survives_spill_roundtrip_with_refcount() {
        let mut p = sharing_pool(1 << 20);
        let store = spill_store();
        p.set_spill(Some(Arc::clone(&store)));
        let mut leader = layered(4); // one stripe
        p.publish_prefix(&mut leader);
        let registry = p.shared_index().unwrap().bytes();
        p.insert(1, leader);

        // Last reference drops: the entry spills ONCE instead of dying.
        p.remove(1);
        assert_eq!(p.shared_index().unwrap().bytes(), 0, "resident bytes drained");
        assert_eq!(p.shared_index().unwrap().entries(), 1, "entry stays indexed");
        assert_eq!(store.live_records(), 1);
        let s = p.stats();
        assert_eq!(s.spill_pages_out, 4, "registry spill counted like any spill");
        assert_eq!(s.spill_bytes, registry as u64);

        // The next identical prompt hydrates it ONCE, refcount resuming.
        let toks: Vec<i32> = (0..4).collect();
        let geom = KvGeom { n_layers: 2, n_heads: 2, d_head: 16 };
        let mut follower = LayeredKv::new(geom, 4, ValueDtype::F32);
        assert_eq!(p.seed_prefix(&mut follower, &toks, 4), 4);
        assert_eq!(store.live_records(), 0, "hydrate releases the record");
        assert_eq!(p.shared_index().unwrap().bytes(), registry);
        let s = p.stats();
        assert_eq!(s.spill_pages_in, 4);
        assert!(s.hydrate_hits >= 1);
        p.insert(2, follower);
        p.remove(2); // back to zero refs: spills again, still one entry
        assert_eq!(p.shared_index().unwrap().entries(), 1);
        assert_eq!(store.live_records(), 1);
    }

    #[test]
    fn cow_divergence_counts_and_reaccounts_private_bytes() {
        let mut p = sharing_pool(1 << 20);
        let mut kv = layered(8);
        p.publish_prefix(&mut kv);
        p.insert(1, kv);
        let registry = p.shared_index().unwrap().bytes();
        assert_eq!(p.bytes(), registry);

        // Truncate into stripe 0: COW materializes its 4 chain-pages
        // privately and releases both stripes' registry references.
        p.truncate_session(1, 2);
        assert_eq!(p.stats().cow_copies, 4);
        assert_eq!(p.shared_index().unwrap().bytes(), 0, "no other referents: entries drain");
        assert!(p.bytes() > 0, "the COW copy is private residency");
        assert_eq!(p.cached_tokens(1), 2);
        p.remove(1);
        assert_eq!(p.bytes(), 0);
    }

    #[test]
    fn claims_are_inert_with_sharing_off() {
        let mut p: PagePool<LayeredKv> =
            PagePool::new(KvCacheConfig { page_tokens: 4, byte_budget: 1 << 20, ..Default::default() });
        assert_eq!(p.try_claim(1, 7), None);
        assert!(!p.claim_held_by_other(1, 8), "no registry, nobody ever waits");
        p.release_claim(1, 7);
        let geom = crate::kvcache::shared::StripeGeom {
            chains: 4,
            page_tokens: 4,
            d_head: 16,
            dtype: ValueDtype::F32,
        };
        assert!(p.prefix_covered(&geom, &[1, 2, 3, 4], 4), "coverage trivially true");
        let mut kv = layered(8);
        p.publish_prefix(&mut kv);
        assert!(PooledKv::bytes(&kv) > 0, "publish is a no-op without the registry");
        let mut fresh = LayeredKv::new(KvGeom { n_layers: 2, n_heads: 2, d_head: 16 }, 4, ValueDtype::F32);
        assert_eq!(p.seed_prefix(&mut fresh, &[0, 1, 2, 3], 4), 0);
    }

    #[test]
    fn layered_insert_reports_evictions() {
        let one = PooledKv::bytes(&layered(4)); // exactly one page per chain
        let mut p: PagePool<LayeredKv> = PagePool::new(KvCacheConfig {
            page_tokens: 4,
            byte_budget: 2 * one,
            ..Default::default()
        });
        p.insert(1, layered(4));
        p.insert(2, layered(4));
        assert!(p.bytes() <= p.budget());
        let evicted = p.insert(3, layered(4));
        assert_eq!(evicted, vec![1], "LRU session evicted and reported");
        assert_eq!(p.stats().evictions, 1);
        p.record_lookup(true);
        p.record_lookup(false);
        assert_eq!((p.stats().hits, p.stats().misses), (1, 1));
    }
}
