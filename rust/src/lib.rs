//! # HAD — Hamming Attention Distillation
//!
//! Production-shaped reproduction of *"Hamming Attention Distillation:
//! Binarizing Keys and Queries for Efficient Long-Context Transformers"*
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (build time): Pallas kernels — fused binarized top-N attention
//!   (`python/compile/kernels/`).
//! * **L2** (build time): JAX transformer + 4-stage distillation graphs,
//!   AOT-lowered to HLO text artifacts (`python/compile/`).
//! * **L3** (this crate): the runtime — PJRT execution, the distillation
//!   pipeline driver, a long-context serving coordinator, a CPU-native
//!   serving backend (`serve`: real per-layer decode over the paged KV
//!   cache), synthetic data generators, a bit-packed CPU fast path, the
//!   custom-hardware cost simulator, and the paper's experiment
//!   harnesses.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `had` binary is self-contained.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod binary;
pub mod coordinator;
pub mod data;
pub mod distill;
pub mod exp;
pub mod generate;
pub mod hwsim;
pub mod kvcache;
pub mod model;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod util;
