//! `Slab` — the storage seam behind [`super::Mat`]: either an owned
//! `Vec<f32>` (the default everywhere) or a zero-copy view into an
//! `Arc<util::mmap::Mapping>` (the checkpoint-store load path). Deref
//! gives `&[f32]` either way; the first mutable access to a mapped slab
//! copies it to the heap (copy-on-write), so existing `Mat` call sites
//! compile and behave unchanged.

use std::ops::{Deref, DerefMut};
use std::sync::Arc;

use crate::util::mmap::Mapping;

#[derive(Clone)]
enum Repr {
    Owned(Vec<f32>),
    Mapped {
        map: Arc<Mapping>,
        /// Element (not byte) offset into the mapping.
        off: usize,
        len: usize,
    },
}

/// f32 storage that is either heap-owned or borrowed from a read-only
/// file mapping. Cheap to clone in mapped form (an `Arc` bump).
#[derive(Clone)]
pub struct Slab(Repr);

impl Slab {
    /// A zero-copy view of `len` f32s starting `byte_off` bytes into the
    /// mapping. Errors on out-of-range or misaligned views (section
    /// alignment in the store format guarantees 4-byte alignment; this
    /// guards against hand-built offsets).
    pub fn mapped(map: Arc<Mapping>, byte_off: usize, len: usize) -> Result<Slab, String> {
        let end = byte_off
            .checked_add(len.checked_mul(4).ok_or("slab length overflows")?)
            .ok_or("slab range overflows")?;
        if end > map.len() {
            return Err(format!("slab [{byte_off}, {end}) outside mapping of {} B", map.len()));
        }
        if (map.as_ptr() as usize + byte_off) % 4 != 0 {
            return Err(format!("slab byte offset {byte_off} not 4-byte aligned"));
        }
        Ok(Slab(Repr::Mapped { map, off: byte_off / 4, len }))
    }

    pub fn as_slice(&self) -> &[f32] {
        match &self.0 {
            Repr::Owned(v) => v,
            // Safety: range and alignment were validated in `mapped`; the
            // Arc keeps the image alive for the borrow's lifetime.
            Repr::Mapped { map, off, len } => unsafe {
                std::slice::from_raw_parts((map.as_ptr() as *const f32).add(*off), *len)
            },
        }
    }

    /// Mutable access; promotes a mapped view to an owned copy first.
    pub fn to_mut(&mut self) -> &mut Vec<f32> {
        if let Repr::Mapped { .. } = self.0 {
            self.0 = Repr::Owned(self.as_slice().to_vec());
        }
        match &mut self.0 {
            Repr::Owned(v) => v,
            Repr::Mapped { .. } => unreachable!(),
        }
    }

    /// The data as an owned vector (no copy when already owned).
    pub fn into_vec(self) -> Vec<f32> {
        match self.0 {
            Repr::Owned(v) => v,
            Repr::Mapped { .. } => self.as_slice().to_vec(),
        }
    }

    /// A sub-view of `len` elements starting at element `start`:
    /// zero-copy for mapped slabs, a copy for owned ones (only the store
    /// loader slices, and it always holds mapped slabs).
    pub fn slice(&self, start: usize, len: usize) -> Slab {
        match &self.0 {
            Repr::Owned(v) => Slab(Repr::Owned(v[start..start + len].to_vec())),
            Repr::Mapped { map, off, len: total } => {
                assert!(start + len <= *total, "slab slice out of range");
                Slab(Repr::Mapped { map: Arc::clone(map), off: off + start, len })
            }
        }
    }

    /// True when the bytes are still borrowed from a mapping (i.e. no
    /// copy-on-write has happened).
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, Repr::Mapped { .. })
    }

    pub fn len(&self) -> usize {
        match &self.0 {
            Repr::Owned(v) => v.len(),
            Repr::Mapped { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Deref for Slab {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl DerefMut for Slab {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.to_mut()
    }
}

impl From<Vec<f32>> for Slab {
    fn from(v: Vec<f32>) -> Slab {
        Slab(Repr::Owned(v))
    }
}

impl FromIterator<f32> for Slab {
    fn from_iter<I: IntoIterator<Item = f32>>(iter: I) -> Slab {
        Slab(Repr::Owned(iter.into_iter().collect()))
    }
}

impl<'a> IntoIterator for &'a Slab {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<'a> IntoIterator for &'a mut Slab {
    type Item = &'a mut f32;
    type IntoIter = std::slice::IterMut<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.to_mut().iter_mut()
    }
}

impl PartialEq for Slab {
    fn eq(&self, other: &Slab) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f32>> for Slab {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Slab> for Vec<f32> {
    fn eq(&self, other: &Slab) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for Slab {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_mapped() {
            write!(f, "Slab(mapped, len={})", self.len())
        } else {
            std::fmt::Debug::fmt(self.as_slice(), f)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn temp_mapping(floats: &[f32]) -> Arc<Mapping> {
        let p = std::env::temp_dir().join(format!(
            "had-slab-{}-{}.bin",
            std::process::id(),
            floats.len()
        ));
        let mut f = std::fs::File::create(&p).unwrap();
        for x in floats {
            f.write_all(&x.to_le_bytes()).unwrap();
        }
        drop(f);
        let m = Arc::new(Mapping::open(&p).unwrap());
        std::fs::remove_file(&p).ok();
        m
    }

    #[test]
    fn owned_roundtrip_and_eq() {
        let s: Slab = vec![1.0f32, 2.0, 3.0].into();
        assert_eq!(s.len(), 3);
        assert_eq!(s, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.clone().into_vec(), vec![1.0, 2.0, 3.0]);
        assert!(!s.is_mapped());
    }

    #[test]
    fn mapped_view_reads_and_cows_on_write() {
        let data = [0.5f32, -1.25, 3.75, 8.0];
        let map = temp_mapping(&data);
        let mut s = Slab::mapped(Arc::clone(&map), 4, 2).unwrap();
        assert!(s.is_mapped());
        assert_eq!(&s[..], &[-1.25, 3.75]);
        s[0] = 9.0; // copy-on-write
        assert!(!s.is_mapped());
        assert_eq!(&s[..], &[9.0, 3.75]);
        // The mapping itself is untouched.
        let again = Slab::mapped(map, 4, 2).unwrap();
        assert_eq!(&again[..], &[-1.25, 3.75]);
    }

    #[test]
    fn mapped_slice_is_zero_copy() {
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let map = temp_mapping(&data);
        let s = Slab::mapped(map, 0, 6).unwrap();
        let sub = s.slice(2, 3);
        assert!(sub.is_mapped());
        assert_eq!(&sub[..], &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn mapped_rejects_bad_ranges() {
        let map = temp_mapping(&[1.0, 2.0]);
        assert!(Slab::mapped(Arc::clone(&map), 0, 3).is_err(), "past end");
        assert!(Slab::mapped(map, 2, 1).is_err(), "misaligned");
    }

    #[test]
    fn iteration_both_ways() {
        let mut s: Slab = vec![1.0f32, 2.0].into();
        let sum: f32 = (&s).into_iter().sum();
        assert_eq!(sum, 3.0);
        for x in &mut s {
            *x *= 2.0;
        }
        assert_eq!(s, vec![2.0, 4.0]);
    }
}
