//! Dense f32 mini-tensor library: oracle-grade reference ops.
//!
//! Used by the Rust-side correctness oracles (binary::attention is checked
//! against these), by metrics, and by the hwsim workload models. These are
//! NOT the serving hot path — that's `binary/` (bit-packed) and the PJRT
//! executables in `runtime/`.

pub mod ops;
pub mod slab;

pub use slab::Slab;

/// Row-major 2-D matrix of f32. Storage is a [`Slab`]: heap-owned by
/// every constructor here, or a zero-copy view into a mapped checkpoint
/// when built via [`Mat::from_slab`] (the `store` load path).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Slab,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols].into() }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data: data.into() }
    }

    /// Wrap existing storage (owned or mapped) without copying.
    pub fn from_slab(rows: usize, cols: usize, data: Slab) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/slab mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data: data.into() }
    }

    pub fn random(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng, std: f32) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols, std).into() }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Dense matmul: self (m,k) @ other (k,n) -> (m,n). ikj loop order.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// self (m,k) @ other^T where other is (n,k) -> (m,n). The attention
    /// score layout (Q @ K^T).
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &other.data[j * k..(j + 1) * k];
                out.data[i * n + j] = dot(arow, brow);
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.at(c, r))
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than naive on the
    // scalar CPU path and keeps float association deterministic.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..a.len() {
        tail += a[j] * b[j];
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Mat::from_fn(2, 2, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_nt_equals_matmul_transpose() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Mat::random(5, 7, &mut rng, 1.0);
        let b = Mat::random(6, 7, &mut rng, 1.0);
        let via_t = a.matmul(&b.transpose());
        let nt = a.matmul_nt(&b);
        assert!(via_t.max_abs_diff(&nt) < 1e-5);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = crate::util::rng::Rng::new(2);
        let a = Mat::random(3, 4, &mut rng, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut rng = crate::util::rng::Rng::new(3);
        for n in [0, 1, 3, 4, 5, 17, 64] {
            let a = rng.normal_vec(n, 1.0);
            let b = rng.normal_vec(n, 1.0);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4);
        }
    }
}
