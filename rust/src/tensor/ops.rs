//! Reference numerics: softmax, layernorm, metrics helpers.
//!
//! `softmax_topn_rows` is the Rust-side oracle for the paper's Eqs. 6-8
//! (used to cross-check `binary::attention` and, in integration tests, the
//! PJRT artifacts).

use super::Mat;

/// Numerically-stable softmax over a slice, in place.
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// Row-wise softmax of a matrix.
pub fn softmax_rows(m: &Mat) -> Mat {
    let mut out = m.clone();
    for r in 0..out.rows {
        softmax_inplace(out.row_mut(r));
    }
    out
}

/// Paper Eqs. 6-7 oracle: keep the top-N entries per row (ties broken by
/// lower column index, the lax.top_k convention), scale by `scale`,
/// softmax over the kept set; other entries exactly 0.
pub fn softmax_topn_rows(m: &Mat, n_top: usize, scale: f32) -> Mat {
    let mut out = Mat::zeros(m.rows, m.cols);
    let n_top = n_top.clamp(1, m.cols);
    let mut idx: Vec<usize> = Vec::with_capacity(m.cols);
    for r in 0..m.rows {
        let row = m.row(r);
        idx.clear();
        idx.extend(0..m.cols);
        // stable sort by descending value; ties keep ascending index
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap().then(a.cmp(&b)));
        let kept = &idx[..n_top];
        let max = kept.iter().map(|&j| row[j] * scale).fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for &j in kept {
            let e = (row[j] * scale - max).exp();
            *out.at_mut(r, j) = e;
            sum += e;
        }
        for &j in kept {
            *out.at_mut(r, j) /= sum;
        }
    }
    out
}

/// Layer norm over the last axis of each row.
pub fn layernorm_rows(m: &Mat, gamma: &[f32], beta: &[f32], eps: f32) -> Mat {
    assert_eq!(gamma.len(), m.cols);
    assert_eq!(beta.len(), m.cols);
    let mut out = m.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        let mean = row.iter().sum::<f32>() / row.len() as f32;
        let var = row.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (i, x) in row.iter_mut().enumerate() {
            *x = (*x - mean) * inv * gamma[i] + beta[i];
        }
    }
    out
}

/// GELU, tanh approximation — the `jax.nn.gelu` default the lowered
/// graphs use, reproduced here for the CPU serving backend's MLP:
/// `0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3)))`.
pub fn gelu_tanh(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

/// Standard deviation over all elements (population).
pub fn std_all(m: &Mat) -> f32 {
    let n = m.data.len() as f32;
    let mean = m.data.iter().sum::<f32>() / n;
    (m.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n).sqrt()
}

/// argmax of a slice (first max wins).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let mut xs = vec![1e30, 1e30 - 1.0];
        softmax_inplace(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn topn_keeps_exactly_n_without_ties() {
        let m = Mat::from_vec(1, 5, vec![0.1, 5.0, 3.0, -1.0, 4.0]);
        let p = softmax_topn_rows(&m, 3, 1.0);
        let nz: Vec<usize> = (0..5).filter(|&j| p.at(0, j) > 0.0).collect();
        assert_eq!(nz, vec![1, 2, 4]);
        assert!((p.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn topn_tie_break_lowest_index() {
        let m = Mat::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        let p = softmax_topn_rows(&m, 2, 1.0);
        assert!(p.at(0, 0) > 0.0 && p.at(0, 1) > 0.0);
        assert_eq!(p.at(0, 2), 0.0);
        assert_eq!(p.at(0, 3), 0.0);
    }

    #[test]
    fn topn_full_equals_softmax() {
        let m = Mat::from_vec(2, 3, vec![0.5, -0.5, 2.0, 1.0, 1.0, 1.0]);
        let a = softmax_topn_rows(&m, 3, 1.0);
        let b = softmax_rows(&m);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let m = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let out = layernorm_rows(&m, &g, &b, 1e-5);
        let mean: f32 = out.row(0).iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
    }

    #[test]
    fn gelu_tanh_known_points() {
        assert_eq!(gelu_tanh(0.0), 0.0);
        // gelu(x) -> x for large x, -> 0 for very negative x
        assert!((gelu_tanh(6.0) - 6.0).abs() < 1e-4);
        assert!(gelu_tanh(-6.0).abs() < 1e-4);
        // reference value at x=1 (tanh approximation): ~0.841192
        assert!((gelu_tanh(1.0) - 0.841192).abs() < 1e-4);
        // odd-ish asymmetry: gelu(x) + gelu(-x) == x
        for x in [0.3f32, 1.7, 2.5] {
            assert!((gelu_tanh(x) + gelu_tanh(-x) - x).abs() < 1e-5);
        }
    }

    #[test]
    fn std_all_known() {
        let m = Mat::from_vec(1, 2, vec![0.0, 2.0]);
        assert!((std_all(&m) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 0.0]), 1);
    }
}
