//! The `HADSTOR1` on-disk container: magic + fixed header + CRC-guarded
//! JSON manifest + alignment-padded, per-section-checksummed payload
//! sections.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! 0   "HADSTOR1"                      8 B   magic
//! 8   version                         4 B   (currently 1)
//! 12  manifest_len                    4 B
//! 16  manifest_crc                    4 B   CRC32 (IEEE) of the manifest
//! 20  reserved                        4 B   zero
//! 24  manifest JSON                   manifest_len B
//!     zero padding to `align`
//!     section payloads, each starting on an `align` boundary
//! ```
//!
//! The manifest records `kind` (what the file holds), `align`, free-form
//! `meta`, and a section table of `{name, off, len, crc}` where `off` is
//! relative to the aligned data base (`align_up(24 + manifest_len,
//! align)`), so section offsets are computable before the manifest is
//! serialized. Every read path returns a typed [`StoreError`] — a
//! truncated, bit-flipped, or future-versioned file must surface as a
//! clean error (metric + cold-start fallback), never a panic or silently
//! wrong weights.

use std::io::Write;
use std::path::Path;
use std::sync::{Arc, OnceLock};

use crate::tensor::Slab;
use crate::util::json::Json;
use crate::util::mmap::Mapping;

pub const MAGIC: &[u8; 8] = b"HADSTOR1";
pub const VERSION: u32 = 1;
const HEADER_LEN: usize = 24;

/// Typed failure modes of the container reader/writer.
#[derive(Debug)]
pub enum StoreError {
    Io(std::io::Error),
    /// The file does not start with `HADSTOR1`.
    BadMagic,
    /// A version this build does not understand.
    BadVersion(u32),
    /// The file ends before a region the header/manifest promised.
    Truncated(String),
    /// The manifest failed to parse or is missing required fields.
    BadManifest(String),
    /// A CRC32 mismatch in the named region ("manifest" or a section).
    ChecksumMismatch(String),
    /// A section the caller asked for is not in the table.
    MissingSection(String),
    /// Section bytes exist but have the wrong size/alignment for the
    /// requested typed view.
    ShapeMismatch(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "store I/O error: {e}"),
            StoreError::BadMagic => write!(f, "not a HADSTOR1 container"),
            StoreError::BadVersion(v) => write!(f, "unsupported store version {v}"),
            StoreError::Truncated(what) => write!(f, "store file truncated: {what}"),
            StoreError::BadManifest(why) => write!(f, "bad store manifest: {why}"),
            StoreError::ChecksumMismatch(what) => write!(f, "store checksum mismatch in {what}"),
            StoreError::MissingSection(name) => write!(f, "store section '{name}' missing"),
            StoreError::ShapeMismatch(why) => write!(f, "store section shape mismatch: {why}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> StoreError {
        StoreError::Io(e)
    }
}

/// CRC32 (IEEE 802.3 polynomial, the zlib/`cksum -o3` flavor).
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// FNV-1a 64-bit — the content hash keying the spill tier's
/// content-addressed index (cheap, deterministic, and collision-safe at
/// spill-file scale; every read is additionally CRC-verified).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_extend(0xcbf2_9ce4_8422_2325, bytes)
}

/// Fold more bytes into an FNV-1a 64-bit state — the incremental form of
/// [`fnv1a64`], used to hash growing token prefixes (the shared-page
/// prefix index) without re-walking the whole prefix per stripe.
pub fn fnv1a64_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn align_up(n: usize, align: usize) -> usize {
    n.div_ceil(align) * align
}

/// Buffered container writer: stage sections, then emit the whole file.
/// Sections are held in RAM until [`ContainerWriter::write_to`] — fine
/// for checkpoints, whose tensors are heap-resident at save time anyway.
pub struct ContainerWriter {
    kind: String,
    align: usize,
    meta: Json,
    sections: Vec<(String, Vec<u8>)>,
}

impl ContainerWriter {
    /// `align` is the section boundary (and the padding unit after the
    /// manifest): 4096 for checkpoints (page-aligned mmap views), smaller
    /// for tests.
    pub fn new(kind: &str, align: usize) -> ContainerWriter {
        assert!(align.is_power_of_two() && align >= 4, "align must be a power of two >= 4");
        ContainerWriter { kind: kind.to_string(), align, meta: Json::obj(vec![]), sections: Vec::new() }
    }

    /// Free-form metadata carried in the manifest (config name, sigmas…).
    pub fn set_meta(&mut self, meta: Json) {
        self.meta = meta;
    }

    pub fn add_section(&mut self, name: &str, bytes: Vec<u8>) {
        self.sections.push((name.to_string(), bytes));
    }

    pub fn write_to(&self, path: &Path) -> Result<(), StoreError> {
        // Section offsets relative to the data base are independent of
        // the manifest's serialized length, so one pass suffices.
        let mut table = Vec::new();
        let mut off = 0usize;
        for (name, bytes) in &self.sections {
            table.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("off", Json::num(off as f64)),
                ("len", Json::num(bytes.len() as f64)),
                ("crc", Json::num(f64::from(crc32(bytes)))),
            ]));
            off = align_up(off + bytes.len(), self.align);
        }
        let manifest = Json::obj(vec![
            ("kind", Json::str(self.kind.clone())),
            ("align", Json::num(self.align as f64)),
            ("meta", self.meta.clone()),
            ("sections", Json::arr(table)),
        ]);
        let mjson = format!("{manifest}");
        let mbytes = mjson.as_bytes();

        let mut out = Vec::with_capacity(HEADER_LEN + mbytes.len() + off);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(mbytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(mbytes).to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(mbytes);
        let data_base = align_up(out.len(), self.align);
        out.resize(data_base, 0);
        for (i, (_, bytes)) in self.sections.iter().enumerate() {
            let want = data_base + sect_off(&manifest, i);
            out.resize(want, 0);
            out.extend_from_slice(bytes);
        }

        // Write to a sibling temp file then rename, so a crash mid-write
        // never leaves a half-written container under the final name.
        let tmp = path.with_extension("tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&out)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

fn sect_off(manifest: &Json, i: usize) -> usize {
    manifest.get("sections").and_then(Json::as_arr).and_then(|s| s[i].get("off")).and_then(Json::as_usize).unwrap()
}

/// One entry of the parsed section table (absolute offsets).
#[derive(Debug, Clone)]
struct Section {
    name: String,
    off: usize,
    len: usize,
}

/// A verified, opened container over a read-only [`Mapping`]. All CRCs
/// (manifest and every section) are checked at open, so section accessors
/// can hand out raw views without re-validating.
pub struct Container {
    map: Arc<Mapping>,
    manifest: Json,
    sections: Vec<Section>,
}

impl Container {
    pub fn open(path: &Path) -> Result<Container, StoreError> {
        let map = Arc::new(Mapping::open(path)?);
        Self::from_mapping(map)
    }

    /// Parse + verify an already-mapped image (tests feed corrupted
    /// byte buffers through a temp file here).
    pub fn from_mapping(map: Arc<Mapping>) -> Result<Container, StoreError> {
        let b = map.bytes();
        if b.len() < HEADER_LEN {
            return Err(StoreError::Truncated(format!("{} B file, {HEADER_LEN} B header", b.len())));
        }
        if &b[..8] != MAGIC {
            return Err(StoreError::BadMagic);
        }
        let version = u32::from_le_bytes(b[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(StoreError::BadVersion(version));
        }
        let mlen = u32::from_le_bytes(b[12..16].try_into().unwrap()) as usize;
        let mcrc = u32::from_le_bytes(b[16..20].try_into().unwrap());
        let mend = HEADER_LEN
            .checked_add(mlen)
            .ok_or_else(|| StoreError::BadManifest("manifest length overflows".into()))?;
        if b.len() < mend {
            return Err(StoreError::Truncated(format!("manifest needs {mend} B, file has {}", b.len())));
        }
        let mbytes = &b[HEADER_LEN..mend];
        if crc32(mbytes) != mcrc {
            return Err(StoreError::ChecksumMismatch("manifest".into()));
        }
        let mjson = std::str::from_utf8(mbytes)
            .map_err(|_| StoreError::BadManifest("manifest is not UTF-8".into()))?;
        let manifest =
            Json::parse(mjson).map_err(|e| StoreError::BadManifest(format!("parse: {e:?}")))?;
        let align = manifest
            .get("align")
            .and_then(Json::as_usize)
            .filter(|a| a.is_power_of_two() && *a >= 4)
            .ok_or_else(|| StoreError::BadManifest("bad or missing align".into()))?;
        let data_base = align_up(mend, align);
        let table = manifest
            .get("sections")
            .and_then(Json::as_arr)
            .ok_or_else(|| StoreError::BadManifest("missing section table".into()))?;
        let mut sections = Vec::with_capacity(table.len());
        for (i, s) in table.iter().enumerate() {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| StoreError::BadManifest(format!("section {i}: missing name")))?
                .to_string();
            let rel = s
                .get("off")
                .and_then(Json::as_usize)
                .ok_or_else(|| StoreError::BadManifest(format!("section {name}: missing off")))?;
            let len = s
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| StoreError::BadManifest(format!("section {name}: missing len")))?;
            let crc = s
                .get("crc")
                .and_then(Json::as_f64)
                .ok_or_else(|| StoreError::BadManifest(format!("section {name}: missing crc")))?
                as u32;
            let off = data_base
                .checked_add(rel)
                .ok_or_else(|| StoreError::BadManifest(format!("section {name}: offset overflows")))?;
            let end = off
                .checked_add(len)
                .ok_or_else(|| StoreError::BadManifest(format!("section {name}: length overflows")))?;
            if end > b.len() {
                return Err(StoreError::Truncated(format!(
                    "section {name} needs {end} B, file has {}",
                    b.len()
                )));
            }
            if crc32(&b[off..end]) != crc {
                return Err(StoreError::ChecksumMismatch(format!("section {name}")));
            }
            sections.push(Section { name, off, len });
        }
        Ok(Container { map, manifest, sections })
    }

    /// The manifest's `kind` field.
    pub fn kind(&self) -> &str {
        self.manifest.get("kind").and_then(Json::as_str).unwrap_or("")
    }

    /// The free-form `meta` object.
    pub fn meta(&self) -> &Json {
        static NULL: Json = Json::Null;
        self.manifest.get("meta").unwrap_or(&NULL)
    }

    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.iter().map(|s| s.name.as_str())
    }

    /// Raw bytes of a named section (CRC already verified at open).
    pub fn section_bytes(&self, name: &str) -> Result<&[u8], StoreError> {
        let s = self
            .sections
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| StoreError::MissingSection(name.to_string()))?;
        Ok(&self.map.bytes()[s.off..s.off + s.len])
    }

    /// Zero-copy f32 view of a section: a [`Slab`] borrowing the mapping.
    pub fn section_f32(&self, name: &str) -> Result<Slab, StoreError> {
        let s = self
            .sections
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| StoreError::MissingSection(name.to_string()))?;
        if s.len % 4 != 0 {
            return Err(StoreError::ShapeMismatch(format!(
                "section {name}: {} B is not a whole number of f32s",
                s.len
            )));
        }
        Slab::mapped(Arc::clone(&self.map), s.off, s.len / 4).map_err(StoreError::ShapeMismatch)
    }

    /// Whether the backing bytes are a true mmap (vs the buffered-read
    /// fallback) — surfaced in logs/benches.
    pub fn is_mapped(&self) -> bool {
        self.map.is_mapped()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("had-format-{}-{name}.stor", std::process::id()))
    }

    fn sample(path: &Path, align: usize) {
        let mut w = ContainerWriter::new("test", align);
        w.set_meta(Json::obj(vec![("note", Json::str("hello"))]));
        w.add_section("alpha", (0..300u16).flat_map(|i| i.to_le_bytes()).collect());
        w.add_section("beta", vec![7u8; 33]);
        w.write_to(path).unwrap();
    }

    #[test]
    fn roundtrip_sections_and_meta() {
        let p = temp("roundtrip");
        sample(&p, 64);
        let c = Container::open(&p).unwrap();
        assert_eq!(c.kind(), "test");
        assert_eq!(c.meta().get("note").and_then(Json::as_str), Some("hello"));
        let alpha = c.section_bytes("alpha").unwrap();
        assert_eq!(alpha.len(), 600);
        assert_eq!(&alpha[..4], &[0, 0, 1, 0]);
        assert_eq!(c.section_bytes("beta").unwrap(), &[7u8; 33][..]);
        assert!(matches!(c.section_bytes("gamma"), Err(StoreError::MissingSection(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn sections_start_on_alignment_boundaries() {
        let p = temp("aligned");
        sample(&p, 4096);
        let c = Container::open(&p).unwrap();
        for s in &c.sections {
            assert_eq!(s.off % 4096, 0, "section {} at {}", s.name, s.off);
        }
        // f32 views are therefore always constructible.
        let slab = c.section_f32("beta");
        assert!(matches!(slab, Err(StoreError::ShapeMismatch(_))), "33 B is not f32s");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn crc_known_vector() {
        // The classic IEEE CRC32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn fnv_distinguishes_content() {
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn fnv_extend_is_the_incremental_form() {
        let whole = fnv1a64(b"hamming attention");
        let split = fnv1a64_extend(fnv1a64(b"hamming "), b"attention");
        assert_eq!(whole, split);
        assert_ne!(fnv1a64_extend(whole, b"x"), whole);
    }

    fn mutate(path: &Path, f: impl FnOnce(&mut Vec<u8>)) -> Result<Container, StoreError> {
        let mut bytes = std::fs::read(path).unwrap();
        f(&mut bytes);
        let p2 = path.with_extension("mut");
        std::fs::write(&p2, &bytes).unwrap();
        let r = Container::open(&p2);
        std::fs::remove_file(&p2).ok();
        r
    }

    #[test]
    fn wrong_magic_is_typed() {
        let p = temp("magic");
        sample(&p, 64);
        let r = mutate(&p, |b| b[0] = b'X');
        assert!(matches!(r, Err(StoreError::BadMagic)));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn future_version_is_typed() {
        let p = temp("version");
        sample(&p, 64);
        let r = mutate(&p, |b| b[8..12].copy_from_slice(&9u32.to_le_bytes()));
        assert!(matches!(r, Err(StoreError::BadVersion(9))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncation_anywhere_is_typed() {
        let p = temp("trunc");
        sample(&p, 64);
        let full = std::fs::read(&p).unwrap().len();
        for keep in [0, 7, HEADER_LEN - 1, HEADER_LEN + 3, full / 2, full - 1] {
            let r = mutate(&p, |b| b.truncate(keep));
            let typed =
                matches!(&r, Err(StoreError::Truncated(_) | StoreError::ChecksumMismatch(_)));
            assert!(typed, "keep={keep} gave {:?}", r.err());
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bit_flip_in_section_is_a_checksum_mismatch() {
        let p = temp("flip");
        sample(&p, 64);
        let full = std::fs::read(&p).unwrap().len();
        let r = mutate(&p, |b| b[full - 5] ^= 0x10);
        assert!(matches!(r, Err(StoreError::ChecksumMismatch(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bit_flip_in_manifest_is_a_checksum_mismatch() {
        let p = temp("mflip");
        sample(&p, 64);
        let r = mutate(&p, |b| b[HEADER_LEN + 2] ^= 0x01);
        assert!(matches!(r, Err(StoreError::ChecksumMismatch(_))));
        std::fs::remove_file(&p).ok();
    }
}
