//! Persistent store: the `HADSTOR1` container format and its two
//! producers/consumers.
//!
//! * [`format`] — the versioned on-disk container: magic + header +
//!   CRC-guarded JSON manifest + alignment-padded, per-section-
//!   checksummed payloads. Every read failure is a typed
//!   [`StoreError`]; corruption can cost a cold start, never a panic or
//!   silently wrong bytes.
//! * [`checkpoint`] — serializes a `model::Checkpoint` one page-aligned
//!   section per tensor, the substrate for `ServeModel::from_store`'s
//!   zero-copy mmap weight load (`util::mmap` + `tensor::Slab`).
//! * [`spill`] — the disk spill tier for cold KV: sealed packed-K/V
//!   stripes evicted from the `PagePool` are written to a
//!   content-addressed spill file and hydrated back bit-identically on
//!   the next checkout, instead of paying re-prefill.

pub mod checkpoint;
pub mod format;
pub mod spill;

pub use checkpoint::{meta_sigmas, open_checkpoint, write_checkpoint, CHECKPOINT_KIND};
pub use format::{crc32, fnv1a64, Container, ContainerWriter, StoreError};
pub use spill::{SpillStats, SpillStore};
