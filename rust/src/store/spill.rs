//! The disk spill tier for cold KV: sealed packed-K/V stripes evicted
//! from the [`crate::kvcache::PagePool`] land here instead of being
//! destroyed, so a later checkout hydrates them back bit-identically
//! instead of paying re-prefill.
//!
//! One append-only file per store (`<dir>/spill-<pid>-<n>.kv`), with a
//! **content-addressed** in-memory index: records are keyed by the
//! FNV-1a 64 hash of their payload, so identical stripes (e.g. shared
//! prompt prefixes across sessions) are written once and refcounted.
//! Every read re-verifies both the CRC32 and the content hash — a bad
//! record surfaces as a typed [`StoreError`] and the caller falls back
//! to re-prefill; corrupt KV is never served. Space is reclaimed by
//! deleting the whole file when the store drops (spill files are
//! per-process scratch, not a database).
//!
//! Fault injection: `spill_write`/`spill_read` (`util::fault`) make
//! `put`/`get` fail on demand so chaos runs can prove the pool degrades
//! to plain eviction and streams re-prefill rather than wedge.

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::store::format::{crc32, fnv1a64, StoreError};
use crate::util::fault::{self, Fault, FaultPlan, SITE_SPILL_READ, SITE_SPILL_WRITE};

const SPILL_MAGIC: &[u8; 8] = b"HADSPIL1";
/// Per-record framing: hash (8) + len (4) + crc (4).
const REC_HEADER: usize = 16;

#[derive(Debug, Clone, Copy)]
struct Slot {
    off: u64,
    len: u32,
    crc: u32,
    refs: u32,
}

struct Inner {
    file: std::fs::File,
    end: u64,
    index: HashMap<u64, Slot>,
    live_bytes: usize,
}

/// Cumulative spill-store counters (monotone).
#[derive(Debug, Clone, Copy, Default)]
pub struct SpillStats {
    /// Records written (dedup hits do not re-write).
    pub writes: u64,
    /// Successful hydrating reads.
    pub reads: u64,
    /// Failed reads: injected faults, I/O errors, checksum mismatches.
    pub read_failures: u64,
    /// Failed writes (injected faults or I/O errors).
    pub write_failures: u64,
}

/// A shared handle to one spill file. All methods take `&self`; the
/// record index and file cursor live behind one mutex (spill I/O is rare
/// next to decode work, and the pool already serializes eviction).
pub struct SpillStore {
    inner: Mutex<Inner>,
    path: PathBuf,
    faults: Option<Arc<FaultPlan>>,
    writes: AtomicU64,
    reads: AtomicU64,
    read_failures: AtomicU64,
    write_failures: AtomicU64,
}

impl SpillStore {
    /// Create a fresh spill file under `dir` (created if missing). The
    /// name embeds pid + a process-local counter so concurrent servers
    /// (and tests) never collide.
    pub fn create(dir: &Path, faults: Option<Arc<FaultPlan>>) -> std::io::Result<SpillStore> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!(
            "spill-{}-{}.kv",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let mut file = std::fs::File::options()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)?;
        file.write_all(SPILL_MAGIC)?;
        file.write_all(&1u32.to_le_bytes())?;
        file.write_all(&0u32.to_le_bytes())?;
        Ok(SpillStore {
            inner: Mutex::new(Inner {
                file,
                end: 16,
                index: HashMap::new(),
                live_bytes: 0,
            }),
            path,
            faults,
            writes: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            read_failures: AtomicU64::new(0),
            write_failures: AtomicU64::new(0),
        })
    }

    /// Resolve a spill store from `HAD_STORE=dir`. Returns `None` when
    /// the knob is unset; logs and returns `None` (serving degrades to
    /// destroy-on-evict) when the directory is unusable.
    pub fn from_env(faults: Option<Arc<FaultPlan>>) -> Option<Arc<SpillStore>> {
        let dir = std::env::var("HAD_STORE").ok().filter(|v| !v.trim().is_empty())?;
        match SpillStore::create(Path::new(&dir), faults) {
            Ok(s) => Some(Arc::new(s)),
            Err(e) => {
                crate::log_warn!("HAD_STORE={dir}: {e}; KV spill disabled");
                None
            }
        }
    }

    /// Write (or dedupe into) the store; returns the content hash that
    /// later [`SpillStore::get`] / [`SpillStore::release`] calls use.
    pub fn put(&self, payload: &[u8]) -> Result<u64, StoreError> {
        let mut sp = crate::obs::span("spill");
        match fault::fire(&self.faults, SITE_SPILL_WRITE) {
            Some(Fault::Deny) => {
                self.write_failures.fetch_add(1, Ordering::Relaxed);
                return Err(StoreError::Io(std::io::Error::other("injected spill_write fault")));
            }
            Some(Fault::Delay(d)) => std::thread::sleep(d),
            Some(Fault::Panic) => panic!("injected spill_write panic"),
            None => {}
        }
        let hash = fnv1a64(payload);
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(slot) = inner.index.get_mut(&hash) {
            slot.refs += 1;
            sp.set_payload(0);
            return Ok(hash);
        }
        let off = inner.end;
        let res: std::io::Result<()> = (|| {
            inner.file.seek(SeekFrom::Start(off))?;
            inner.file.write_all(&hash.to_le_bytes())?;
            inner.file.write_all(&(payload.len() as u32).to_le_bytes())?;
            inner.file.write_all(&crc32(payload).to_le_bytes())?;
            inner.file.write_all(payload)?;
            Ok(())
        })();
        if let Err(e) = res {
            self.write_failures.fetch_add(1, Ordering::Relaxed);
            return Err(StoreError::Io(e));
        }
        inner.end = off + (REC_HEADER + payload.len()) as u64;
        inner.index.insert(
            hash,
            Slot { off, len: payload.len() as u32, crc: crc32(payload), refs: 1 },
        );
        inner.live_bytes += payload.len();
        self.writes.fetch_add(1, Ordering::Relaxed);
        sp.set_payload(payload.len() as u64);
        Ok(hash)
    }

    /// Read a record back, verifying CRC32 and the content hash. Any
    /// failure is typed; the caller must treat the record as gone (the
    /// stream re-prefills) — corrupt bytes are never returned.
    pub fn get(&self, hash: u64) -> Result<Vec<u8>, StoreError> {
        let mut sp = crate::obs::span("hydrate");
        if let Some(f) = fault::fire(&self.faults, SITE_SPILL_READ) {
            match f {
                Fault::Delay(d) => std::thread::sleep(d),
                _ => {
                    self.read_failures.fetch_add(1, Ordering::Relaxed);
                    return Err(StoreError::Io(std::io::Error::other(
                        "injected spill_read fault",
                    )));
                }
            }
        }
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let slot = *inner.index.get(&hash).ok_or_else(|| {
            StoreError::MissingSection(format!("spill record {hash:016x}"))
        })?;
        let mut buf = vec![0u8; slot.len as usize];
        let res: std::io::Result<()> = (|| {
            inner.file.seek(SeekFrom::Start(slot.off + REC_HEADER as u64))?;
            inner.file.read_exact(&mut buf)?;
            Ok(())
        })();
        drop(inner);
        if let Err(e) = res {
            self.read_failures.fetch_add(1, Ordering::Relaxed);
            return Err(StoreError::Io(e));
        }
        if crc32(&buf) != slot.crc || fnv1a64(&buf) != hash {
            self.read_failures.fetch_add(1, Ordering::Relaxed);
            return Err(StoreError::ChecksumMismatch(format!("spill record {hash:016x}")));
        }
        self.reads.fetch_add(1, Ordering::Relaxed);
        sp.set_payload(buf.len() as u64);
        Ok(buf)
    }

    /// Drop one reference to a record; the last release forgets it (the
    /// bytes stay in the append-only file until the store drops).
    pub fn release(&self, hash: u64) {
        let mut inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(slot) = inner.index.get_mut(&hash) {
            slot.refs -= 1;
            if slot.refs == 0 {
                let len = slot.len as usize;
                inner.index.remove(&hash);
                inner.live_bytes -= len;
            }
        }
    }

    /// Bytes of payload currently referenced by at least one session.
    pub fn live_bytes(&self) -> usize {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner).live_bytes
    }

    /// Distinct records currently referenced.
    pub fn live_records(&self) -> usize {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner).index.len()
    }

    pub fn stats(&self) -> SpillStats {
        SpillStats {
            writes: self.writes.load(Ordering::Relaxed),
            reads: self.reads.load(Ordering::Relaxed),
            read_failures: self.read_failures.load(Ordering::Relaxed),
            write_failures: self.write_failures.load(Ordering::Relaxed),
        }
    }

    /// Where the spill file lives (benches report it).
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        std::fs::remove_file(&self.path).ok();
    }
}

impl std::fmt::Debug for SpillStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpillStore")
            .field("path", &self.path)
            .field("live_records", &self.live_records())
            .field("live_bytes", &self.live_bytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> SpillStore {
        SpillStore::create(&std::env::temp_dir().join("had-spill-test"), None).unwrap()
    }

    #[test]
    fn put_get_roundtrip_bit_identical() {
        let s = store();
        let payload: Vec<u8> = (0..5000u32).map(|i| (i * 31 + 7) as u8).collect();
        let h = s.put(&payload).unwrap();
        assert_eq!(s.get(h).unwrap(), payload);
        assert_eq!(s.live_bytes(), payload.len());
        assert_eq!(s.stats().writes, 1);
        assert_eq!(s.stats().reads, 1);
    }

    #[test]
    fn content_addressing_dedupes_and_refcounts() {
        let s = store();
        let payload = vec![42u8; 1024];
        let h1 = s.put(&payload).unwrap();
        let h2 = s.put(&payload).unwrap();
        assert_eq!(h1, h2);
        assert_eq!(s.stats().writes, 1, "identical payload written once");
        assert_eq!(s.live_bytes(), 1024);
        s.release(h1);
        assert_eq!(s.live_records(), 1, "still referenced by the second put");
        assert!(s.get(h2).is_ok());
        s.release(h2);
        assert_eq!(s.live_records(), 0);
        assert_eq!(s.live_bytes(), 0);
        assert!(matches!(s.get(h2), Err(StoreError::MissingSection(_))));
    }

    #[test]
    fn spill_file_is_deleted_on_drop() {
        let s = store();
        let path = s.path().to_path_buf();
        s.put(&[1, 2, 3]).unwrap();
        assert!(path.exists());
        drop(s);
        assert!(!path.exists());
    }

    #[test]
    fn injected_write_fault_is_a_typed_error() {
        let plan = Arc::new(FaultPlan::parse("spill_write").unwrap());
        let s = SpillStore::create(
            &std::env::temp_dir().join("had-spill-test"),
            Some(Arc::clone(&plan)),
        )
        .unwrap();
        assert!(matches!(s.put(&[1, 2, 3]), Err(StoreError::Io(_))));
        assert_eq!(s.stats().write_failures, 1);
        assert!(plan.injected() > 0);
    }

    #[test]
    fn injected_read_fault_never_returns_bytes() {
        let plan = Arc::new(FaultPlan::parse("spill_read").unwrap());
        let s = SpillStore::create(
            &std::env::temp_dir().join("had-spill-test"),
            Some(plan),
        )
        .unwrap();
        // Writes are clean (plan only covers reads); every get fails typed.
        let h = s.put(&[9u8; 64]).unwrap();
        assert!(s.get(h).is_err());
        assert_eq!(s.stats().read_failures, 1);
    }

    #[test]
    fn corrupted_record_fails_checksum() {
        let s = store();
        let payload = vec![7u8; 256];
        let h = s.put(&payload).unwrap();
        // Flip a byte of the record's payload on disk behind the index.
        {
            let inner = s.inner.lock().unwrap();
            let off = inner.index[&h].off + REC_HEADER as u64 + 13;
            let mut f = std::fs::File::options().write(true).open(&s.path).unwrap();
            f.seek(SeekFrom::Start(off)).unwrap();
            f.write_all(&[0xFF]).unwrap();
        }
        assert!(matches!(s.get(h), Err(StoreError::ChecksumMismatch(_))));
        assert_eq!(s.stats().read_failures, 1);
    }
}
