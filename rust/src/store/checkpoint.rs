//! Checkpoint producer/consumer for the `HADSTOR1` container: one
//! page-aligned section per tensor, so [`crate::serve::ServeModel`] can
//! borrow weight slabs straight out of a read-only mmap
//! ([`ServeModel::from_store`]) instead of copying them to the heap —
//! bit-identical logits, near-zero load cost, and one shared physical
//! image across processes.
//!
//! Coexists with the legacy `HADCKPT1` stream format in
//! `model::checkpoint` (the training pipeline's save/resume path); this
//! is the serving-side store.

use std::path::Path;

use crate::model::Checkpoint;
use crate::runtime::ConfigEntry;
use crate::store::format::{Container, ContainerWriter, StoreError};
use crate::util::json::Json;

/// Section (and manifest) alignment: one 4 KiB page, so every mapped
/// tensor view is page-aligned (and trivially f32-aligned).
pub const CHECKPOINT_ALIGN: usize = 4096;
pub const CHECKPOINT_KIND: &str = "checkpoint";

/// Serialize a checkpoint into the container format. Tensors are written
/// in manifest (`cfg.params`) order, one section per tensor, each padded
/// to [`CHECKPOINT_ALIGN`]; sigmas and config identity travel in the
/// manifest's `meta`.
pub fn write_checkpoint(
    path: &Path,
    cfg: &ConfigEntry,
    ckpt: &Checkpoint,
) -> Result<(), StoreError> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut w = ContainerWriter::new(CHECKPOINT_KIND, CHECKPOINT_ALIGN);
    let tensors = Json::arr(cfg.params.iter().map(|p| {
        Json::obj(vec![
            ("name", Json::str(p.name.clone())),
            ("shape", Json::arr(p.shape.iter().map(|&d| Json::num(d as f64)))),
        ])
    }));
    w.set_meta(Json::obj(vec![
        ("config", Json::str(ckpt.config.clone())),
        ("step", Json::num(f64::from(ckpt.step))),
        ("sigma_q", Json::arr(ckpt.sigma_q.iter().map(|&x| Json::num(f64::from(x))))),
        ("sigma_k", Json::arr(ckpt.sigma_k.iter().map(|&x| Json::num(f64::from(x))))),
        ("tensors", tensors),
    ]));
    for (spec, t) in cfg.params.iter().zip(&ckpt.params.tensors) {
        let data = t
            .as_f32()
            .map_err(|e| StoreError::ShapeMismatch(format!("tensor {}: {e}", spec.name)))?;
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        w.add_section(&spec.name, bytes);
    }
    w.write_to(path)
}

/// Open a container and check it holds a checkpoint for `cfg`. All CRCs
/// are verified here; the returned container hands out zero-copy views.
pub fn open_checkpoint(path: &Path, cfg: &ConfigEntry) -> Result<Container, StoreError> {
    let c = Container::open(path)?;
    if c.kind() != CHECKPOINT_KIND {
        return Err(StoreError::BadManifest(format!(
            "container holds '{}', expected '{CHECKPOINT_KIND}'",
            c.kind()
        )));
    }
    let config = c.meta().get("config").and_then(Json::as_str).unwrap_or("");
    if config != cfg.name {
        return Err(StoreError::BadManifest(format!(
            "checkpoint is for config '{config}', expected '{}'",
            cfg.name
        )));
    }
    Ok(c)
}

/// Read a per-layer sigma vector out of a checkpoint container's meta.
pub fn meta_sigmas(c: &Container, key: &str) -> Result<Vec<f32>, StoreError> {
    Ok(c.meta()
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| StoreError::BadManifest(format!("missing {key}")))?
        .iter()
        .map(|x| x.as_f64().unwrap_or(1.0) as f32)
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamSet;
    use crate::runtime::ModelCfg;
    use crate::serve::model::token_config_entry;
    use crate::serve::reference::reference_forward;
    use crate::serve::ServeModel;
    use crate::util::rng::Rng;

    fn tiny_cfg(name: &str) -> ConfigEntry {
        token_config_entry(
            name,
            ModelCfg {
                n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 16,
                n_classes: 3, vocab: 24, input_dim: 0, n_top: 8, block_q: 16,
            },
        )
    }

    fn tiny_ckpt(cfg: &ConfigEntry, seed: u64) -> Checkpoint {
        let mut rng = Rng::new(seed);
        Checkpoint {
            config: cfg.name.clone(),
            step: 11.0,
            sigma_q: vec![0.5, 0.7],
            sigma_k: vec![0.9, 1.1],
            params: ParamSet::init(cfg, &mut rng),
        }
    }

    fn temp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("had-storeckpt-{}-{name}.stor", std::process::id()))
    }

    #[test]
    fn mmap_load_is_bit_identical_to_heap_load() {
        let cfg = tiny_cfg("store_tiny");
        let ckpt = tiny_ckpt(&cfg, 21);
        let p = temp("identity");
        write_checkpoint(&p, &cfg, &ckpt).unwrap();

        let heap = ServeModel::from_checkpoint(&cfg, &ckpt).unwrap();
        let mapped = ServeModel::from_store(&cfg, &p).unwrap();
        assert_eq!(mapped.sigma_q, heap.sigma_q);
        assert_eq!(mapped.sigma_k, heap.sigma_k);
        assert_eq!(mapped.tok_emb, heap.tok_emb);
        for (a, b) in mapped.layers.iter().zip(&heap.layers) {
            assert_eq!(a.wq, b.wq);
            assert_eq!(a.w2, b.w2);
            assert_eq!(a.ln1_g, b.ln1_g);
        }
        // End to end: the reference forward pass produces bit-identical
        // logits from the mapped and heap-loaded weights.
        let tokens: Vec<i32> = (0..12).map(|i| i % 24).collect();
        let lm = reference_forward(&mapped, &tokens);
        let lh = reference_forward(&heap, &tokens);
        assert_eq!(lm.data, lh.data, "mapped vs heap logits must be bit-identical");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn weight_slabs_stay_mapped_until_written() {
        let cfg = tiny_cfg("store_mapped");
        let ckpt = tiny_ckpt(&cfg, 22);
        let p = temp("mapped");
        write_checkpoint(&p, &cfg, &ckpt).unwrap();
        let model = ServeModel::from_store(&cfg, &p).unwrap();
        // Big weight matrices borrow the mapping zero-copy; the decode
        // path never writes them, so they stay borrowed.
        assert!(model.tok_emb.data.is_mapped());
        assert!(model.layers[0].wq.data.is_mapped());
        assert!(model.layers[1].w1.data.is_mapped());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn config_mismatch_is_typed_not_silent() {
        let cfg = tiny_cfg("store_cfg_a");
        let ckpt = tiny_ckpt(&cfg, 23);
        let p = temp("cfgmismatch");
        write_checkpoint(&p, &cfg, &ckpt).unwrap();
        let other = tiny_cfg("store_cfg_b");
        assert!(matches!(
            ServeModel::from_store(&other, &p),
            Err(StoreError::BadManifest(_))
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn shape_drift_is_typed_not_silent() {
        // Same config name, different geometry: the weight sections no
        // longer match the architecture — must be a ShapeMismatch, never
        // silently mis-sliced weights.
        let cfg = tiny_cfg("store_shape");
        let ckpt = tiny_ckpt(&cfg, 24);
        let p = temp("shapedrift");
        write_checkpoint(&p, &cfg, &ckpt).unwrap();
        let wider = token_config_entry(
            "store_shape",
            ModelCfg {
                n_layers: 2, d_model: 48, n_heads: 2, d_ff: 64, n_ctx: 16,
                n_classes: 3, vocab: 24, input_dim: 0, n_top: 8, block_q: 16,
            },
        );
        assert!(matches!(
            ServeModel::from_store(&wider, &p),
            Err(StoreError::ShapeMismatch(_))
        ));
        std::fs::remove_file(&p).ok();
    }
}
