//! Runtime layer: PJRT execution of the AOT artifacts.
//!
//! * `manifest` — the python→rust artifact contract (configs, param
//!   layout, signatures).
//! * `tensor_data` — Send-able host tensors, Literal conversion.
//! * `client` — single-threaded Runtime: load HLO text, compile, execute.
//! * `engine` — the engine thread owning the Runtime; Send handles for
//!   the coordinator.

pub mod client;
pub mod engine;
pub mod manifest;
pub mod tensor_data;

pub use client::{Executable, Runtime};
pub use engine::{Engine, EngineHandle};
pub use manifest::{ArtifactMeta, ConfigEntry, Init, Manifest, ModelCfg, ParamSpec};
pub use tensor_data::HostTensor;

/// Default artifact directory: $HAD_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var("HAD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
