//! Artifact manifest: the contract written by python/compile/aot.py.
//!
//! The manifest pins down (a) every model configuration (architecture +
//! batch sizes), (b) the exact parameter layout (name/shape/init order —
//! Rust materializes parameters and optimizer state in THIS order), and
//! (c) every artifact's signature.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Parameter initializer kinds understood by `model::init`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    Normal, // N(0, 0.02)
    Zeros,
    Ones,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Mirror of python ModelConfig.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_ctx: usize,
    pub n_classes: usize,
    pub vocab: usize,
    pub input_dim: usize,
    pub n_top: usize,
    pub block_q: usize,
}

impl ModelCfg {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn is_token_mode(&self) -> bool {
        self.vocab > 0
    }

    pub fn n_patches(&self) -> usize {
        self.n_ctx - 1
    }

    fn from_json(j: &Json) -> Result<ModelCfg> {
        let g = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("model config missing {k}"))
        };
        Ok(ModelCfg {
            n_layers: g("n_layers")?,
            d_model: g("d_model")?,
            n_heads: g("n_heads")?,
            d_ff: g("d_ff")?,
            n_ctx: g("n_ctx")?,
            n_classes: g("n_classes")?,
            vocab: g("vocab")?,
            input_dim: g("input_dim")?,
            n_top: g("n_top")?,
            block_q: g("block_q")?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub name: String,
    pub model: ModelCfg,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub params: Vec<ParamSpec>,
}

impl ConfigEntry {
    pub fn n_params_tensors(&self) -> usize {
        self.params.len()
    }

    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(ParamSpec::numel).sum()
    }
}

/// Signature entry for one artifact input.
#[derive(Clone, Debug)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub config: String,
    pub name: String, // e.g. "distill_had_tanh"
    pub file: String,
    pub kind: String,    // teacher_step | distill_step | fwd | calib
    pub variant: String, // standard | had | bit | sab | fp_topn | noattn
    pub ste: bool,
    pub pallas: bool,
    pub batch: usize,
    pub inputs: Vec<TensorSig>,
}

impl ArtifactMeta {
    /// Fully-qualified name used as the runtime cache key.
    pub fn qualified(&self) -> String {
        format!("{}__{}", self.config, self.name)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigEntry>,
    pub artifacts: BTreeMap<String, ArtifactMeta>, // keyed by qualified name
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if j.get("version").and_then(Json::as_usize) != Some(1) {
            bail!("unsupported manifest version");
        }

        let mut configs = BTreeMap::new();
        for (name, cj) in j.get("configs").and_then(Json::as_obj).context("configs")? {
            let model = ModelCfg::from_json(cj.get("model").context("model")?)?;
            let params = cj
                .get("params")
                .and_then(Json::as_arr)
                .context("params")?
                .iter()
                .map(|p| -> Result<ParamSpec> {
                    let name = p.get("name").and_then(Json::as_str).context("param name")?;
                    let shape = p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()?;
                    let init = match p.get("init").and_then(Json::as_str) {
                        Some("normal") => Init::Normal,
                        Some("zeros") => Init::Zeros,
                        Some("ones") => Init::Ones,
                        other => bail!("unknown init {other:?}"),
                    };
                    Ok(ParamSpec { name: name.to_string(), shape, init })
                })
                .collect::<Result<Vec<_>>>()?;
            configs.insert(
                name.clone(),
                ConfigEntry {
                    name: name.clone(),
                    model,
                    train_batch: cj.get("train_batch").and_then(Json::as_usize).context("train_batch")?,
                    eval_batch: cj.get("eval_batch").and_then(Json::as_usize).context("eval_batch")?,
                    params,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts").and_then(Json::as_arr).context("artifacts")? {
            let s = |k: &str| -> Result<String> {
                Ok(a.get(k).and_then(Json::as_str).with_context(|| format!("artifact {k}"))?.to_string())
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .map(|i| -> Result<TensorSig> {
                    Ok(TensorSig {
                        shape: i
                            .get("shape")
                            .and_then(Json::as_arr)
                            .context("sig shape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<Vec<_>>>()?,
                        dtype: i.get("dtype").and_then(Json::as_str).context("dtype")?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let meta = ArtifactMeta {
                config: s("config")?,
                name: s("name")?,
                file: s("file")?,
                kind: s("kind")?,
                variant: s("variant")?,
                ste: a.get("ste").and_then(Json::as_bool).unwrap_or(true),
                pallas: a.get("pallas").and_then(Json::as_bool).unwrap_or(false),
                batch: a.get("batch").and_then(Json::as_usize).context("batch")?,
                inputs,
            };
            if !configs.contains_key(&meta.config) {
                bail!("artifact {} references unknown config {}", meta.name, meta.config);
            }
            artifacts.insert(meta.qualified(), meta);
        }

        Ok(Manifest { dir, configs, artifacts })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs
            .get(name)
            .with_context(|| format!("unknown config {name:?} (have: {:?})", self.configs.keys().collect::<Vec<_>>()))
    }

    pub fn artifact(&self, qualified: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(qualified)
            .with_context(|| format!("unknown artifact {qualified:?}"))
    }

    pub fn artifact_path(&self, qualified: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(qualified)?.file))
    }

    /// All artifacts belonging to one config.
    pub fn artifacts_for(&self, config: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.values().filter(|a| a.config == config).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = manifest_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.configs.contains_key("tinyglue"));
        let art = m.artifact("tinyglue__distill_had_tanh").unwrap();
        assert_eq!(art.kind, "distill_step");
        let cfg = m.config("tinyglue").unwrap();
        // distill signature: 3P + 1 + P + 7 tensors + n_top
        let p = cfg.n_params_tensors();
        assert_eq!(art.inputs.len(), 4 * p + 9);
        assert!(m.artifact_path("tinyglue__teacher_step").unwrap().exists());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load("/nonexistent").is_err());
    }
}
