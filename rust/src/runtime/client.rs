//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (xla_extension 0.5.1 rejects jax>=0.5 protos with
//! 64-bit instruction ids; the text parser reassigns ids).
//!
//! All xla types are !Send: a Runtime must live and be used on a single
//! thread. Cross-thread serving goes through `engine::Engine` instead.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::manifest::Manifest;
use super::tensor_data::HostTensor;
use crate::log_debug;
use crate::log_info;

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// wall-clock spent compiling (reported by `had exp fig1` and §Perf)
    pub compile_time_ms: u128,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest from `dir`.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log_info!(
            "PJRT client up: platform={} devices={} | {} artifacts, {} configs",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len(),
            manifest.configs.len()
        );
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Load + compile an artifact by qualified name (cached).
    pub fn load(&self, qualified: &str) -> Result<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(qualified) {
            return Ok(Rc::clone(exe));
        }
        let path = self.manifest.artifact_path(qualified)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {qualified}"))?;
        let compile_time_ms = t0.elapsed().as_millis();
        log_info!("compiled {qualified} in {compile_time_ms} ms");
        let exe = Rc::new(Executable { name: qualified.to_string(), exe, compile_time_ms });
        self.cache.borrow_mut().insert(qualified.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact with host tensors; returns the un-tupled outputs.
    /// Validates inputs against the manifest signature (cheap; shape bugs
    /// caught here rather than inside XLA).
    pub fn exec(&self, qualified: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let meta = self.manifest.artifact(qualified)?;
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "{qualified}: got {} inputs, want {}",
            inputs.len(),
            meta.inputs.len()
        );
        for (i, (t, sig)) in inputs.iter().zip(&meta.inputs).enumerate() {
            t.check_sig(&sig.shape, &sig.dtype)
                .with_context(|| format!("{qualified} input #{i}"))?;
        }
        let exe = self.load(qualified)?;
        exe.run(inputs)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Number of compiled executables currently cached.
    pub fn cache_len(&self) -> usize {
        self.cache.borrow().len()
    }
}

impl Executable {
    /// Execute with host tensors (converted to literals at the boundary).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        let out = self.run_literals(&lits)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    /// Literal-level execution (used by the distillation hot loop to skip
    /// redundant host conversions — §Perf).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let outs = tuple.to_tuple().context("untupling result")?;
        log_debug!("{} ran in {:?} ({} outputs)", self.name, t0.elapsed(), outs.len());
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    #[test]
    fn exec_rejects_wrong_arity() {
        let Some(rt) = runtime() else { return };
        let err = rt.exec("tinyglue__calib", &[]).unwrap_err();
        assert!(format!("{err}").contains("inputs"));
    }

    #[test]
    fn cache_hits() {
        let Some(rt) = runtime() else { return };
        let a = rt.load("tinyglue__fwd_standard").unwrap();
        let b = rt.load("tinyglue__fwd_standard").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert_eq!(rt.cache_len(), 1);
    }
}
