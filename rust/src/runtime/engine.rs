//! Engine: a dedicated thread that owns the (!Send) PJRT runtime and
//! serves execution requests over channels.
//!
//! This is the boundary between the multi-threaded coordinator (router,
//! batcher, metrics — all Send) and single-threaded PJRT. Handles are
//! cheap to clone; requests are processed FIFO by the engine thread.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::client::Runtime;
use super::tensor_data::HostTensor;
use crate::log_info;

enum Msg {
    Exec {
        artifact: String,
        inputs: Vec<HostTensor>,
        reply: Sender<Result<Vec<HostTensor>>>,
    },
    /// Pre-compile an artifact (warmup) without running it.
    Warmup {
        artifact: String,
        reply: Sender<Result<u128>>,
    },
    Shutdown,
}

/// Cloneable, Send handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Msg>,
}

// Sender<Msg> is Send but not Sync; wrap sends behind per-clone channels.
// We instead make EngineHandle cheap-clone with its own Sender.

pub struct Engine {
    handle: EngineHandle,
    thread: Option<JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine thread; it builds the Runtime from `artifact_dir`.
    pub fn start(artifact_dir: impl Into<std::path::PathBuf>) -> Result<Engine> {
        let dir = artifact_dir.into();
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("had-engine".into())
            .spawn(move || engine_main(dir, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Engine { handle: EngineHandle { tx }, thread: Some(thread) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl EngineHandle {
    /// Blocking execute on the engine thread.
    pub fn exec(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Exec { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }

    /// Compile an artifact ahead of time; returns compile time in ms.
    pub fn warmup(&self, artifact: &str) -> Result<u128> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Warmup { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }
}

fn engine_main(dir: std::path::PathBuf, rx: Receiver<Msg>, ready: Sender<Result<()>>) {
    let rt = match Runtime::new(&dir) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let mut served = 0u64;
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Exec { artifact, inputs, reply } => {
                let out = rt.exec(&artifact, &inputs);
                served += 1;
                let _ = reply.send(out);
            }
            Msg::Warmup { artifact, reply } => {
                let out = rt.load(&artifact).map(|e| e.compile_time_ms);
                let _ = reply.send(out);
            }
            Msg::Shutdown => break,
        }
    }
    log_info!("engine thread exiting after {served} requests");
}

/// A shared engine for tests/benches that want a singleton (compiling
/// artifacts is expensive; reuse across test cases).
pub fn shared_engine(dir: &std::path::Path) -> Result<Arc<Mutex<Engine>>> {
    Ok(Arc::new(Mutex::new(Engine::start(dir)?)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn engine_round_trip_from_other_threads() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::start(dir).unwrap();
        let h = engine.handle();
        // error path crosses the thread boundary cleanly
        let err = h.exec("tinyglue__calib", vec![]).unwrap_err();
        assert!(format!("{err}").contains("inputs"));
        // concurrent handles from several threads
        let mut joins = vec![];
        for _ in 0..4 {
            let h = engine.handle();
            joins.push(std::thread::spawn(move || {
                h.exec("nonexistent__artifact", vec![]).unwrap_err();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}
