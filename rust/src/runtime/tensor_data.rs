//! Host-side tensors: the Send-able currency between coordinator threads
//! and the (single) PJRT engine thread. Converts to/from xla::Literal at
//! the engine boundary.

use anyhow::{bail, Context, Result};

/// Plain host tensor. Shapes are explicit; data is row-major.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn vec_f32(data: Vec<f32>) -> HostTensor {
        HostTensor::F32 { shape: vec![data.len()], data }
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "float32",
            HostTensor::I32 { .. } => "int32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor, got f32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            HostTensor::I32 { data, .. } if data.len() == 1 => Ok(data[0] as f32),
            _ => bail!("not a scalar (numel={})", self.numel()),
        }
    }

    /// Build an xla::Literal with this tensor's shape and contents.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims).context("reshape literal")?)
    }

    /// Read a Literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.primitive_type() {
            xla::PrimitiveType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>().context("to_vec f32")?,
            }),
            xla::PrimitiveType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>().context("to_vec i32")?,
            }),
            other => bail!("unsupported literal type {other:?}"),
        }
    }

    /// Validate against a manifest signature entry.
    pub fn check_sig(&self, shape: &[usize], dtype: &str) -> Result<()> {
        if self.shape() != shape {
            bail!("shape mismatch: got {:?}, want {:?}", self.shape(), shape);
        }
        if self.dtype_str() != dtype {
            bail!("dtype mismatch: got {}, want {}", self.dtype_str(), dtype);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert!(t.check_sig(&[2, 3], "float32").is_ok());
        assert!(t.check_sig(&[3, 2], "float32").is_err());
        assert!(t.check_sig(&[2, 3], "int32").is_err());
    }

    #[test]
    fn scalar_access() {
        assert_eq!(HostTensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
        assert!(HostTensor::f32(vec![2], vec![1.0, 2.0]).scalar().is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_scalar_shapes() {
        let t = HostTensor::i32(vec![3], vec![7, -1, 0]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let s = HostTensor::scalar_f32(1.5);
        let back = HostTensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(s, back);
    }
}
