//! `had` — the leader binary: experiment harnesses, the distillation
//! pipeline, and the long-context serving demo, all driven from the AOT
//! artifacts (run `make artifacts` once; Python never runs here).

use anyhow::{bail, Result};

use had::exp::{self, SuiteOptions};
use had::runtime::{default_artifact_dir, Runtime};
use had::util::cli::Args;

const USAGE: &str = "\
had — Hamming Attention Distillation (paper reproduction CLI)

USAGE:
  had exp <table1|table2|table3|fig1|fig3|fig4|fig5|all> [--scale X] [--seed N]
          [--task MNLI] [--config vision_tiny] [--ctx 256] [--reps 20]
  had hwsim                     print the Table-3 hardware comparison
  had artifacts                 list artifacts in the manifest
  had --help

Common flags:
  --artifacts DIR   artifact directory (default: ./artifacts or $HAD_ARTIFACTS)
  --scale X         scale every training budget (default 1.0; see EXPERIMENTS.md)
  --seed N          RNG seed (default 0x4AD)
  --results DIR     results sink (default ./results)
";

fn suite_options(args: &Args) -> SuiteOptions {
    let mut opts = SuiteOptions::default();
    opts.scale = args.get_f64("scale", opts.scale);
    opts.teacher_scale = args.get_f64("teacher-scale", opts.scale);
    opts.seed = args.get_u64("seed", opts.seed);
    opts.eval_batches = args.get_usize("eval-batches", opts.eval_batches);
    opts.calib_batches = args.get_usize("calib-batches", opts.calib_batches);
    opts.lr = args.get_f64("lr", opts.lr as f64) as f32;
    opts.teacher_lr = args.get_f64("teacher-lr", opts.teacher_lr as f64) as f32;
    opts.results_dir = args.get_str("results", "results").into();
    opts
}

fn main() -> Result<()> {
    had::util::log::init_from_env();
    let args = Args::from_env();
    let artifact_dir = args
        .flag("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);

    match args.command.as_deref() {
        Some("exp") => {
            let which = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("all");
            let opts = suite_options(&args);
            // fig4 and table3 need no runtime
            match which {
                "fig4" => {
                    exp::fig4::run(&opts)?;
                    return Ok(());
                }
                "table3" => {
                    exp::table3::run(&opts)?;
                    return Ok(());
                }
                _ => {}
            }
            let rt = Runtime::new(&artifact_dir)?;
            match which {
                "table1" => {
                    // --task accepts a comma-separated list
                    let tasks = args.flag("task").map(|t| {
                        had::data::tinyglue::GlueTask::ALL
                            .iter()
                            .copied()
                            .filter(|x| {
                                t.split(',').any(|n| x.name().eq_ignore_ascii_case(n.trim()))
                            })
                            .collect::<Vec<_>>()
                    });
                    exp::table1::run(&rt, &opts, tasks)?;
                }
                "table2" => {
                    exp::table2::run(&rt, &opts, args.flag("config"))?;
                }
                "fig1" => {
                    exp::fig1::run(&rt, &opts, args.get_usize("reps", 10))?;
                }
                "fig3" => {
                    exp::fig3::run(&rt, &opts)?;
                }
                "fig5" => {
                    let only = args.flag("ctx").map(|c| c.parse::<usize>().unwrap());
                    exp::fig5::run(&rt, &opts, only)?;
                }
                "all" => {
                    exp::fig4::run(&opts)?;
                    exp::table3::run(&opts)?;
                    exp::fig1::run(&rt, &opts, args.get_usize("reps", 10))?;
                    exp::table1::run(&rt, &opts, None)?;
                    exp::table2::run(&rt, &opts, None)?;
                    exp::fig3::run(&rt, &opts)?;
                    exp::fig5::run(&rt, &opts, None)?;
                }
                other => bail!("unknown experiment {other:?}\n{USAGE}"),
            }
        }
        Some("hwsim") => {
            let opts = suite_options(&args);
            exp::table3::run(&opts)?;
        }
        Some("artifacts") => {
            let m = had::runtime::Manifest::load(&artifact_dir)?;
            println!("{} configs, {} artifacts in {:?}", m.configs.len(), m.artifacts.len(), m.dir);
            for (name, art) in &m.artifacts {
                println!("  {name:<40} kind={:<13} batch={}", art.kind, art.batch);
            }
        }
        Some("--help") | None => {
            println!("{USAGE}");
        }
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
    Ok(())
}
