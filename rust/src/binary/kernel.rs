//! Tiled multi-threaded XNOR-popcount scoring engine with fused
//! streaming top-N — the blocked rewrite of the paper's Hamming scoring
//! loop (Eqs. 4-6) that both attention fast paths now run on.
//!
//! ## Why a kernel module
//!
//! The original fast path (`binary::attention`, kept as the scalar
//! oracle) scores one (query, key) pair at a time, materializes the full
//! integer score row, and then runs top-N selection as a second pass over
//! that row. Three structural costs fall out of that shape:
//!
//! 1. every packed key row is re-read once per query (no register reuse),
//! 2. an `n_k`-sized score buffer is written and re-read per query (for a
//!    128k-token context that is 512 KiB of traffic per query row), and
//! 3. the whole computation runs on one core even though the serving
//!    coordinator already owns a worker pool.
//!
//! ## Tile / threshold design
//!
//! **Register-blocked tiles.** Queries are processed in blocks of
//! [`QUERY_BLOCK`] (= 4) rows. The block's packed query words are hoisted
//! into stack arrays (`[[u64; W]; 4]`, monomorphized over `W =
//! words_per_row` exactly like `hamming::score_matrix_w`), and the key
//! stream is walked page-major: each contiguous key block — the whole
//! `PackedMat` for the contiguous path, each resident `kvcache` page for
//! the paged path — is streamed exactly once per query block, and every
//! key row loaded from memory is scored against all 4 resident queries
//! before moving on. Key-side memory traffic drops 4x; the XOR+POPCNT
//! chain stays fully unrolled.
//!
//! **Fused streaming top-N.** Binary scores live in the tiny integer
//! domain `[-d, +d]` (with the parity of `d`), so a counting histogram
//! over the scores a query has *kept so far* is enough to maintain the
//! exact running top-N threshold while scoring ([`StreamTopN`]). Each
//! score is compared against the threshold the moment it is produced:
//! once `n_top` candidates are live, a score at or below the cutoff is
//! discarded inline — one compare, no write — and a better score bumps
//! the cutoff via the histogram. Selection (Eq. 6) therefore finishes
//! when scoring finishes: there is no second full-row pass and no
//! `O(n_k)` score buffer at all, only `O(n_top)` candidate state per
//! query. The kept set — including the "ties broken by lowest index"
//! rule — is bit-identical to `topn::select_topn_counting` on the
//! materialized row, which the property suite asserts.
//!
//! **Data parallelism.** [`had_attention_pooled`] /
//! [`had_attention_paged_pooled`] shard query blocks via
//! `util::threadpool::parallel_map`, with the pool supplying the
//! concurrency budget (execution runs on scoped threads so shards may
//! borrow the caller's stack); each shard owns its scratch, writes a
//! disjoint range of output rows, and performs the exact same per-row
//! arithmetic, so threaded output equals serial output bit for bit
//! (also property-tested). The serving coordinator layers the second
//! axis on top: sessions within a drained batch are sharded across its
//! `kernel_workers` budget (`coordinator::server`).
//!
//! **Backend dispatch.** The popcount inner step is a runtime-selected
//! [`KernelBackend`] (`binary::simd`): scalar oracle, portable SWAR, or
//! AVX2 / AVX-512 VPOPCNTQ / NEON vectorized tile scorers — all behind
//! the same 4-query tile shapes, selected once per process
//! (`HAD_KERNEL` env override) and threaded through every engine entry
//! here, so the contiguous, paged, pooled, serve-decode, and generation
//! paths all dispatch through it. Explicit-backend entry points
//! ([`had_attention_backend`] etc.) serve the bench sweep and the
//! backend-matrix property tests.
//!
//! Everything downstream of selection — sparse softmax (Eq. 7) and
//! sparse AV accumulation (Eq. 8) — deliberately reproduces the scalar
//! oracle's operation order so outputs stay bit-identical end to end.

use crate::binary::attention::{HadAttnConfig, PackedKv, Scratch, EMPTY_KV_MSG};
use crate::binary::bitpack::PackedMat;
use crate::binary::simd::{self, KernelBackend};
use crate::binary::topn::sort_entries;
use crate::kvcache::SessionKv;
use crate::tensor::Mat;
use crate::util::threadpool::{parallel_map, shard_ranges, ThreadPool};

/// Queries scored per tile: each key row loaded from memory is scored
/// against this many resident queries before the next row is touched.
pub const QUERY_BLOCK: usize = 4;

/// Streaming exact top-N over the bounded integer score domain.
///
/// Scores arrive in ascending index order; `push` keeps the invariant
/// that the live candidate set is exactly the top-`n_top` of the prefix
/// seen so far, ties broken by lowest index (the shared lax.top_k
/// convention). State is a `2d+1`-bucket histogram of live candidate
/// scores plus an append-only candidate buffer that is compacted in
/// place whenever it reaches twice the kept size, so memory stays
/// `O(n_top)` regardless of context length.
#[derive(Clone, Debug, Default)]
pub struct StreamTopN {
    d: usize,
    n_top: usize,
    /// Cutoff once `live == n_top`: scores <= thr can no longer enter.
    thr: i32,
    live: usize,
    /// Histogram of live candidate scores, bucket `s + d`.
    hist: Vec<u32>,
    /// Admitted candidates in index order; may carry dead entries until
    /// the next compaction. A dead entry is one whose score fell below
    /// the advancing threshold after it was admitted.
    cand: Vec<(i32, usize)>,
    /// Compaction trigger for `cand`.
    cap: usize,
}

impl StreamTopN {
    pub fn new() -> StreamTopN {
        StreamTopN::default()
    }

    /// Prepare for one score stream keeping `n_top` of scores in
    /// `[-d, d]`. Reuses the histogram/candidate allocations.
    pub fn reset(&mut self, n_top: usize, d: usize) {
        self.d = d;
        self.n_top = n_top.max(1);
        self.thr = i32::MIN;
        self.live = 0;
        self.hist.clear();
        self.hist.resize(2 * d + 1, 0);
        self.cand.clear();
        self.cap = 2 * self.n_top + 8;
    }

    /// Offer score `s` for key index `idx`. Indices must arrive in
    /// ascending order (the tie-break rule depends on it). The common
    /// long-context case — a score at or below the established cutoff —
    /// is a single compare.
    #[inline]
    pub fn push(&mut self, s: i32, idx: usize) {
        debug_assert!(s.unsigned_abs() as usize <= self.d, "score outside [-d, d]");
        if self.live == self.n_top && s <= self.thr {
            return;
        }
        self.admit(s, idx);
    }

    fn admit(&mut self, s: i32, idx: usize) {
        if self.cand.len() == self.cap {
            self.compact();
        }
        self.cand.push((s, idx));
        let d = self.d as i32;
        self.hist[(s + d) as usize] += 1;
        if self.live < self.n_top {
            self.live += 1;
            if self.live == self.n_top {
                // establish the cutoff: lowest non-empty bucket
                let mut b = 0usize;
                while self.hist[b] == 0 {
                    b += 1;
                }
                self.thr = b as i32 - d;
            }
        } else {
            // drop one live candidate at the cutoff (the latest-admitted
            // one — future keeps never resurrect it, see compact())
            let mut b = (self.thr + d) as usize;
            self.hist[b] -= 1;
            if self.hist[b] == 0 {
                // terminates: the entry just admitted sits above thr
                while self.hist[b] == 0 {
                    b += 1;
                }
                self.thr = b as i32 - d;
            }
        }
    }

    /// Drop dead candidates: the live set is every entry above the
    /// cutoff plus the FIRST `hist[thr]` entries at the cutoff (admission
    /// is in index order and drops always removed the latest-admitted
    /// cutoff entry, so earliest-index ties survive — the oracle rule).
    fn compact(&mut self) {
        let thr = self.thr;
        let mut take = self.hist[(thr + self.d as i32) as usize];
        self.cand.retain(|&(s, _)| {
            if s > thr {
                true
            } else if s == thr && take > 0 {
                take -= 1;
                true
            } else {
                false
            }
        });
    }

    /// Finish the stream: the kept entries sorted by descending score,
    /// ties by ascending index — exactly `select_topn_counting`'s output
    /// on the full score row.
    pub fn finish(&mut self) -> &[(i32, usize)] {
        if self.live == self.n_top {
            self.compact();
        }
        sort_entries(&mut self.cand);
        &self.cand
    }
}

/// A key store the kernel can stream: contiguous packed key blocks in
/// ascending global-index order, plus value-row accumulation. Implemented
/// by the contiguous `PackedKv` layout (one block) and the paged
/// `SessionKv` layout (one block per resident page).
pub(crate) trait KeyBlocks: Sync {
    fn d(&self) -> usize;
    fn d_v(&self) -> usize;
    fn n_k(&self) -> usize;
    /// Visit every key block as `(base_index, n_rows, packed_words)`,
    /// in ascending base order (`packed_words.len() == n_rows * w`).
    fn for_each_block(&self, visit: &mut dyn FnMut(usize, usize, &[u64]));
    /// `orow += w * value_row(i)` — accumulation lives behind the source
    /// so paged stores can decode bf16 values inline instead of handing
    /// out borrowed f32 rows.
    fn accum_value(&self, i: usize, w: f32, orow: &mut [f32]);
}

/// Contiguous layout: the whole `PackedMat` is one tile-aligned block.
pub(crate) struct ContiguousSrc<'a> {
    keys: &'a PackedMat,
    values: &'a Mat,
}

impl<'a> ContiguousSrc<'a> {
    pub(crate) fn new(kv: &'a PackedKv) -> ContiguousSrc<'a> {
        ContiguousSrc { keys: &kv.keys, values: &kv.values }
    }
}

impl KeyBlocks for ContiguousSrc<'_> {
    fn d(&self) -> usize {
        self.keys.d
    }
    fn d_v(&self) -> usize {
        self.values.cols
    }
    fn n_k(&self) -> usize {
        self.keys.rows
    }
    fn for_each_block(&self, visit: &mut dyn FnMut(usize, usize, &[u64])) {
        visit(0, self.keys.rows, self.keys.block(0, self.keys.rows));
    }
    fn accum_value(&self, i: usize, w: f32, orow: &mut [f32]) {
        for (o, &v) in orow.iter_mut().zip(self.values.row(i)) {
            *o += w * v;
        }
    }
}

/// Paged layout: one block per resident page, streamed page-major so each
/// page is touched exactly once per query block.
pub(crate) struct PagedSrc<'a> {
    kv: &'a SessionKv,
}

impl<'a> PagedSrc<'a> {
    pub(crate) fn new(kv: &'a SessionKv) -> PagedSrc<'a> {
        PagedSrc { kv }
    }
}

impl KeyBlocks for PagedSrc<'_> {
    fn d(&self) -> usize {
        self.kv.d()
    }
    fn d_v(&self) -> usize {
        self.kv.d_v()
    }
    fn n_k(&self) -> usize {
        self.kv.len()
    }
    fn for_each_block(&self, visit: &mut dyn FnMut(usize, usize, &[u64])) {
        let mut base = 0usize;
        for page in self.kv.pages() {
            if !page.is_empty() {
                visit(base, page.len(), page.keys_packed());
            }
            base += page.len();
        }
    }
    fn accum_value(&self, i: usize, w: f32, orow: &mut [f32]) {
        self.kv.accum_value(i, w, orow);
    }
}

/// Monomorphized query-block scorer: hoist the block's packed query
/// words into registers — row-major for the scalar chains, transposed
/// once per tile for the lane-parallel backends — then stream every
/// key block once through the selected backend's tile scorer (the
/// fusion point: each score goes straight into its query's streaming
/// top-N, not a second pass).
#[allow(clippy::too_many_arguments)]
fn stream_scores_w<const W: usize>(
    be: KernelBackend,
    d: i32,
    qp: &PackedMat,
    q0: usize,
    qb: usize,
    src: &dyn KeyBlocks,
    tops: &mut [StreamTopN],
) {
    debug_assert_eq!(qp.words_per_row, W);
    let mut qw = [[0u64; W]; QUERY_BLOCK];
    for (t, qwt) in qw.iter_mut().take(qb).enumerate() {
        qwt.copy_from_slice(&qp.row(q0 + t)[..W]);
    }
    let qt = simd::transpose::<W>(&qw[..qb]);
    src.for_each_block(&mut |base, n_rows, keys| {
        simd::score_block_w::<W>(be, d, &qw[..qb], &qt, n_rows, keys, base, &mut *tops);
    });
}

/// Fallback for wide heads (d > 256): dynamic word count, same blocking.
/// The query block is transposed once per tile (`qt[w][t]` = word w of
/// query t) into the caller's scratch buffer — no allocation in the
/// steady state — so lane-parallel backends run without per-block setup.
#[allow(clippy::too_many_arguments)]
fn stream_scores_dyn(
    be: KernelBackend,
    d: i32,
    qp: &PackedMat,
    q0: usize,
    qb: usize,
    src: &dyn KeyBlocks,
    tops: &mut [StreamTopN],
    qt: &mut Vec<[u64; QUERY_BLOCK]>,
) {
    let w = qp.words_per_row;
    qt.clear();
    qt.resize(w, [0u64; QUERY_BLOCK]);
    for t in 0..qb {
        for (qs, &x) in qt.iter_mut().zip(qp.row(q0 + t)) {
            qs[t] = x;
        }
    }
    let qt: &[[u64; QUERY_BLOCK]] = qt;
    src.for_each_block(&mut |base, n_rows, keys| {
        simd::score_block_dyn(be, d, qt, qb, n_rows, keys, base, &mut *tops);
    });
}

#[allow(clippy::too_many_arguments)]
fn stream_scores(
    be: KernelBackend,
    d_bits: usize,
    qp: &PackedMat,
    q0: usize,
    qb: usize,
    src: &dyn KeyBlocks,
    tops: &mut [StreamTopN],
    qt_scratch: &mut Vec<[u64; QUERY_BLOCK]>,
) {
    let d = d_bits as i32;
    match qp.words_per_row {
        1 => stream_scores_w::<1>(be, d, qp, q0, qb, src, tops),
        2 => stream_scores_w::<2>(be, d, qp, q0, qb, src, tops),
        3 => stream_scores_w::<3>(be, d, qp, q0, qb, src, tops),
        4 => stream_scores_w::<4>(be, d, qp, q0, qb, src, tops),
        _ => stream_scores_dyn(be, d, qp, q0, qb, src, tops, qt_scratch),
    }
}

/// Sparse softmax + AV accumulation over the kept entries — operation
/// order copied verbatim from the scalar oracle (Eqs. 7-8) so outputs
/// match bit for bit.
fn finalize_row(
    kept: &[(i32, usize)],
    scale: f32,
    src: &dyn KeyBlocks,
    probs: &mut [f32],
    orow: &mut [f32],
) {
    let probs = &mut probs[..kept.len()];
    let max = kept[0].0 as f32 * scale; // kept is sorted descending
    let mut sum = 0.0f32;
    for (p, &(s, _)) in probs.iter_mut().zip(kept) {
        *p = (s as f32 * scale - max).exp();
        sum += *p;
    }
    let inv = 1.0 / sum;
    for (&p, &(_, j)) in probs.iter().zip(kept) {
        src.accum_value(j, p * inv, orow);
    }
}

/// Score query rows `[lo, hi)` (`lo` tile-aligned) and write their
/// output rows into `out_rows` (`(hi - lo) * d_v` floats). This is the
/// single shared per-shard body of the serial and pooled engines — one
/// copy of the block loop, so the two cannot drift apart and break the
/// pooled == serial bit-identity invariant.
#[allow(clippy::too_many_arguments)]
fn score_rows(
    be: KernelBackend,
    qp: &PackedMat,
    src: &dyn KeyBlocks,
    lo: usize,
    hi: usize,
    d: usize,
    n_top: usize,
    scale: f32,
    tops: &mut [StreamTopN],
    probs: &mut [f32],
    out_rows: &mut [f32],
) {
    let d_v = src.d_v();
    // dyn-path transpose scratch, reused across this shard's tiles
    // (empty and untouched for d <= 256)
    let mut qt_scratch: Vec<[u64; QUERY_BLOCK]> = Vec::new();
    let mut q0 = lo;
    while q0 < hi {
        let qb = QUERY_BLOCK.min(hi - q0);
        for top in tops.iter_mut().take(qb) {
            top.reset(n_top, d);
        }
        stream_scores(be, d, qp, q0, qb, src, &mut tops[..qb], &mut qt_scratch);
        for t in 0..qb {
            let kept = tops[t].finish();
            let r0 = (q0 - lo + t) * d_v;
            finalize_row(kept, scale, src, probs, &mut out_rows[r0..r0 + d_v]);
        }
        q0 += qb;
    }
}

/// Serial blocked engine: the body behind `had_attention_with` and
/// `had_attention_paged_with`, dispatching through the process-wide
/// active backend.
pub(crate) fn run_serial(
    q: &Mat,
    src: &dyn KeyBlocks,
    cfg: &HadAttnConfig,
    scratch: &mut Scratch,
) -> Mat {
    run_serial_backend(q, src, cfg, scratch, KernelBackend::active())
}

/// Serial blocked engine with an explicit backend (bench sweep and the
/// backend-matrix property tests).
pub(crate) fn run_serial_backend(
    q: &Mat,
    src: &dyn KeyBlocks,
    cfg: &HadAttnConfig,
    scratch: &mut Scratch,
    be: KernelBackend,
) -> Mat {
    // inert unless this call runs inside a traced (sampled) scope
    let mut kspan = crate::obs::span("kernel");
    let d = q.cols;
    assert_eq!(d, src.d(), "query/key dim mismatch");
    let n_k = src.n_k();
    assert!(n_k > 0, "{}", EMPTY_KV_MSG);
    kspan.set_payload(n_k as u64);
    let d_v = src.d_v();
    let n_top = cfg.n_top.clamp(1, n_k);
    let scale = cfg.temp / (d as f32).sqrt();

    let Scratch { probs, qp, tops, .. } = scratch;
    qp.pack_into(q.rows, d, &q.data);
    probs.resize(n_top, 0.0);
    if tops.len() < QUERY_BLOCK {
        tops.resize_with(QUERY_BLOCK, StreamTopN::default);
    }

    let mut out = Mat::zeros(q.rows, d_v);
    score_rows(be, qp, src, 0, q.rows, d, n_top, scale, tops, probs, &mut out.data);
    out
}

/// Threaded blocked engine: shard query blocks across the pool via
/// `parallel_map`. Each shard runs the same `score_rows` body on a
/// disjoint output range, so the result equals `run_serial` bit for bit
/// regardless of worker count.
pub(crate) fn run_pooled(
    q: &Mat,
    src: &dyn KeyBlocks,
    cfg: &HadAttnConfig,
    pool: &ThreadPool,
) -> Mat {
    run_pooled_backend(q, src, cfg, pool, KernelBackend::active())
}

pub(crate) fn run_pooled_backend(
    q: &Mat,
    src: &dyn KeyBlocks,
    cfg: &HadAttnConfig,
    pool: &ThreadPool,
    be: KernelBackend,
) -> Mat {
    // inert unless this call runs inside a traced (sampled) scope
    let mut kspan = crate::obs::span("kernel");
    let d = q.cols;
    assert_eq!(d, src.d(), "query/key dim mismatch");
    let n_k = src.n_k();
    assert!(n_k > 0, "{}", EMPTY_KV_MSG);
    kspan.set_payload(n_k as u64);
    let d_v = src.d_v();
    let n_top = cfg.n_top.clamp(1, n_k);
    let scale = cfg.temp / (d as f32).sqrt();

    let qp = PackedMat::pack(q.rows, d, &q.data);
    let shards = shard_ranges(q.rows, pool.n_workers(), QUERY_BLOCK);
    let chunks: Vec<Vec<f32>> = parallel_map(pool, &shards, |_, &(lo, hi)| {
        let mut tops: Vec<StreamTopN> = Vec::new();
        tops.resize_with(QUERY_BLOCK, StreamTopN::default);
        let mut probs = vec![0.0f32; n_top];
        let mut rows = vec![0.0f32; (hi - lo) * d_v];
        score_rows(be, &qp, src, lo, hi, d, n_top, scale, &mut tops, &mut probs, &mut rows);
        rows
    });

    let mut out = Mat::zeros(q.rows, d_v);
    for (chunk, &(lo, hi)) in chunks.iter().zip(&shards) {
        out.data[lo * d_v..hi * d_v].copy_from_slice(chunk);
    }
    out
}

/// Threaded HAD attention over a contiguous `PackedKv`; bit-identical to
/// `had_attention` at any worker count.
pub fn had_attention_pooled(
    q: &Mat,
    kv: &PackedKv,
    cfg: &HadAttnConfig,
    pool: &ThreadPool,
) -> Mat {
    run_pooled(q, &ContiguousSrc::new(kv), cfg, pool)
}

/// Threaded HAD attention over a paged session cache; bit-identical to
/// `had_attention_paged` at any worker count.
pub fn had_attention_paged_pooled(
    q: &Mat,
    kv: &SessionKv,
    cfg: &HadAttnConfig,
    pool: &ThreadPool,
) -> Mat {
    run_pooled(q, &PagedSrc::new(kv), cfg, pool)
}

/// HAD attention over a contiguous `PackedKv` on an explicit popcount
/// backend; bit-identical to `had_attention` (and the scalar oracle)
/// for every available backend.
pub fn had_attention_backend(
    q: &Mat,
    kv: &PackedKv,
    cfg: &HadAttnConfig,
    be: KernelBackend,
) -> Mat {
    let mut scratch = Scratch::default();
    run_serial_backend(q, &ContiguousSrc::new(kv), cfg, &mut scratch, be)
}

/// Paged HAD attention on an explicit popcount backend.
pub fn had_attention_paged_backend(
    q: &Mat,
    kv: &SessionKv,
    cfg: &HadAttnConfig,
    be: KernelBackend,
) -> Mat {
    let mut scratch = Scratch::default();
    run_serial_backend(q, &PagedSrc::new(kv), cfg, &mut scratch, be)
}

/// Threaded contiguous HAD attention on an explicit popcount backend.
pub fn had_attention_pooled_backend(
    q: &Mat,
    kv: &PackedKv,
    cfg: &HadAttnConfig,
    pool: &ThreadPool,
    be: KernelBackend,
) -> Mat {
    run_pooled_backend(q, &ContiguousSrc::new(kv), cfg, pool, be)
}

/// Threaded paged HAD attention on an explicit popcount backend.
pub fn had_attention_paged_pooled_backend(
    q: &Mat,
    kv: &SessionKv,
    cfg: &HadAttnConfig,
    pool: &ThreadPool,
    be: KernelBackend,
) -> Mat {
    run_pooled_backend(q, &PagedSrc::new(kv), cfg, pool, be)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binary::attention::{
        had_attention, had_attention_paged, had_attention_paged_scalar, had_attention_scalar,
    };
    use crate::binary::topn::select_topn_counting;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::random(r, c, rng, 1.0)
    }

    fn stream_all(scores: &[i32], n_top: usize, d: usize) -> Vec<(i32, usize)> {
        let mut st = StreamTopN::new();
        st.reset(n_top, d);
        for (i, &s) in scores.iter().enumerate() {
            st.push(s, i);
        }
        st.finish().to_vec()
    }

    #[test]
    fn stream_topn_matches_counting_randomized() {
        let mut rng = Rng::new(31);
        for _ in 0..300 {
            let d = rng.range_usize(1, 96);
            let n = rng.range_usize(1, 400);
            let n_top = match rng.range_usize(0, 3) {
                0 => 1,
                1 => n,
                _ => rng.range_usize(1, n + 1),
            };
            let scores: Vec<i32> = (0..n)
                .map(|_| rng.below((2 * d + 1) as u64) as i32 - d as i32)
                .collect();
            assert_eq!(
                stream_all(&scores, n_top, d),
                select_topn_counting(&scores, n_top, d),
                "d={d} n={n} N={n_top}"
            );
        }
    }

    #[test]
    fn stream_topn_adversarial_orders() {
        // ascending scores force maximal admissions (every score beats
        // the cutoff), exercising compaction; constant scores force
        // maximal ties.
        let d = 16usize;
        for n_top in [1usize, 5, 64] {
            let asc: Vec<i32> = (0..500).map(|i| (i % (2 * d as i32 + 1)) - d as i32).collect();
            let mut sorted = asc.clone();
            sorted.sort_unstable();
            for scores in [&sorted, &asc, &vec![3i32; 500]] {
                assert_eq!(
                    stream_all(scores, n_top, d),
                    select_topn_counting(scores, n_top, d),
                    "N={n_top}"
                );
            }
        }
    }

    #[test]
    fn stream_topn_memory_stays_bounded() {
        // worst case (sorted ascending) must not grow past the
        // compaction cap even with 50k keys
        let mut st = StreamTopN::new();
        st.reset(10, 32);
        for i in 0..50_000usize {
            st.push((i % 65) as i32 - 32, i);
        }
        assert!(st.cand.len() <= st.cap, "{} > {}", st.cand.len(), st.cap);
        assert_eq!(st.finish().len(), 10);
    }

    #[test]
    fn blocked_matches_scalar_contiguous() {
        let mut rng = Rng::new(5);
        // n_q covering full and partial query blocks; ragged dims
        for (n_q, n_k, d, n_top) in
            [(1usize, 7usize, 16usize, 3usize), (4, 64, 64, 9), (5, 33, 65, 33), (11, 100, 96, 1)]
        {
            let q = rand_mat(&mut rng, n_q, d);
            let k = rand_mat(&mut rng, n_k, d);
            let v = rand_mat(&mut rng, n_k, 8);
            let kv = PackedKv::new(&k, &v);
            let cfg = HadAttnConfig { n_top, temp: 0.8 };
            assert_eq!(
                had_attention(&q, &kv, &cfg),
                had_attention_scalar(&q, &kv, &cfg),
                "n_q={n_q} n_k={n_k} d={d} N={n_top}"
            );
        }
    }

    #[test]
    fn blocked_matches_scalar_paged() {
        let mut rng = Rng::new(6);
        // page sizes that straddle the 4-query tile and word boundaries
        for (n_k, d, page_tokens) in [(32usize, 64usize, 3usize), (33, 65, 8), (100, 130, 7)] {
            let q = rand_mat(&mut rng, 6, d);
            let k = rand_mat(&mut rng, n_k, d);
            let v = rand_mat(&mut rng, n_k, 8);
            let mut paged = SessionKv::new(d, 8, page_tokens);
            paged.append(&k, &v);
            let cfg = HadAttnConfig { n_top: 9, temp: 1.0 };
            assert_eq!(
                had_attention_paged(&q, &paged, &cfg),
                had_attention_paged_scalar(&q, &paged, &cfg),
                "n_k={n_k} d={d} page={page_tokens}"
            );
        }
    }

    #[test]
    fn pooled_matches_serial_any_worker_count() {
        let mut rng = Rng::new(7);
        let (n_q, n_k, d, d_v) = (13usize, 70usize, 48usize, 8usize);
        let q = rand_mat(&mut rng, n_q, d);
        let k = rand_mat(&mut rng, n_k, d);
        let v = rand_mat(&mut rng, n_k, d_v);
        let kv = PackedKv::new(&k, &v);
        let cfg = HadAttnConfig { n_top: 12, temp: 1.0 };
        let want = had_attention(&q, &kv, &cfg);
        let mut paged = SessionKv::new(d, d_v, 16);
        paged.append(&k, &v);
        let want_paged = had_attention_paged(&q, &paged, &cfg);
        assert_eq!(want, want_paged);
        for workers in 1..=4 {
            let pool = ThreadPool::new(workers);
            assert_eq!(want, had_attention_pooled(&q, &kv, &cfg, &pool), "w={workers}");
            assert_eq!(
                want,
                had_attention_paged_pooled(&q, &paged, &cfg, &pool),
                "paged w={workers}"
            );
        }
    }

    #[test]
    fn backend_matrix_matches_scalar_contiguous_and_paged() {
        // every host-available backend, through both monomorphized tile
        // widths (W = 1..4, incl. the d = 256 boundary) and the dyn
        // wide-head path (d = 320), contiguous and paged
        let mut rng = Rng::new(12);
        for (n_q, n_k, d, n_top) in
            [(5usize, 33usize, 64usize, 9usize), (4, 64, 256, 7), (3, 50, 320, 5), (1, 7, 16, 3)]
        {
            let q = rand_mat(&mut rng, n_q, d);
            let k = rand_mat(&mut rng, n_k, d);
            let v = rand_mat(&mut rng, n_k, 8);
            let kv = PackedKv::new(&k, &v);
            let mut paged = SessionKv::new(d, 8, 7);
            paged.append(&k, &v);
            let cfg = HadAttnConfig { n_top, temp: 0.9 };
            let want = had_attention_scalar(&q, &kv, &cfg);
            let want_paged = had_attention_paged_scalar(&q, &paged, &cfg);
            for be in KernelBackend::available() {
                assert_eq!(
                    want,
                    had_attention_backend(&q, &kv, &cfg, be),
                    "backend={} d={d}",
                    be.name()
                );
                assert_eq!(
                    want_paged,
                    had_attention_paged_backend(&q, &paged, &cfg, be),
                    "paged backend={} d={d}",
                    be.name()
                );
            }
        }
    }

    #[test]
    fn backend_pooled_matches_serial_scalar() {
        let mut rng = Rng::new(13);
        let (n_q, n_k, d, d_v) = (11usize, 60usize, 80usize, 8usize);
        let q = rand_mat(&mut rng, n_q, d);
        let k = rand_mat(&mut rng, n_k, d);
        let v = rand_mat(&mut rng, n_k, d_v);
        let kv = PackedKv::new(&k, &v);
        let mut paged = SessionKv::new(d, d_v, 13);
        paged.append(&k, &v);
        let cfg = HadAttnConfig { n_top: 10, temp: 1.0 };
        let want = had_attention_scalar(&q, &kv, &cfg);
        let pool = ThreadPool::new(3);
        for be in KernelBackend::available() {
            assert_eq!(
                want,
                had_attention_pooled_backend(&q, &kv, &cfg, &pool, be),
                "pooled backend={}",
                be.name()
            );
            assert_eq!(
                want,
                had_attention_paged_pooled_backend(&q, &paged, &cfg, &pool, be),
                "paged pooled backend={}",
                be.name()
            );
        }
    }

    #[test]
    #[should_panic(expected = "attention over an empty KV cache")]
    fn pooled_empty_kv_panics_with_unified_message() {
        let pool = ThreadPool::new(1);
        let kv = SessionKv::new(8, 4, 4);
        let q = Mat::zeros(1, 8);
        had_attention_paged_pooled(&q, &kv, &HadAttnConfig::default(), &pool);
    }
}
