//! Runtime-dispatched popcount backends for the Hamming inner loop.
//!
//! The blocked kernel's entire arithmetic is `popcount(q ^ k)` over u64
//! words; everything else (tiling, page-major traversal, streaming
//! top-N) is backend-independent. This module owns that inner seam as a
//! [`KernelBackend`]: the block scorer every engine path calls is
//! dispatched once per key block to one of
//!
//! - **scalar** — the original `u64::count_ones` loop (`hamming_w`),
//!   retained as the bit-exactness oracle every other backend is
//!   property-tested against,
//! - **swar**   — a portable branch-free SWAR popcount (the classic
//!   bit-sliced reduction + multiply-gather), identical codegen on every
//!   architecture regardless of `-C target-cpu`,
//! - **avx2**   — 4 query lanes per 256-bit vector (one lane per query
//!   of the 4-query tile): broadcast each key word, XOR against the
//!   transposed query block, popcount via the `vpshufb` nibble-LUT +
//!   `vpsadbw` reduction,
//! - **avx512** — the same 4-lane shape with the LUT replaced by native
//!   `VPOPCNTQ` (`_mm256_popcnt_epi64`, AVX-512VL + VPOPCNTDQ),
//! - **neon**   — two 128-bit vectors cover the tile (2 query lanes
//!   each); `CNT` counts bits per byte and a pairwise-widening chain
//!   (`vpaddlq_u8/u16/u32`) folds bytes into per-lane u64 sums.
//!
//! All backends compute *exact* Hamming distances, so scores — and
//! therefore selection, softmax, and outputs — are bit-identical across
//! backends by construction; `rust/tests/properties.rs` asserts it.
//!
//! Selection is runtime CPU-feature detection ([`KernelBackend::auto`])
//! with an env override: `HAD_KERNEL=scalar|swar|avx2|avx512|neon|auto`
//! (read once, cached). Every attention path — `had_attention{,_paged}`,
//! the pooled variants, `serve::HadBackend::decode`, and the generation
//! tick loop — dispatches through [`KernelBackend::active`], and the
//! chosen backend + detected features surface in coordinator `Metrics`
//! snapshots and the bench JSONL records.

use crate::binary::kernel::{StreamTopN, QUERY_BLOCK};
use std::sync::OnceLock;

/// One implementation of the Hamming block scorer. Variants exist on
/// every architecture (so names parse uniformly); availability is a
/// runtime property of the host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelBackend {
    /// `u64::count_ones` loop — the oracle.
    Scalar,
    /// Portable branch-free SWAR popcount.
    Swar,
    /// x86-64 AVX2: nibble-LUT popcount, 4 query lanes per vector.
    Avx2,
    /// x86-64 AVX-512VL + VPOPCNTDQ: native 64-bit lane popcount.
    Avx512,
    /// aarch64 NEON: per-byte CNT + pairwise widening.
    Neon,
}

impl KernelBackend {
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Swar => "swar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Avx512 => "avx512",
            KernelBackend::Neon => "neon",
        }
    }

    /// Parse a backend name (`auto` is not a backend — see [`select`]).
    pub fn parse(name: &str) -> Option<KernelBackend> {
        match name {
            "scalar" => Some(KernelBackend::Scalar),
            "swar" => Some(KernelBackend::Swar),
            "avx2" => Some(KernelBackend::Avx2),
            "avx512" => Some(KernelBackend::Avx512),
            "neon" => Some(KernelBackend::Neon),
            _ => None,
        }
    }

    /// Can this backend run on the current host (arch + CPU features)?
    pub fn is_available(self) -> bool {
        match self {
            KernelBackend::Scalar | KernelBackend::Swar => true,
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx2 => is_x86_feature_detected!("avx2"),
            // the avx512 scorers' target_feature contract is
            // avx2+avx512vl+avx512vpopcntdq — detect all three (a
            // masked-feature VM could report VL without AVX2)
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx512 => {
                is_x86_feature_detected!("avx2")
                    && is_x86_feature_detected!("avx512vl")
                    && is_x86_feature_detected!("avx512vpopcntdq")
            }
            #[cfg(target_arch = "aarch64")]
            KernelBackend::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Every backend the host can run, oracle first (stable order: the
    /// bench sweep and property matrix iterate this).
    pub fn available() -> Vec<KernelBackend> {
        [
            KernelBackend::Scalar,
            KernelBackend::Swar,
            KernelBackend::Avx2,
            KernelBackend::Avx512,
            KernelBackend::Neon,
        ]
        .into_iter()
        .filter(|b| b.is_available())
        .collect()
    }

    /// Best available backend by static preference: widest exact
    /// popcount first (avx512 > avx2 > neon > swar). `HAD_KERNEL`
    /// overrides when a measurement disagrees with the static order.
    pub fn auto() -> KernelBackend {
        [KernelBackend::Avx512, KernelBackend::Avx2, KernelBackend::Neon, KernelBackend::Swar]
            .into_iter()
            .find(|b| b.is_available())
            .unwrap_or(KernelBackend::Scalar)
    }

    /// The backend every default attention path dispatches through:
    /// `HAD_KERNEL` if set (panicking loudly on unknown or unavailable
    /// names — a misconfigured fleet should fail at startup, not
    /// silently run scalar), else [`KernelBackend::auto`]. Read once.
    pub fn active() -> KernelBackend {
        static ACTIVE: OnceLock<KernelBackend> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            let spec = std::env::var("HAD_KERNEL").unwrap_or_else(|_| "auto".to_string());
            match select(&spec) {
                Ok(be) => be,
                Err(e) => panic!("HAD_KERNEL: {e}"),
            }
        })
    }
}

/// Resolve a `HAD_KERNEL` value: `auto`/empty picks the best available
/// backend; a concrete name must be known *and* available on this host.
pub fn select(spec: &str) -> Result<KernelBackend, String> {
    let spec = spec.trim().to_ascii_lowercase();
    if spec.is_empty() || spec == "auto" {
        return Ok(KernelBackend::auto());
    }
    let be = KernelBackend::parse(&spec).ok_or_else(|| {
        format!("unknown kernel backend {spec:?} (expected scalar|swar|avx2|avx512|neon|auto)")
    })?;
    if !be.is_available() {
        return Err(format!(
            "backend {:?} is not available on this host (available: {})",
            be.name(),
            available_names()
        ));
    }
    Ok(be)
}

/// Space-joined names of every host-available backend.
pub fn available_names() -> String {
    KernelBackend::available()
        .iter()
        .map(|b| b.name())
        .collect::<Vec<_>>()
        .join(" ")
}

/// Detected CPU features relevant to the kernel, e.g.
/// `"x86_64: popcnt avx2"` — recorded in bench JSONL and `Metrics`.
pub fn cpu_features() -> String {
    #[allow(unused_mut)]
    let mut feats: Vec<&str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("popcnt") {
            feats.push("popcnt");
        }
        if is_x86_feature_detected!("avx2") {
            feats.push("avx2");
        }
        if is_x86_feature_detected!("avx512f") {
            feats.push("avx512f");
        }
        if is_x86_feature_detected!("avx512vl") {
            feats.push("avx512vl");
        }
        if is_x86_feature_detected!("avx512vpopcntdq") {
            feats.push("avx512vpopcntdq");
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            feats.push("neon");
        }
    }
    let list = if feats.is_empty() { "baseline".to_string() } else { feats.join(" ") };
    format!("{}: {}", std::env::consts::ARCH, list)
}

/// Portable branch-free 64-bit popcount (SWAR reduction + multiply
/// gather). Exact for every input; no per-field borrow/carry, so the
/// debug-build arithmetic never overflows.
#[inline(always)]
pub fn popcount_swar(x: u64) -> u32 {
    let x = x - ((x >> 1) & 0x5555_5555_5555_5555);
    let x = (x & 0x3333_3333_3333_3333) + ((x >> 2) & 0x3333_3333_3333_3333);
    let x = (x + (x >> 4)) & 0x0f0f_0f0f_0f0f_0f0f;
    (x.wrapping_mul(0x0101_0101_0101_0101) >> 56) as u32
}

/// Transpose a resident query block for lane-parallel backends:
/// `out[w][t]` = word `w` of query `t`. Lanes past the block's real
/// query count stay 0 — their (garbage) scores are never pushed.
/// Computed once per query tile (kernel::stream_scores_w), NOT per key
/// block, so paged traversal pays no per-page setup.
#[inline(always)]
pub(crate) fn transpose<const W: usize>(qw: &[[u64; W]]) -> [[u64; QUERY_BLOCK]; W] {
    let mut qt = [[0u64; QUERY_BLOCK]; W];
    for (t, q) in qw.iter().enumerate() {
        for (w, &x) in q.iter().enumerate() {
            qt[w][t] = x;
        }
    }
    qt
}

// ---------------------------------------------------------------------------
// Block scorers: one key block against a resident <=4-query tile, each
// score fed straight into its query's streaming top-N. The monomorphized
// `_w` seam serves d <= 256 (W in 1..=4, fully unrolled); `_dyn` serves
// wide heads with runtime word counts and pre-transposed queries.
// ---------------------------------------------------------------------------

/// Monomorphized dispatch: `keys` holds `n_rows * W` words, `qw`/`tops`
/// are the tile's resident queries and their selection state, `qt` the
/// tile's pre-transposed words (built once per tile by the caller, so
/// lane-parallel backends do no per-key-block setup).
#[allow(clippy::too_many_arguments)]
pub(crate) fn score_block_w<const W: usize>(
    be: KernelBackend,
    d: i32,
    qw: &[[u64; W]],
    qt: &[[u64; QUERY_BLOCK]; W],
    n_rows: usize,
    keys: &[u64],
    base: usize,
    tops: &mut [StreamTopN],
) {
    debug_assert!(keys.len() >= n_rows * W);
    debug_assert_eq!(qw.len(), tops.len());
    debug_assert!(qw.len() <= QUERY_BLOCK);
    match be {
        KernelBackend::Scalar => score_block_scalar_w::<W>(d, qw, n_rows, keys, base, tops),
        KernelBackend::Swar => score_block_swar_w::<W>(d, qw, n_rows, keys, base, tops),
        // SAFETY (all arms): `active()`/`available()` admit a SIMD
        // backend only after runtime feature detection on this host.
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => unsafe {
            x86::score_block_avx2_w::<W>(d, qt, qw.len(), n_rows, keys, base, tops)
        },
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx512 => unsafe {
            x86::score_block_avx512_w::<W>(d, qt, qw.len(), n_rows, keys, base, tops)
        },
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => unsafe {
            arm::score_block_neon_w::<W>(d, qt, qw.len(), n_rows, keys, base, tops)
        },
        other => unreachable!(
            "backend {} is not compiled for {}",
            other.name(),
            std::env::consts::ARCH
        ),
    }
}

/// Dynamic-width dispatch (wide heads, d > 256): `qt` is the transposed
/// query block (`qt[w][t]` = word `w` of query `t`, one entry per word),
/// `qb` the real query count of the tile.
#[allow(clippy::too_many_arguments)]
pub(crate) fn score_block_dyn(
    be: KernelBackend,
    d: i32,
    qt: &[[u64; QUERY_BLOCK]],
    qb: usize,
    n_rows: usize,
    keys: &[u64],
    base: usize,
    tops: &mut [StreamTopN],
) {
    debug_assert!(keys.len() >= n_rows * qt.len());
    debug_assert!(qb <= QUERY_BLOCK && qb <= tops.len());
    match be {
        KernelBackend::Scalar => score_block_scalar_dyn(d, qt, qb, n_rows, keys, base, tops),
        KernelBackend::Swar => score_block_swar_dyn(d, qt, qb, n_rows, keys, base, tops),
        // SAFETY (all arms): backend admitted only after feature
        // detection — see `score_block_w`.
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => unsafe {
            x86::score_block_avx2_dyn(d, qt, qb, n_rows, keys, base, tops)
        },
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx512 => unsafe {
            x86::score_block_avx512_dyn(d, qt, qb, n_rows, keys, base, tops)
        },
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => unsafe {
            arm::score_block_neon_dyn(d, qt, qb, n_rows, keys, base, tops)
        },
        other => unreachable!(
            "backend {} is not compiled for {}",
            other.name(),
            std::env::consts::ARCH
        ),
    }
}

/// The original inner loop (moved here from `kernel::score_block_w`):
/// every key row loaded once, scored against all resident queries via
/// the fully-unrolled `hamming_w` XOR/POPCNT chain.
fn score_block_scalar_w<const W: usize>(
    d: i32,
    qw: &[[u64; W]],
    n_rows: usize,
    keys: &[u64],
    base: usize,
    tops: &mut [StreamTopN],
) {
    use crate::binary::hamming::hamming_w;
    for j in 0..n_rows {
        let kj = &keys[j * W..j * W + W];
        for (qi, top) in qw.iter().zip(tops.iter_mut()) {
            top.push(d - 2 * hamming_w::<W>(qi, kj) as i32, base + j);
        }
    }
}

/// Same tile walk with the portable SWAR popcount in the chain.
fn score_block_swar_w<const W: usize>(
    d: i32,
    qw: &[[u64; W]],
    n_rows: usize,
    keys: &[u64],
    base: usize,
    tops: &mut [StreamTopN],
) {
    for j in 0..n_rows {
        let kj = &keys[j * W..j * W + W];
        for (qi, top) in qw.iter().zip(tops.iter_mut()) {
            let mut ham = 0u32;
            for t in 0..W {
                ham += popcount_swar(qi[t] ^ kj[t]);
            }
            top.push(d - 2 * ham as i32, base + j);
        }
    }
}

fn score_block_scalar_dyn(
    d: i32,
    qt: &[[u64; QUERY_BLOCK]],
    qb: usize,
    n_rows: usize,
    keys: &[u64],
    base: usize,
    tops: &mut [StreamTopN],
) {
    let w = qt.len();
    for j in 0..n_rows {
        let kj = &keys[j * w..(j + 1) * w];
        for (t, top) in tops.iter_mut().enumerate().take(qb) {
            let mut ham = 0u32;
            for (x, qs) in kj.iter().zip(qt) {
                ham += (x ^ qs[t]).count_ones();
            }
            top.push(d - 2 * ham as i32, base + j);
        }
    }
}

fn score_block_swar_dyn(
    d: i32,
    qt: &[[u64; QUERY_BLOCK]],
    qb: usize,
    n_rows: usize,
    keys: &[u64],
    base: usize,
    tops: &mut [StreamTopN],
) {
    let w = qt.len();
    for j in 0..n_rows {
        let kj = &keys[j * w..(j + 1) * w];
        for (t, top) in tops.iter_mut().enumerate().take(qb) {
            let mut ham = 0u32;
            for (x, qs) in kj.iter().zip(qt) {
                ham += popcount_swar(x ^ qs[t]);
            }
            top.push(d - 2 * ham as i32, base + j);
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{StreamTopN, QUERY_BLOCK};
    use core::arch::x86_64::*;

    /// Per-64-bit-lane popcount without VPOPCNTQ: nibble lookup via
    /// `vpshufb`, then `vpsadbw` folds the 8 byte-counts of each lane
    /// into its low 16 bits.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64_lut(x: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, // low 128
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, // high 128
        );
        let low_mask = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(x, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(x), low_mask);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    /// Push one key row's 4 lane-Hamming sums into the tile's top-Ns.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn push_lanes(d: i32, acc: __m256i, qb: usize, idx: usize, tops: &mut [StreamTopN]) {
        let mut lanes = [0u64; QUERY_BLOCK];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        for (t, top) in tops.iter_mut().enumerate().take(qb) {
            top.push(d - 2 * (lanes[t] as i32), idx);
        }
    }

    /// One tile-scorer pair (monomorphized + dyn) per popcount op: the
    /// AVX2 and AVX-512 backends share every line of the tile walk —
    /// only the per-lane popcount differs — so both bodies expand from
    /// this macro and cannot drift apart.
    macro_rules! avx_tile_scorers {
        ($w_name:ident, $dyn_name:ident, $feat:literal, $popcnt:path) => {
            /// Tile scorer: one lane per query of the 4-query tile;
            /// each key word is broadcast once and XORed against the
            /// pre-transposed query block held in registers.
            #[target_feature(enable = $feat)]
            pub(super) unsafe fn $w_name<const W: usize>(
                d: i32,
                qt: &[[u64; QUERY_BLOCK]; W],
                qb: usize,
                n_rows: usize,
                keys: &[u64],
                base: usize,
                tops: &mut [StreamTopN],
            ) {
                let mut qv = [_mm256_setzero_si256(); W];
                for (v, q) in qv.iter_mut().zip(qt) {
                    *v = _mm256_loadu_si256(q.as_ptr() as *const __m256i);
                }
                for j in 0..n_rows {
                    let row = &keys[j * W..j * W + W];
                    let mut acc = _mm256_setzero_si256();
                    for (&kw, &qvw) in row.iter().zip(&qv) {
                        let x = _mm256_xor_si256(_mm256_set1_epi64x(kw as i64), qvw);
                        acc = _mm256_add_epi64(acc, $popcnt(x));
                    }
                    push_lanes(d, acc, qb, base + j, tops);
                }
            }

            /// Dynamic-width variant: query vectors re-loaded per word
            /// from the caller's transposed block.
            #[target_feature(enable = $feat)]
            pub(super) unsafe fn $dyn_name(
                d: i32,
                qt: &[[u64; QUERY_BLOCK]],
                qb: usize,
                n_rows: usize,
                keys: &[u64],
                base: usize,
                tops: &mut [StreamTopN],
            ) {
                let w = qt.len();
                for j in 0..n_rows {
                    let row = &keys[j * w..(j + 1) * w];
                    let mut acc = _mm256_setzero_si256();
                    for (&kw, qs) in row.iter().zip(qt) {
                        let qv = _mm256_loadu_si256(qs.as_ptr() as *const __m256i);
                        let x = _mm256_xor_si256(_mm256_set1_epi64x(kw as i64), qv);
                        acc = _mm256_add_epi64(acc, $popcnt(x));
                    }
                    push_lanes(d, acc, qb, base + j, tops);
                }
            }
        };
    }

    avx_tile_scorers!(score_block_avx2_w, score_block_avx2_dyn, "avx2", popcnt_epi64_lut);
    // AVX-512 variant: native VPOPCNTQ per lane (256-bit form — the
    // 4-query tile fills exactly 4 lanes, so the VL encoding is the
    // right width, not a downgrade).
    avx_tile_scorers!(
        score_block_avx512_w,
        score_block_avx512_dyn,
        "avx2,avx512vl,avx512vpopcntdq",
        _mm256_popcnt_epi64
    );
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{StreamTopN, QUERY_BLOCK};
    use core::arch::aarch64::*;

    /// Fold a per-byte count accumulator into per-u64-lane sums.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn widen(acc: uint8x16_t) -> uint64x2_t {
        vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(acc)))
    }

    /// NEON tile scorer: lanes (q0,q1) and (q2,q3) in two 128-bit
    /// vectors over the pre-transposed query block; `CNT` counts bits
    /// per byte, accumulated in u8 (W <= 31 keeps every byte <= 248)
    /// and widened once per key row.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn score_block_neon_w<const W: usize>(
        d: i32,
        qt: &[[u64; QUERY_BLOCK]; W],
        qb: usize,
        n_rows: usize,
        keys: &[u64],
        base: usize,
        tops: &mut [StreamTopN],
    ) {
        debug_assert!(W <= 31, "u8 byte-count accumulator would overflow");
        for j in 0..n_rows {
            let row = &keys[j * W..j * W + W];
            let mut a01 = vdupq_n_u8(0);
            let mut a23 = vdupq_n_u8(0);
            for (qs, &kw) in qt.iter().zip(row) {
                let kx = vdupq_n_u64(kw);
                let q01 = vld1q_u64(qs.as_ptr());
                let q23 = vld1q_u64(qs.as_ptr().add(2));
                a01 = vaddq_u8(a01, vcntq_u8(vreinterpretq_u8_u64(veorq_u64(kx, q01))));
                a23 = vaddq_u8(a23, vcntq_u8(vreinterpretq_u8_u64(veorq_u64(kx, q23))));
            }
            let h01 = widen(a01);
            let h23 = widen(a23);
            let hams = [
                vgetq_lane_u64::<0>(h01),
                vgetq_lane_u64::<1>(h01),
                vgetq_lane_u64::<0>(h23),
                vgetq_lane_u64::<1>(h23),
            ];
            for (t, top) in tops.iter_mut().enumerate().take(qb) {
                top.push(d - 2 * (hams[t] as i32), base + j);
            }
        }
    }

    /// Dynamic width: byte accumulators flushed into u64 lanes every
    /// 31 words so arbitrarily wide heads cannot overflow u8.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn score_block_neon_dyn(
        d: i32,
        qt: &[[u64; QUERY_BLOCK]],
        qb: usize,
        n_rows: usize,
        keys: &[u64],
        base: usize,
        tops: &mut [StreamTopN],
    ) {
        let w = qt.len();
        for j in 0..n_rows {
            let row = &keys[j * w..(j + 1) * w];
            let mut h01 = vdupq_n_u64(0);
            let mut h23 = vdupq_n_u64(0);
            let mut w0 = 0usize;
            while w0 < w {
                let chunk = (w - w0).min(31);
                let mut a01 = vdupq_n_u8(0);
                let mut a23 = vdupq_n_u8(0);
                for (qs, &kw) in qt[w0..w0 + chunk].iter().zip(&row[w0..w0 + chunk]) {
                    let kx = vdupq_n_u64(kw);
                    let q01 = vld1q_u64(qs.as_ptr());
                    let q23 = vld1q_u64(qs.as_ptr().add(2));
                    a01 = vaddq_u8(a01, vcntq_u8(vreinterpretq_u8_u64(veorq_u64(kx, q01))));
                    a23 = vaddq_u8(a23, vcntq_u8(vreinterpretq_u8_u64(veorq_u64(kx, q23))));
                }
                h01 = vaddq_u64(h01, widen(a01));
                h23 = vaddq_u64(h23, widen(a23));
                w0 += chunk;
            }
            let hams = [
                vgetq_lane_u64::<0>(h01),
                vgetq_lane_u64::<1>(h01),
                vgetq_lane_u64::<0>(h23),
                vgetq_lane_u64::<1>(h23),
            ];
            for (t, top) in tops.iter_mut().enumerate().take(qb) {
                top.push(d - 2 * (hams[t] as i32), base + j);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binary::bitpack::{words_for, PackedMat};
    use crate::binary::hamming::hamming;
    use crate::util::rng::Rng;

    #[test]
    fn swar_popcount_matches_count_ones() {
        for x in [0u64, 1, !0, 0x8000_0000_0000_0000, 0x5555_5555_5555_5555, 0xdead_beef_cafe_f00d]
        {
            assert_eq!(popcount_swar(x), x.count_ones(), "x={x:#x}");
        }
        let mut rng = Rng::new(77);
        for _ in 0..2000 {
            let x = rng.next_u64();
            assert_eq!(popcount_swar(x), x.count_ones(), "x={x:#x}");
        }
    }

    #[test]
    fn parse_and_select() {
        assert_eq!(KernelBackend::parse("scalar"), Some(KernelBackend::Scalar));
        assert_eq!(KernelBackend::parse("avx512"), Some(KernelBackend::Avx512));
        assert_eq!(KernelBackend::parse("auto"), None, "auto resolves via select");
        assert_eq!(select("auto").unwrap(), KernelBackend::auto());
        assert_eq!(select("  SWAR ").unwrap(), KernelBackend::Swar);
        assert!(select("popcnt9000").unwrap_err().contains("unknown kernel backend"));
    }

    #[test]
    fn portable_backends_always_available_and_auto_resolves() {
        let avail = KernelBackend::available();
        assert!(avail.contains(&KernelBackend::Scalar));
        assert!(avail.contains(&KernelBackend::Swar));
        assert!(avail.contains(&KernelBackend::auto()));
        assert!(avail.contains(&KernelBackend::active()));
        assert!(!available_names().is_empty());
        assert!(cpu_features().contains(std::env::consts::ARCH));
    }

    /// Drive one backend's dyn block scorer over a full score stream and
    /// return each query's kept set.
    fn run_dyn(
        be: KernelBackend,
        d: usize,
        qp: &PackedMat,
        kp: &PackedMat,
        qb: usize,
        n_top: usize,
    ) -> Vec<Vec<(i32, usize)>> {
        let w = qp.words_per_row;
        let mut qt = vec![[0u64; QUERY_BLOCK]; w];
        for t in 0..qb {
            for (ww, &x) in qp.row(t).iter().enumerate() {
                qt[ww][t] = x;
            }
        }
        let mut tops: Vec<StreamTopN> = Vec::new();
        tops.resize_with(QUERY_BLOCK, StreamTopN::default);
        for top in tops.iter_mut().take(qb) {
            top.reset(n_top, d);
        }
        score_block_dyn(be, d as i32, &qt, qb, kp.rows, &kp.data, 0, &mut tops);
        tops.iter_mut().take(qb).map(|t| t.finish().to_vec()).collect()
    }

    #[test]
    fn every_backend_matches_scalar_on_the_dyn_seam() {
        // ragged dims crossing word boundaries, partial tiles, and wide
        // heads (w in 1..=6); scalar is the oracle
        let mut rng = Rng::new(3);
        for d in [1usize, 63, 64, 65, 128, 200, 257, 384] {
            for qb in 1..=QUERY_BLOCK {
                let n_k = 1 + rng.range_usize(0, 40);
                let n_top = 1 + rng.range_usize(0, n_k);
                let q = rng.normal_vec(qb * d, 1.0);
                let k = rng.normal_vec(n_k * d, 1.0);
                let qp = PackedMat::pack(qb, d, &q);
                let kp = PackedMat::pack(n_k, d, &k);
                assert_eq!(qp.words_per_row, words_for(d));
                let want = run_dyn(KernelBackend::Scalar, d, &qp, &kp, qb, n_top);
                for be in KernelBackend::available() {
                    assert_eq!(
                        run_dyn(be, d, &qp, &kp, qb, n_top),
                        want,
                        "backend={} d={d} qb={qb} n_k={n_k} N={n_top}",
                        be.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dyn_seam_scores_equal_raw_hamming_identity() {
        // with n_top == n_k every score survives; check each against the
        // packed-row hamming oracle directly
        let mut rng = Rng::new(9);
        let (d, qb, n_k) = (96usize, 3usize, 17usize);
        let q = rng.normal_vec(qb * d, 1.0);
        let k = rng.normal_vec(n_k * d, 1.0);
        let qp = PackedMat::pack(qb, d, &q);
        let kp = PackedMat::pack(n_k, d, &k);
        for be in KernelBackend::available() {
            let kept = run_dyn(be, d, &qp, &kp, qb, n_k);
            for (t, row) in kept.iter().enumerate() {
                assert_eq!(row.len(), n_k);
                for &(s, j) in row {
                    let want = d as i32 - 2 * hamming(qp.row(t), kp.row(j)) as i32;
                    assert_eq!(s, want, "backend={} t={t} j={j}", be.name());
                }
            }
        }
    }
}
