//! Sign-bit packing: f32 vectors -> u64 words (the paper's binary K/Q at
//! rest; 32x smaller than f32).
//!
//! Convention (shared with python/compile/kernels/bitops.py and the
//! oracles): bit = 1 iff x >= 0, i.e. sign(0) = +1. Padding bits beyond
//! the true dimension are 1 in every pattern so they XOR to zero and never
//! contribute to Hamming distances.

/// Number of u64 words needed to hold `d` sign bits.
#[inline]
pub fn words_for(d: usize) -> usize {
    d.div_ceil(64)
}

/// Pack one f32 vector into u64 words (little-endian bit order within a
/// word: bit i of word w = sign of element 64*w + i).
pub fn pack_vector(x: &[f32], out: &mut [u64]) {
    let w = words_for(x.len());
    assert!(out.len() >= w, "output too small");
    for word in out[..w].iter_mut() {
        *word = 0;
    }
    for (i, &v) in x.iter().enumerate() {
        if v >= 0.0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
    // pad bits = 1 (sign(+1)) so equal padding never adds Hamming distance
    let used = x.len() % 64;
    if used != 0 {
        out[w - 1] |= !0u64 << used;
    }
    for word in out[w..].iter_mut() {
        *word = !0u64;
    }
}

/// A matrix of packed sign patterns: `rows` patterns of `d` bits each.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMat {
    pub rows: usize,
    pub d: usize,
    pub words_per_row: usize,
    pub data: Vec<u64>,
}

impl Default for PackedMat {
    /// Empty matrix; useful as a reusable pack buffer (see `pack_into`).
    fn default() -> Self {
        PackedMat { rows: 0, d: 0, words_per_row: 0, data: Vec::new() }
    }
}

impl PackedMat {
    /// Pack a row-major f32 matrix (rows x d).
    pub fn pack(rows: usize, d: usize, data: &[f32]) -> PackedMat {
        let mut out = PackedMat::default();
        out.pack_into(rows, d, data);
        out
    }

    /// Re-pack in place, reusing this matrix's allocation (the hot-path
    /// variant: per-call query packing in attention allocates nothing
    /// once the scratch buffer has warmed up).
    pub fn pack_into(&mut self, rows: usize, d: usize, data: &[f32]) {
        assert_eq!(data.len(), rows * d);
        let wpr = words_for(d);
        self.rows = rows;
        self.d = d;
        self.words_per_row = wpr;
        self.data.clear();
        self.data.resize(rows * wpr, 0);
        for r in 0..rows {
            pack_vector(&data[r * d..(r + 1) * d], &mut self.data[r * wpr..(r + 1) * wpr]);
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u64] {
        &self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Contiguous packed words of rows `lo..hi` — the tile-friendly block
    /// view the blocked kernel streams (`(hi - lo) * words_per_row`
    /// words).
    #[inline]
    pub fn block(&self, lo: usize, hi: usize) -> &[u64] {
        &self.data[lo * self.words_per_row..hi * self.words_per_row]
    }

    /// Bytes of the packed representation (the 32x story vs f32).
    pub fn bytes(&self) -> usize {
        self.data.len() * 8
    }

    /// Unpack to ±1.0 f32 (test helper / oracle input).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.d);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..self.d {
                let bit = (row[i / 64] >> (i % 64)) & 1;
                out.push(if bit == 1 { 1.0 } else { -1.0 });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn words_for_boundaries() {
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        assert_eq!(words_for(128), 2);
    }

    #[test]
    fn pack_unpack_roundtrip_signs() {
        let mut rng = Rng::new(1);
        for d in [3, 16, 64, 65, 100, 128] {
            let x = rng.normal_vec(4 * d, 1.0);
            let packed = PackedMat::pack(4, d, &x);
            let signs = packed.unpack();
            for (a, b) in x.iter().zip(&signs) {
                let want = if *a >= 0.0 { 1.0 } else { -1.0 };
                assert_eq!(*b, want);
            }
        }
    }

    #[test]
    fn zero_packs_as_positive() {
        let x = vec![0.0f32, -0.0, 1.0, -1.0];
        let p = PackedMat::pack(1, 4, &x);
        // -0.0 >= 0.0 is true in IEEE: sign(-0.0) = +1 like the jnp oracle
        assert_eq!(p.unpack(), vec![1.0, 1.0, 1.0, -1.0]);
    }

    #[test]
    fn padding_bits_are_ones() {
        let x = vec![-1.0f32; 10];
        let p = PackedMat::pack(1, 10, &x);
        let w = p.row(0)[0];
        assert_eq!(w & 0x3FF, 0, "data bits all negative");
        assert_eq!(w >> 10, !0u64 >> 10, "pad bits all ones");
    }

    #[test]
    fn pack_into_reuses_buffer_and_matches_pack() {
        let mut rng = Rng::new(9);
        let mut buf = PackedMat::default();
        for (rows, d) in [(4usize, 100usize), (2, 64), (7, 33)] {
            let x = rng.normal_vec(rows * d, 1.0);
            buf.pack_into(rows, d, &x);
            assert_eq!(buf, PackedMat::pack(rows, d, &x), "rows={rows} d={d}");
        }
        // shrinking re-pack keeps capacity but not stale contents
        let x = rng.normal_vec(3, 1.0);
        buf.pack_into(1, 3, &x);
        assert_eq!(buf.data.len(), 1);
    }

    #[test]
    fn bytes_32x_smaller_than_f32() {
        let x = vec![1.0f32; 256 * 64];
        let p = PackedMat::pack(256, 64, &x);
        assert_eq!(p.bytes() * 32, 256 * 64 * 4);
    }
}
