//! Fused CPU HAD attention: the paper's full pipeline (Eqs. 4-8) on
//! bit-packed operands — binarize/pack, XNOR-popcount scores, top-N
//! selection, sparse softmax, sparse AV accumulation.
//!
//! Since the kernel rewrite, `had_attention{,_paged}` run on the tiled
//! `binary::kernel` engine (4-query register blocking, page-major key
//! streaming, fused streaming top-N — see that module's docs), whose
//! popcount inner step dispatches through the runtime-selected
//! `binary::simd::KernelBackend` (`HAD_KERNEL` override). The
//! original one-pair-at-a-time implementations are kept here as
//! `had_attention_scalar{,_paged_scalar}`: they are the bit-exactness
//! oracle the kernel is property-tested against, and the baseline the
//! attention_kernels bench measures the blocked engine over.
//!
//! This is the Rust-side production fast path used by the serving
//! coordinator when a request asks for the `cpu-bitpacked` backend.
//! Cross-checked against tensor::ops oracles in unit tests and against
//! the PJRT artifacts in integration tests.

use crate::binary::bitpack::PackedMat;
use crate::binary::hamming;
use crate::binary::kernel::{self, StreamTopN};
use crate::binary::topn::select_topn_counting;
use crate::kvcache::SessionKv;
use crate::tensor::{ops, Mat};

/// Shared empty-cache contract: every attention entry point (contiguous,
/// paged, scalar, blocked, pooled) rejects an empty KV with this exact
/// message instead of panicking obscurely mid-loop.
pub(crate) const EMPTY_KV_MSG: &str = "attention over an empty KV cache";

/// Configuration of one attention head computation.
#[derive(Clone, Copy, Debug)]
pub struct HadAttnConfig {
    pub n_top: usize,
    /// softmax temperature multiplier (sigma_q * sigma_k of the calibrated
    /// model); the 1/sqrt(d) factor is applied automatically.
    pub temp: f32,
}

impl Default for HadAttnConfig {
    fn default() -> Self {
        HadAttnConfig { n_top: 30, temp: 1.0 }
    }
}

/// Pre-packed key/value cache for one head: keys as sign bits, values in
/// f32. In a serving deployment this is built once per sequence and reused
/// across queries (the packed-K residency story — 32x smaller than f32 K).
#[derive(Clone, Debug)]
pub struct PackedKv {
    pub keys: PackedMat,
    pub values: Mat, // (n_k, d_v)
}

impl PackedKv {
    pub fn new(k: &Mat, v: &Mat) -> PackedKv {
        PackedKv::from_parts(k, v.clone())
    }

    /// Like `new` but takes ownership of V — callers that own their value
    /// matrix (cache builders, benches) skip the clone.
    pub fn from_parts(k: &Mat, v: Mat) -> PackedKv {
        assert_eq!(k.rows, v.rows, "K/V length mismatch");
        PackedKv { keys: PackedMat::pack(k.rows, k.cols, &k.data), values: v }
    }
}

/// Scratch buffers reused across calls (allocation-free hot loop — §Perf):
/// the packed-query buffer and softmax probabilities serve every path;
/// `scores` is the full integer row only the scalar oracle materializes;
/// `tops` is the kernel's per-query-block streaming top-N state.
#[derive(Default)]
pub struct Scratch {
    pub(crate) scores: Vec<i32>,
    pub(crate) probs: Vec<f32>,
    pub(crate) qp: PackedMat,
    pub(crate) tops: Vec<StreamTopN>,
}

/// Full HAD attention for a block of queries against one PackedKv, on the
/// tiled kernel engine. q: (n_q, d) continuous queries (binarized
/// inside). Returns (n_q, d_v). Bit-identical to `had_attention_scalar`.
pub fn had_attention(q: &Mat, kv: &PackedKv, cfg: &HadAttnConfig) -> Mat {
    let mut scratch = Scratch::default();
    had_attention_with(q, kv, cfg, &mut scratch)
}

pub fn had_attention_with(
    q: &Mat,
    kv: &PackedKv,
    cfg: &HadAttnConfig,
    scratch: &mut Scratch,
) -> Mat {
    kernel::run_serial(q, &kernel::ContiguousSrc::new(kv), cfg, scratch)
}

/// Full HAD attention for a block of queries against a paged session
/// cache, scoring XNOR-popcount directly over the non-contiguous pages
/// without gathering them (page-major: each resident page is streamed
/// once per 4-query block). Bit-identical to `had_attention` on the same
/// keys and to `had_attention_paged_scalar`.
pub fn had_attention_paged(q: &Mat, kv: &SessionKv, cfg: &HadAttnConfig) -> Mat {
    let mut scratch = Scratch::default();
    had_attention_paged_with(q, kv, cfg, &mut scratch)
}

pub fn had_attention_paged_with(
    q: &Mat,
    kv: &SessionKv,
    cfg: &HadAttnConfig,
    scratch: &mut Scratch,
) -> Mat {
    kernel::run_serial(q, &kernel::PagedSrc::new(kv), cfg, scratch)
}

/// The original scalar fast path, kept as the kernel's bit-exactness
/// oracle: one (query, key) pair per iteration, full score-row
/// materialization, top-N as a separate counting pass.
pub fn had_attention_scalar(q: &Mat, kv: &PackedKv, cfg: &HadAttnConfig) -> Mat {
    let mut scratch = Scratch::default();
    had_attention_scalar_with(q, kv, cfg, &mut scratch)
}

pub fn had_attention_scalar_with(
    q: &Mat,
    kv: &PackedKv,
    cfg: &HadAttnConfig,
    scratch: &mut Scratch,
) -> Mat {
    let d = q.cols;
    assert_eq!(d, kv.keys.d, "query/key dim mismatch");
    let n_k = kv.keys.rows;
    assert!(n_k > 0, "{}", EMPTY_KV_MSG);
    let d_v = kv.values.cols;
    let n_top = cfg.n_top.clamp(1, n_k);
    let scale = cfg.temp / (d as f32).sqrt();

    let Scratch { scores, probs, qp, .. } = scratch;
    qp.pack_into(q.rows, d, &q.data);
    scores.resize(n_k, 0);
    probs.resize(n_top, 0.0);

    let mut out = Mat::zeros(q.rows, d_v);
    for i in 0..q.rows {
        // 1) binary scores via XNOR-popcount (Eqs. 4-5)
        let qrow = qp.row(i);
        for (j, s) in scores.iter_mut().enumerate() {
            *s = hamming::binary_dot(qrow, kv.keys.row(j), d);
        }
        // 2) top-N selection (Eq. 6)
        let kept = select_topn_counting(scores, n_top, d);
        // 3) softmax over kept logits only (Eq. 7)
        let probs = &mut probs[..kept.len()];
        let max = kept[0].0 as f32 * scale; // kept is sorted descending
        let mut sum = 0.0f32;
        for (p, &(s, _)) in probs.iter_mut().zip(&kept) {
            *p = (s as f32 * scale - max).exp();
            sum += *p;
        }
        let inv = 1.0 / sum;
        // 4) sparse AV accumulation (Eq. 8)
        let orow = out.row_mut(i);
        for (&p, &(_, j)) in probs.iter().zip(&kept) {
            let w = p * inv;
            let vrow = kv.values.row(j);
            for (o, &v) in orow.iter_mut().zip(vrow) {
                *o += w * v;
            }
        }
    }
    out
}

/// Scalar oracle over a paged session cache (same arithmetic, selection,
/// and accumulation order as `had_attention_scalar`, page-resolved keys).
pub fn had_attention_paged_scalar(q: &Mat, kv: &SessionKv, cfg: &HadAttnConfig) -> Mat {
    let mut scratch = Scratch::default();
    had_attention_paged_scalar_with(q, kv, cfg, &mut scratch)
}

pub fn had_attention_paged_scalar_with(
    q: &Mat,
    kv: &SessionKv,
    cfg: &HadAttnConfig,
    scratch: &mut Scratch,
) -> Mat {
    let d = q.cols;
    assert_eq!(d, kv.d(), "query/key dim mismatch");
    let n_k = kv.len();
    assert!(n_k > 0, "{}", EMPTY_KV_MSG);
    let d_v = kv.d_v();
    let n_top = cfg.n_top.clamp(1, n_k);
    let scale = cfg.temp / (d as f32).sqrt();

    let Scratch { scores, probs, qp, .. } = scratch;
    qp.pack_into(q.rows, d, &q.data);
    scores.resize(n_k, 0);
    probs.resize(n_top, 0.0);

    let mut out = Mat::zeros(q.rows, d_v);
    for i in 0..q.rows {
        // 1) binary scores, page by page (global key index = page base + j)
        let qrow = qp.row(i);
        let mut base = 0usize;
        for page in kv.pages() {
            let prow = &mut scores[base..base + page.len()];
            for (j, s) in prow.iter_mut().enumerate() {
                *s = hamming::binary_dot(qrow, page.key(j), d);
            }
            base += page.len();
        }
        // 2) top-N selection over the full score row
        let kept = select_topn_counting(scores, n_top, d);
        // 3) sparse softmax
        let probs = &mut probs[..kept.len()];
        let max = kept[0].0 as f32 * scale;
        let mut sum = 0.0f32;
        for (p, &(s, _)) in probs.iter_mut().zip(&kept) {
            *p = (s as f32 * scale - max).exp();
            sum += *p;
        }
        let inv = 1.0 / sum;
        // 4) sparse AV accumulation; value rows resolved through the pages
        //    (accum_value so bf16-valued sessions decode inline, exactly
        //    as the blocked kernel's PagedSrc does)
        let orow = out.row_mut(i);
        for (&p, &(_, j)) in probs.iter().zip(&kept) {
            kv.accum_value(j, p * inv, orow);
        }
    }
    out
}

/// Oracle: same computation with dense f32 ops (tensor::ops path).
pub fn had_attention_ref(q: &Mat, k: &Mat, v: &Mat, cfg: &HadAttnConfig) -> Mat {
    let sign = |m: &Mat| m.map(|x| if x >= 0.0 { 1.0 } else { -1.0 });
    let logits = sign(q).matmul_nt(&sign(k));
    let scale = cfg.temp / (q.cols as f32).sqrt();
    let probs = ops::softmax_topn_rows(&logits, cfg.n_top, scale);
    probs.matmul(v)
}

/// Dense standard attention in f32 (the baseline the paper compares
/// against; used by benches and the Figure-1 analytic model).
pub fn standard_attention_ref(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let logits = q.matmul_nt(k).map(|x| x * scale);
    let probs = ops::softmax_rows(&logits);
    probs.matmul(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::random(r, c, rng, 1.0)
    }

    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(42);
        for (n_q, n_k, d, d_v, n_top) in
            [(8, 32, 16, 8, 5), (4, 64, 64, 16, 30), (1, 100, 96, 32, 10)]
        {
            let q = rand_mat(&mut rng, n_q, d);
            let k = rand_mat(&mut rng, n_k, d);
            let v = rand_mat(&mut rng, n_k, d_v);
            let cfg = HadAttnConfig { n_top, temp: 1.0 };
            let kv = PackedKv::new(&k, &v);
            let fast = had_attention(&q, &kv, &cfg);
            let want = had_attention_ref(&q, &k, &v, &cfg);
            assert!(
                fast.max_abs_diff(&want) < 1e-5,
                "mismatch n_q={n_q} n_k={n_k} d={d}: {}",
                fast.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn kernel_matches_scalar_bit_for_bit() {
        let mut rng = Rng::new(40);
        for (n_q, n_k, d, d_v, n_top) in
            [(8, 32, 16, 8, 5), (3, 64, 64, 16, 64), (6, 100, 96, 32, 1), (1, 9, 33, 4, 4)]
        {
            let q = rand_mat(&mut rng, n_q, d);
            let k = rand_mat(&mut rng, n_k, d);
            let v = rand_mat(&mut rng, n_k, d_v);
            let cfg = HadAttnConfig { n_top, temp: 0.9 };
            let kv = PackedKv::new(&k, &v);
            assert_eq!(
                had_attention(&q, &kv, &cfg),
                had_attention_scalar(&q, &kv, &cfg),
                "n_q={n_q} n_k={n_k} d={d}"
            );
        }
    }

    #[test]
    fn temp_changes_distribution() {
        let mut rng = Rng::new(1);
        let q = rand_mat(&mut rng, 2, 32);
        let k = rand_mat(&mut rng, 16, 32);
        let v = rand_mat(&mut rng, 16, 8);
        let kv = PackedKv::new(&k, &v);
        let a = had_attention(&q, &kv, &HadAttnConfig { n_top: 8, temp: 1.0 });
        let b = had_attention(&q, &kv, &HadAttnConfig { n_top: 8, temp: 0.1 });
        assert!(a.max_abs_diff(&b) > 1e-6);
    }

    #[test]
    fn n_top_full_equals_dense_binary_attention() {
        let mut rng = Rng::new(2);
        let q = rand_mat(&mut rng, 4, 32);
        let k = rand_mat(&mut rng, 16, 32);
        let v = rand_mat(&mut rng, 16, 8);
        let kv = PackedKv::new(&k, &v);
        let cfg = HadAttnConfig { n_top: 16, temp: 1.0 };
        let got = had_attention(&q, &kv, &cfg);
        let want = had_attention_ref(&q, &k, &v, &cfg);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn output_in_value_envelope() {
        let mut rng = Rng::new(3);
        let q = rand_mat(&mut rng, 8, 32);
        let k = rand_mat(&mut rng, 32, 32);
        let v = rand_mat(&mut rng, 32, 4);
        let kv = PackedKv::new(&k, &v);
        let out = had_attention(&q, &kv, &HadAttnConfig { n_top: 5, temp: 1.0 });
        for c in 0..4 {
            let vmin = (0..32).map(|r| v.at(r, c)).fold(f32::INFINITY, f32::min);
            let vmax = (0..32).map(|r| v.at(r, c)).fold(f32::NEG_INFINITY, f32::max);
            for r in 0..8 {
                assert!(out.at(r, c) >= vmin - 1e-5 && out.at(r, c) <= vmax + 1e-5);
            }
        }
    }

    #[test]
    fn paged_matches_contiguous_bit_for_bit() {
        let mut rng = Rng::new(7);
        // page sizes that divide, straddle, and exceed n_k; ragged dims
        for (n_k, d, page_tokens) in
            [(32usize, 64usize, 8usize), (33, 65, 8), (100, 96, 7), (5, 16, 64)]
        {
            let (n_q, d_v) = (6, 8);
            let q = rand_mat(&mut rng, n_q, d);
            let k = rand_mat(&mut rng, n_k, d);
            let v = rand_mat(&mut rng, n_k, d_v);
            let cfg = HadAttnConfig { n_top: 9, temp: 1.0 };
            let kv = PackedKv::new(&k, &v);
            let mut paged = SessionKv::new(d, d_v, page_tokens);
            paged.append(&k, &v);
            let a = had_attention(&q, &kv, &cfg);
            let b = had_attention_paged(&q, &paged, &cfg);
            assert_eq!(a, b, "n_k={n_k} d={d} page={page_tokens}");
            let want = had_attention_ref(&q, &k, &v, &cfg);
            assert!(b.max_abs_diff(&want) < 1e-5);
        }
    }

    #[test]
    fn paged_incremental_append_matches_full_prefill() {
        let mut rng = Rng::new(8);
        let (n_k, d, d_v) = (50usize, 48, 16);
        let k = rand_mat(&mut rng, n_k, d);
        let v = rand_mat(&mut rng, n_k, d_v);
        let q = rand_mat(&mut rng, 3, d);
        let cfg = HadAttnConfig { n_top: 12, temp: 0.7 };
        let mut cold = SessionKv::new(d, d_v, 16);
        cold.append(&k, &v);
        // warm: same tokens arriving over four uneven turns
        let mut warm = SessionKv::new(d, d_v, 16);
        let chunk = |m: &Mat, lo: usize, hi: usize| {
            Mat::from_vec(hi - lo, m.cols, m.data[lo * m.cols..hi * m.cols].to_vec())
        };
        for (lo, hi) in [(0usize, 20usize), (20, 21), (21, 37), (37, 50)] {
            warm.append(&chunk(&k, lo, hi), &chunk(&v, lo, hi));
        }
        let a = had_attention_paged(&q, &cold, &cfg);
        let b = had_attention_paged(&q, &warm, &cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn from_parts_equals_new() {
        let mut rng = Rng::new(9);
        let k = rand_mat(&mut rng, 16, 32);
        let v = rand_mat(&mut rng, 16, 8);
        let a = PackedKv::new(&k, &v);
        let b = PackedKv::from_parts(&k, v.clone());
        assert_eq!(a.keys, b.keys);
        assert_eq!(a.values, b.values);
    }

    #[test]
    fn scratch_reuse_identical_results() {
        let mut rng = Rng::new(4);
        let q = rand_mat(&mut rng, 4, 32);
        let k = rand_mat(&mut rng, 16, 32);
        let v = rand_mat(&mut rng, 16, 8);
        let kv = PackedKv::new(&k, &v);
        let cfg = HadAttnConfig { n_top: 4, temp: 1.0 };
        let mut scratch = Scratch::default();
        let a = had_attention_with(&q, &kv, &cfg, &mut scratch);
        let b = had_attention_with(&q, &kv, &cfg, &mut scratch);
        assert_eq!(a, b);
        // the same scratch serves paged, scalar, and kernel calls of
        // different geometry
        let mut paged = SessionKv::new(32, 8, 5);
        paged.append(&k, &v);
        let c = had_attention_paged_with(&q, &paged, &cfg, &mut scratch);
        assert_eq!(a, c);
        let d = had_attention_scalar_with(&q, &kv, &cfg, &mut scratch);
        assert_eq!(a, d);
    }

    #[test]
    #[should_panic(expected = "attention over an empty KV cache")]
    fn contiguous_empty_kv_panics_with_unified_message() {
        let kv = PackedKv::new(&Mat::zeros(0, 16), &Mat::zeros(0, 8));
        had_attention(&Mat::zeros(1, 16), &kv, &HadAttnConfig::default());
    }

    #[test]
    #[should_panic(expected = "attention over an empty KV cache")]
    fn paged_empty_kv_panics_with_unified_message() {
        let kv = SessionKv::new(16, 8, 4);
        had_attention_paged(&Mat::zeros(1, 16), &kv, &HadAttnConfig::default());
    }

    #[test]
    #[should_panic(expected = "attention over an empty KV cache")]
    fn scalar_empty_kv_panics_with_unified_message() {
        let kv = PackedKv::new(&Mat::zeros(0, 16), &Mat::zeros(0, 8));
        had_attention_scalar(&Mat::zeros(1, 16), &kv, &HadAttnConfig::default());
    }
}
