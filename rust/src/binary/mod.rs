//! Binary attention substrate: the paper's Hamming kernel on CPU.
//!
//! `bitpack` packs sign bits (32x smaller K at rest), `hamming` computes
//! the XNOR-popcount score matrix, `topn` does deterministic top-N
//! selection over the tiny integer score domain, `simd` owns the
//! runtime-dispatched popcount backends (scalar oracle / SWAR / AVX2 /
//! AVX-512 VPOPCNTQ / NEON, `HAD_KERNEL` override), `kernel` is the
//! tiled multi-threaded scoring engine with fused streaming top-N, and
//! `attention` exposes the whole pipeline (Eqs. 4-8) — kernel-backed
//! fast paths plus the retained scalar oracles.

pub mod attention;
pub mod bitpack;
pub mod hamming;
pub mod kernel;
pub mod simd;
pub mod topn;

pub use attention::{
    had_attention, had_attention_paged, had_attention_paged_scalar, had_attention_ref,
    had_attention_scalar, standard_attention_ref, HadAttnConfig, PackedKv,
};
pub use bitpack::PackedMat;
pub use kernel::{
    had_attention_backend, had_attention_paged_backend, had_attention_paged_pooled,
    had_attention_paged_pooled_backend, had_attention_pooled, had_attention_pooled_backend,
    StreamTopN, QUERY_BLOCK,
};
pub use simd::KernelBackend;
