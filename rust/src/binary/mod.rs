//! Binary attention substrate: the paper's Hamming kernel on CPU.
//!
//! `bitpack` packs sign bits (32x smaller K at rest), `hamming` computes
//! the XNOR-popcount score matrix, `topn` does deterministic top-N
//! selection over the tiny integer score domain, and `attention` fuses
//! the whole pipeline (Eqs. 4-8) allocation-free.

pub mod attention;
pub mod bitpack;
pub mod hamming;
pub mod topn;

pub use attention::{
    had_attention, had_attention_paged, had_attention_ref, standard_attention_ref,
    HadAttnConfig, PackedKv,
};
pub use bitpack::PackedMat;
