//! Top-N selection over integer score rows (paper Eq. 6).
//!
//! Binary scores are small integers in [-d, d] with guaranteed ties, so
//! selection must be deterministic: keep the N largest values, ties broken
//! by LOWEST index (the lax.top_k convention shared with the kernels and
//! oracles).
//!
//! Two implementations:
//!  * `select_topn_heap` — classic bounded min-heap, O(n log N).
//!  * `select_topn_counting` — counting selection exploiting the tiny
//!    integer domain (2d+1 buckets), O(n + d); the §Perf winner for d<=256.

/// Canonical kept-entry order: descending score, ties by ascending index
/// — the one comparator every selection path (counting, heap, and the
/// kernel's streaming top-N) must share for bit-identical outputs.
pub fn sort_entries(entries: &mut [(i32, usize)]) {
    entries.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
}

/// (score, index) pairs of the selected entries, sorted by descending
/// score then ascending index.
pub fn select_topn_heap(scores: &[i32], n_top: usize) -> Vec<(i32, usize)> {
    let n_top = n_top.clamp(1, scores.len().max(1));
    if scores.is_empty() {
        return Vec::new();
    }
    // Bounded "heap" as a sorted insertion buffer: N is small (<=128), so
    // linear insertion beats a real heap in practice and is simpler to
    // keep deterministic. Order: worst kept element last.
    let mut kept: Vec<(i32, usize)> = Vec::with_capacity(n_top + 1);
    for (i, &s) in scores.iter().enumerate() {
        if kept.len() == n_top {
            let (ws, wi) = *kept.last().unwrap();
            // strictly better, or equal score with smaller index? no —
            // equal score: the EARLIER index wins, and we scan forward, so
            // an incoming tie never displaces a kept entry.
            if s <= ws || (s == ws && i > wi) {
                continue;
            }
        }
        let pos = kept
            .binary_search_by(|&(ks, ki)| {
                // descending score, ascending index
                s.cmp(&ks).then(ki.cmp(&i))
            })
            .unwrap_or_else(|p| p);
        kept.insert(pos, (s, i));
        if kept.len() > n_top {
            kept.pop();
        }
    }
    kept
}

/// Counting selection: histogram scores (domain [-d, d]), find the cutoff
/// value, then emit kept entries in index order and sort. `d` bounds
/// |score|.
pub fn select_topn_counting(scores: &[i32], n_top: usize, d: usize) -> Vec<(i32, usize)> {
    let n_top = n_top.clamp(1, scores.len().max(1));
    if scores.is_empty() {
        return Vec::new();
    }
    let buckets = 2 * d + 1;
    let mut hist = vec![0u32; buckets];
    for &s in scores {
        hist[(s + d as i32) as usize] += 1;
    }
    // walk from the top down to find the threshold bucket and how many
    // threshold-valued entries to keep
    let mut remaining = n_top as u32;
    let mut cutoff = 0i32;
    let mut take_at_cutoff = 0u32;
    for b in (0..buckets).rev() {
        let c = hist[b];
        if c == 0 {
            continue;
        }
        if c >= remaining {
            cutoff = b as i32 - d as i32;
            take_at_cutoff = remaining;
            break;
        }
        remaining -= c;
    }
    let mut out = Vec::with_capacity(n_top);
    let mut at_cutoff = 0u32;
    for (i, &s) in scores.iter().enumerate() {
        if s > cutoff {
            out.push((s, i));
        } else if s == cutoff && at_cutoff < take_at_cutoff {
            out.push((s, i));
            at_cutoff += 1;
        }
    }
    sort_entries(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reference(scores: &[i32], n_top: usize) -> Vec<(i32, usize)> {
        let mut all: Vec<(i32, usize)> = scores.iter().copied().zip(0..).map(|(s, i)| (s, i)).collect();
        all.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        all.truncate(n_top.clamp(1, scores.len().max(1)));
        all
    }

    #[test]
    fn simple_case() {
        let scores = vec![1, 5, 3, 5, -2];
        // ties at 5: indices 1 then 3
        assert_eq!(select_topn_heap(&scores, 3), vec![(5, 1), (5, 3), (3, 2)]);
        assert_eq!(select_topn_counting(&scores, 3, 8), vec![(5, 1), (5, 3), (3, 2)]);
    }

    #[test]
    fn all_tied_keeps_lowest_indices() {
        let scores = vec![4; 10];
        let want: Vec<(i32, usize)> = (0..3).map(|i| (4, i)).collect();
        assert_eq!(select_topn_heap(&scores, 3), want);
        assert_eq!(select_topn_counting(&scores, 3, 4), want);
    }

    #[test]
    fn n_larger_than_len() {
        let scores = vec![2, 1];
        assert_eq!(select_topn_heap(&scores, 10).len(), 2);
        assert_eq!(select_topn_counting(&scores, 10, 4).len(), 2);
    }

    #[test]
    fn agree_with_reference_randomized() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let d = rng.range_usize(4, 64);
            let n = rng.range_usize(1, 200);
            let n_top = rng.range_usize(1, n + 1);
            let scores: Vec<i32> = (0..n)
                .map(|_| rng.below((2 * d + 1) as u64) as i32 - d as i32)
                .collect();
            let want = reference(&scores, n_top);
            assert_eq!(select_topn_heap(&scores, n_top), want, "heap d={d} n={n} N={n_top}");
            assert_eq!(
                select_topn_counting(&scores, n_top, d),
                want,
                "counting d={d} n={n} N={n_top}"
            );
        }
    }
}
