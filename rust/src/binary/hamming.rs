//! XNOR-popcount Hamming scores: the paper's core compute, CPU-realized.
//!
//! For ±1 patterns q, k of dimension d:
//!     q . k = d - 2 * ham(q, k)
//! where ham counts differing sign bits. On packed u64 words this is
//! XOR + POPCNT — the hot loop the paper's CAM hardware replaces with an
//! analog match, and our TPU kernel replaces with a ±1 MXU matmul.
//!
//! Everything in this module is deliberately the *scalar* realization
//! (`u64::count_ones`): it is the bit-exactness oracle the
//! runtime-dispatched SIMD backends in `binary::simd` are verified
//! against, and [`hamming_w`] is the inner chain the simd module's
//! scalar backend runs verbatim.

use super::bitpack::PackedMat;

/// Hamming distance between two packed patterns (pad bits are equal by
/// construction and cancel in the XOR).
#[inline]
pub fn hamming(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (x, y) in a.iter().zip(b) {
        acc += (x ^ y).count_ones();
    }
    acc
}

/// Binary dot product via the Hamming identity.
#[inline]
pub fn binary_dot(a: &[u64], b: &[u64], d: usize) -> i32 {
    d as i32 - 2 * hamming(a, b) as i32
}

/// Monomorphized W-word Hamming distance: the fully-unrolled XOR/POPCNT
/// chain shared by `score_matrix_w` and the tiled `binary::kernel`
/// engine (`a` is a register-resident pattern, `b` a key-row slice of at
/// least W words).
#[inline(always)]
pub(crate) fn hamming_w<const W: usize>(a: &[u64; W], b: &[u64]) -> u32 {
    let b = &b[..W];
    let mut ham = 0u32;
    for t in 0..W {
        ham += (a[t] ^ b[t]).count_ones();
    }
    ham
}

/// Score matrix: q_packed (n_q patterns) x k_packed (n_k patterns) ->
/// row-major i32 scores (n_q x n_k), scores[i][j] = sign(q_i).sign(k_j).
pub fn score_matrix(q: &PackedMat, k: &PackedMat, out: &mut [i32]) {
    assert_eq!(q.d, k.d, "dimension mismatch");
    assert_eq!(out.len(), q.rows * k.rows, "output size");
    let d = q.d as i32;
    let w = q.words_per_row;
    match w {
        1 => score_matrix_w::<1>(q, k, d, out),
        2 => score_matrix_w::<2>(q, k, d, out),
        3 => score_matrix_w::<3>(q, k, d, out),
        4 => score_matrix_w::<4>(q, k, d, out),
        _ => {
            for i in 0..q.rows {
                let qi = q.row(i);
                let orow = &mut out[i * k.rows..(i + 1) * k.rows];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = d - 2 * hamming(qi, k.row(j)) as i32;
                }
            }
        }
    }
}

/// Monomorphized inner loop for small word counts (d <= 256): the
/// compiler fully unrolls the XOR/popcount chain. This is the §Perf L3
/// optimization recorded in EXPERIMENTS.md.
fn score_matrix_w<const W: usize>(q: &PackedMat, k: &PackedMat, d: i32, out: &mut [i32]) {
    let n_k = k.rows;
    for i in 0..q.rows {
        let mut qw = [0u64; W];
        qw.copy_from_slice(&q.row(i)[..W]);
        let orow = &mut out[i * n_k..(i + 1) * n_k];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = d - 2 * hamming_w::<W>(&qw, &k.data[j * W..j * W + W]) as i32;
        }
    }
}

/// Convenience: scores straight from float inputs (packs internally).
pub fn score_matrix_from_f32(
    q: &[f32],
    k: &[f32],
    n_q: usize,
    n_k: usize,
    d: usize,
) -> Vec<i32> {
    let qp = PackedMat::pack(n_q, d, q);
    let kp = PackedMat::pack(n_k, d, k);
    let mut out = vec![0i32; n_q * n_k];
    score_matrix(&qp, &kp, &mut out);
    out
}

/// Float reference for the same scores (oracle; O(n^2 d) flops).
pub fn score_matrix_f32_ref(q: &[f32], k: &[f32], n_q: usize, n_k: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n_q * n_k];
    for i in 0..n_q {
        for j in 0..n_k {
            let mut acc = 0.0f32;
            for t in 0..d {
                let qs = if q[i * d + t] >= 0.0 { 1.0 } else { -1.0 };
                let ks = if k[j * d + t] >= 0.0 { 1.0 } else { -1.0 };
                acc += qs * ks;
            }
            out[i * n_k + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn hamming_identity_small() {
        // q = [+,+,-,-], k = [+,-,+,-]: 2 bits differ, dot = 0
        let q = PackedMat::pack(1, 4, &[1.0, 1.0, -1.0, -1.0]);
        let k = PackedMat::pack(1, 4, &[1.0, -1.0, 1.0, -1.0]);
        assert_eq!(hamming(q.row(0), k.row(0)), 2);
        assert_eq!(binary_dot(q.row(0), k.row(0), 4), 0);
    }

    #[test]
    fn self_dot_is_d() {
        let mut rng = Rng::new(3);
        for d in [7, 64, 65, 128, 200] {
            let x = rng.normal_vec(d, 1.0);
            let p = PackedMat::pack(1, d, &x);
            assert_eq!(binary_dot(p.row(0), p.row(0), d), d as i32);
        }
    }

    #[test]
    fn scores_match_float_reference() {
        let mut rng = Rng::new(7);
        for d in [8, 32, 64, 96, 128, 192] {
            let (n_q, n_k) = (9, 13);
            let q = rng.normal_vec(n_q * d, 1.0);
            let k = rng.normal_vec(n_k * d, 1.0);
            let fast = score_matrix_from_f32(&q, &k, n_q, n_k, d);
            let slow = score_matrix_f32_ref(&q, &k, n_q, n_k, d);
            for (a, b) in fast.iter().zip(&slow) {
                assert_eq!(*a as f32, *b);
            }
        }
    }

    #[test]
    fn scores_have_correct_parity() {
        // sign dots over dimension d always have the same parity as d
        let mut rng = Rng::new(11);
        let d = 33;
        let q = rng.normal_vec(4 * d, 1.0);
        let k = rng.normal_vec(4 * d, 1.0);
        for s in score_matrix_from_f32(&q, &k, 4, 4, d) {
            assert_eq!((s - d as i32).rem_euclid(2), 0);
            assert!(s.abs() <= d as i32);
        }
    }
}
