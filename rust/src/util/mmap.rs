//! Minimal read-only file mapping without libc (the cargo registry is
//! unreachable — DESIGN.md §Substrates).
//!
//! On linux-x86_64 [`Mapping::open`] issues the `mmap`/`munmap` syscalls
//! directly via inline asm, so checkpoint weight sections can be borrowed
//! in place: zero copies at load, demand paging, and one physical image
//! shared across every process serving the same file. Everywhere else
//! (and under `HAD_MMAP=0`) it degrades to a buffered read into an
//! 8-byte-aligned heap buffer behind the same API, so callers never
//! branch on platform.
//!
//! The image is immutable for the lifetime of the mapping; `tensor::Slab`
//! views borrow it through an `Arc<Mapping>`.

use std::fs::File;
use std::io::{self, Read};
use std::path::Path;

/// A read-only byte image of a file: a real `mmap` on linux-x86_64, or an
/// owned aligned heap buffer on the fallback path.
pub struct Mapping {
    ptr: *const u8,
    len: usize,
    /// Fallback storage; `None` when `ptr` came from mmap. A `Vec<u64>`
    /// (not `Vec<u8>`) so the base address is 8-byte aligned and f32/u64
    /// views over the image are always well-aligned.
    heap: Option<Vec<u64>>,
}

// Safety: the image is read-only and never mutated after construction,
// so shared references across threads are safe; the heap buffer (if any)
// is owned and freed exactly once in Drop.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `path` read-only. Uses mmap where available unless
    /// `HAD_MMAP=0`; otherwise reads the whole file into an aligned
    /// buffer. Empty files always take the buffered path (a zero-length
    /// mmap is EINVAL).
    pub fn open(path: &Path) -> io::Result<Mapping> {
        if cfg!(all(target_os = "linux", target_arch = "x86_64"))
            && std::env::var("HAD_MMAP").map(|v| v != "0").unwrap_or(true)
        {
            let file = File::open(path)?;
            let len = file.metadata()?.len() as usize;
            if len > 0 {
                if let Ok(ptr) = map_file(&file, len) {
                    return Ok(Mapping { ptr, len, heap: None });
                }
                // mmap refused (exotic filesystem): fall through to read.
            }
            return Self::read_into_heap(file, len);
        }
        Self::buffered(path)
    }

    /// Force the buffered path (used by tests to compare against mmap and
    /// by non-linux builds).
    pub fn buffered(path: &Path) -> io::Result<Mapping> {
        let file = File::open(path)?;
        let len = file.metadata()?.len() as usize;
        Self::read_into_heap(file, len)
    }

    fn read_into_heap(mut file: File, len: usize) -> io::Result<Mapping> {
        let mut buf = vec![0u64; len.div_ceil(8)];
        if len > 0 {
            // Safety: the buffer holds len.div_ceil(8)*8 >= len writable
            // bytes; u64 has no invalid bit patterns.
            let bytes =
                unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len) };
            file.read_exact(bytes)?;
        }
        Ok(Mapping { ptr: buf.as_ptr() as *const u8, len, heap: Some(buf) })
    }

    /// The whole image.
    pub fn bytes(&self) -> &[u8] {
        // Safety: ptr/len describe a live image (mmap'd or heap-owned)
        // valid for the lifetime of self.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Base pointer of the image (8-byte aligned on both paths: mmap
    /// returns page-aligned addresses, the heap buffer is `Vec<u64>`).
    pub fn as_ptr(&self) -> *const u8 {
        self.ptr
    }

    /// True when the bytes are a real file mapping (zero-copy path).
    pub fn is_mapped(&self) -> bool {
        self.heap.is_none()
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        if self.heap.is_none() && self.len > 0 {
            unmap_file(self.ptr, self.len);
        }
    }
}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mapping")
            .field("len", &self.len)
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn map_file(file: &File, len: usize) -> io::Result<*const u8> {
    use std::os::unix::io::AsRawFd;
    const SYS_MMAP: usize = 9;
    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;
    let ret: isize;
    // Safety: mmap(NULL, len, PROT_READ, MAP_PRIVATE, fd, 0) with a valid
    // open fd; the kernel either returns a mapping or an errno in
    // [-4095, -1]. rcx/r11 are clobbered by the syscall instruction.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") SYS_MMAP => ret,
            in("rdi") 0usize,
            in("rsi") len,
            in("rdx") PROT_READ,
            in("r10") MAP_PRIVATE,
            in("r8") file.as_raw_fd() as usize,
            in("r9") 0usize,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
    }
    if (-4095..0).contains(&ret) {
        Err(io::Error::from_raw_os_error(-ret as i32))
    } else {
        Ok(ret as *const u8)
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
fn map_file(_file: &File, _len: usize) -> io::Result<*const u8> {
    Err(io::Error::new(io::ErrorKind::Unsupported, "mmap unavailable"))
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn unmap_file(ptr: *const u8, len: usize) {
    const SYS_MUNMAP: usize = 11;
    let _ret: isize;
    // Safety: ptr/len came from a successful map_file; munmap failure at
    // drop time is unreportable and ignored.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") SYS_MUNMAP => _ret,
            in("rdi") ptr as usize,
            in("rsi") len,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
fn unmap_file(_ptr: *const u8, _len: usize) {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn temp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("had-mmap-{}-{name}", std::process::id()));
        let mut f = File::create(&p).unwrap();
        f.write_all(bytes).unwrap();
        p
    }

    #[test]
    fn mapped_and_buffered_agree() {
        let payload: Vec<u8> = (0..4099u32).map(|i| (i * 7 + 3) as u8).collect();
        let p = temp("agree", &payload);
        let m = Mapping::open(&p).unwrap();
        let b = Mapping::buffered(&p).unwrap();
        assert_eq!(m.bytes(), &payload[..]);
        assert_eq!(b.bytes(), &payload[..]);
        assert!(!b.is_mapped());
        #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
        assert!(m.is_mapped(), "linux-x86_64 should take the real mmap path");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn buffered_base_is_8_byte_aligned() {
        let p = temp("align", &[1, 2, 3]);
        let b = Mapping::buffered(&p).unwrap();
        assert_eq!(b.as_ptr() as usize % 8, 0);
        assert_eq!(b.len(), 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_maps_to_empty_image() {
        let p = temp("empty", &[]);
        let m = Mapping::open(&p).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), &[] as &[u8]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_an_io_error() {
        let p = std::env::temp_dir().join("had-mmap-definitely-missing");
        assert!(Mapping::open(&p).is_err());
    }
}
