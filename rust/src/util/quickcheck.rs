//! Property-based testing substrate (replaces proptest — DESIGN.md
//! §Substrates).
//!
//! A property runs against `cases` random inputs drawn from a generator;
//! on failure it greedily shrinks the input via the generator's `shrink`
//! before reporting the minimal counterexample. Coordinator invariants
//! (routing, batching, state) are checked with this in rust/tests/.

use crate::util::rng::Rng;

/// A generator of values of type T with an attached shrinker.
pub struct Gen<T> {
    pub sample: Box<dyn Fn(&mut Rng) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        sample: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen { sample: Box::new(sample), shrink: Box::new(shrink) }
    }

    /// Generator without shrinking.
    pub fn opaque(sample: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen::new(sample, |_| Vec::new())
    }

    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + Clone + 'static) -> Gen<U> {
        let sample = self.sample;
        let f2 = f.clone();
        Gen::new(move |r| f(sample(r)), move |_| {
            let _ = &f2;
            Vec::new()
        })
    }
}

/// usize in [lo, hi] shrinking toward lo.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(
        move |r| r.range_usize(lo, hi + 1),
        move |&v| {
            // halving ladder toward lo: v-(v-lo), v-(v-lo)/2, ..., v-1
            let mut out = Vec::new();
            let mut delta = v.saturating_sub(lo);
            while delta > 0 {
                out.push(v - delta);
                delta /= 2;
            }
            out.dedup();
            out
        },
    )
}

/// f32 in [lo, hi) shrinking toward 0/lo.
pub fn f32_in(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(
        move |r| lo + (hi - lo) * r.next_f32(),
        move |&v| {
            let mut out = vec![lo, v / 2.0];
            out.retain(|x| (*x - v).abs() > 1e-9 && *x >= lo && *x < hi);
            out
        },
    )
}

/// Vec<f32> of length in [min_len, max_len] with normal(0,1) entries;
/// shrinks by halving the length.
pub fn normal_vec(min_len: usize, max_len: usize) -> Gen<Vec<f32>> {
    Gen::new(
        move |r| {
            let n = r.range_usize(min_len, max_len + 1);
            (0..n).map(|_| r.normal()).collect()
        },
        move |v: &Vec<f32>| {
            let mut out = Vec::new();
            if v.len() > min_len {
                out.push(v[..(min_len.max(v.len() / 2))].to_vec());
                let mut tail = v.clone();
                tail.remove(0);
                out.push(tail);
            }
            out
        },
    )
}

/// Pair combinator.
pub fn pair<A: Clone + 'static, B: Clone + 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    let (sa, sha) = (a.sample, a.shrink);
    let (sb, shb) = (b.sample, b.shrink);
    Gen::new(
        move |r| (sa(r), sb(r)),
        move |(x, y)| {
            let mut out: Vec<(A, B)> = sha(x).into_iter().map(|x2| (x2, y.clone())).collect();
            out.extend(shb(y).into_iter().map(|y2| (x.clone(), y2)));
            out
        },
    )
}

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0x5EED, max_shrink_steps: 200 }
    }
}

/// Run `prop` against `cfg.cases` random inputs; panic with the minimal
/// shrunk counterexample on failure.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    cfg: &Config,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = (gen.sample)(&mut rng);
        if prop(&input) {
            continue;
        }
        // shrink greedily
        let mut cur = input;
        let mut steps = 0;
        'outer: while steps < cfg.max_shrink_steps {
            for cand in (gen.shrink)(&cur) {
                steps += 1;
                if !prop(&cand) {
                    cur = cand;
                    continue 'outer;
                }
                if steps >= cfg.max_shrink_steps {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed (case {case}/{}, seed {:#x}); minimal counterexample: {:?}",
            cfg.cases, cfg.seed, cur
        );
    }
}

/// Shorthand with default config.
pub fn quickcheck<T: Clone + std::fmt::Debug + 'static>(gen: &Gen<T>, prop: impl Fn(&T) -> bool) {
    check(&Config::default(), gen, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        quickcheck(&usize_in(0, 100), |&x| x <= 100);
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let result = std::panic::catch_unwind(|| {
            quickcheck(&usize_in(0, 1000), |&x| x < 500);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal counterexample for x < 500 is exactly 500
        assert!(msg.contains("500"), "{msg}");
    }

    #[test]
    fn pair_generator() {
        quickcheck(&pair(usize_in(1, 8), usize_in(1, 8)), |&(a, b)| a * b <= 64);
    }

    #[test]
    fn vec_generator_lengths() {
        quickcheck(&normal_vec(2, 16), |v| v.len() >= 2 && v.len() <= 16);
    }
}
