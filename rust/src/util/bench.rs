//! Micro-benchmark harness substrate (replaces criterion — DESIGN.md
//! §Substrates).
//!
//! Measures wall-clock of a closure with warmup, reports min / p50 / p90 /
//! mean and derived throughput. Used by the `benches/` targets (declared
//! with `harness = false`) and the §Perf iteration loop.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
    pub mean: Duration,
}

impl Stats {
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }

    /// items/sec given `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  min {:>12}  p50 {:>12}  p90 {:>12}  p99 {:>12}  mean {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.min),
            fmt_dur(self.p50),
            fmt_dur(self.p90),
            fmt_dur(self.p99),
            fmt_dur(self.mean),
        );
    }

    pub fn print_throughput(&self, items: f64, unit: &str) {
        println!(
            "{:<44} mean {:>12}   {:>14.1} {unit}/s",
            self.name,
            fmt_dur(self.mean),
            self.throughput(items),
        );
    }
}

/// Percentile of an ascending-sorted µs sample (0 on empty): index
/// `floor(len * p)`, clamped — the one convention the coordinator's
/// `Metrics` snapshots and the bench mains share, so their printed
/// percentiles can never diverge.
pub fn percentile_us(sorted: &[u128], p: f64) -> u128 {
    if sorted.is_empty() {
        0
    } else {
        sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
    }
}

/// Schema version stamped into every results record; bump when record
/// shapes change so scripts/summarize_results.py can tell generations
/// apart instead of guessing from missing keys.
pub const RESULTS_SCHEMA_VERSION: u64 = 2;

/// Process-stable run id: one bench invocation = one id, so the
/// summarizer can group records instead of silently mixing appended runs.
pub fn run_id() -> &'static str {
    static ID: std::sync::OnceLock<String> = std::sync::OnceLock::new();
    ID.get_or_init(|| {
        let ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis())
            .unwrap_or(0);
        format!("run-{ms:x}-{}", std::process::id())
    })
}

/// Commit identity for provenance: `GITHUB_SHA` in CI, `git rev-parse`
/// locally, "unknown" outside a work tree.
pub fn git_sha() -> &'static str {
    static SHA: std::sync::OnceLock<String> = std::sync::OnceLock::new();
    SHA.get_or_init(|| {
        if let Ok(sha) = std::env::var("GITHUB_SHA") {
            if !sha.is_empty() {
                return sha.chars().take(12).collect();
            }
        }
        std::process::Command::new("git")
            .args(["rev-parse", "--short=12", "HEAD"])
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "unknown".to_string())
    })
}

/// Stamp provenance (run id, git sha, schema version) into a record,
/// leaving any keys the caller already set alone.
fn stamp_provenance(r: &crate::util::json::Json) -> crate::util::json::Json {
    use crate::util::json::Json;
    match r {
        Json::Obj(m) => {
            let mut m = m.clone();
            m.entry("run".to_string()).or_insert_with(|| Json::str(run_id()));
            m.entry("git_sha".to_string()).or_insert_with(|| Json::str(git_sha()));
            m.entry("schema".to_string())
                .or_insert_with(|| Json::num(RESULTS_SCHEMA_VERSION as f64));
            Json::Obj(m)
        }
        other => other.clone(),
    }
}

/// Append JSONL records to `path` (creating parent dirs) — the
/// results-file convention every bench main shares and
/// scripts/summarize_results.py reads. Every object record is stamped
/// with run id + git sha + schema version.
pub fn write_jsonl(path: &str, records: &[crate::util::json::Json]) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    for r in records {
        writeln!(f, "{}", stamp_provenance(r))?;
    }
    Ok(())
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Is `HAD_BENCH_QUICK` set (to a non-"0" value)? The single source of
/// truth for quick mode — `Bencher::from_env` and bench-side perf gates
/// (which should relax under tiny budgets) must agree on it.
pub fn quick_env() -> bool {
    std::env::var("HAD_BENCH_QUICK").map_or(false, |v| v != "0")
}

pub struct Bencher {
    /// target total measurement time per benchmark
    pub budget: Duration,
    /// warmup time before measurement
    pub warmup: Duration,
    /// hard cap on measured iterations
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Modest defaults: the whole bench suite must fit the CI budget.
        Bencher {
            budget: Duration::from_millis(700),
            warmup: Duration::from_millis(150),
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            budget: Duration::from_millis(250),
            warmup: Duration::from_millis(50),
            max_iters: 2_000,
        }
    }

    /// Default budgets, or `quick()` when [`quick_env`] says so — the
    /// tiny-iteration mode CI's bench smoke step runs so kernel
    /// regressions in bench code are caught cheaply.
    pub fn from_env() -> Self {
        if quick_env() {
            Bencher::quick()
        } else {
            Bencher::default()
        }
    }

    /// Benchmark `f`, preventing dead-code elimination via the returned
    /// value (use `std::hint::black_box` inside `f` for inputs).
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Stats {
        // Warmup + estimate per-iter cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = warm_start.elapsed() / warm_iters.max(1) as u32;
        let target_iters = if per_iter.as_nanos() == 0 {
            self.max_iters
        } else {
            ((self.budget.as_nanos() / per_iter.as_nanos().max(1)) as usize)
                .clamp(3, self.max_iters)
        };

        let mut samples = Vec::with_capacity(target_iters);
        for _ in 0..target_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let total: Duration = samples.iter().sum();
        Stats {
            name: name.to_string(),
            iters: samples.len(),
            min: samples[0],
            p50: samples[samples.len() / 2],
            p90: samples[(samples.len() * 9 / 10).min(samples.len() - 1)],
            p99: samples[(samples.len() * 99 / 100).min(samples.len() - 1)],
            mean: total / samples.len() as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let s = b.run("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.iters >= 3);
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99);
    }

    #[test]
    fn jsonl_records_carry_provenance() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir().join(format!("had_bench_prov_{}", std::process::id()));
        let path = dir.join("r.jsonl");
        let rec = Json::obj(vec![("kind", Json::str("kernel")), ("keys_per_s", Json::num(1.0))]);
        // A record with its own run id must not be overwritten.
        let pinned = Json::obj(vec![("kind", Json::str("kernel")), ("run", Json::str("mine"))]);
        write_jsonl(path.to_str().unwrap(), &[rec, pinned]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let first = Json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(first.get("run").and_then(|v| v.as_str()), Some(run_id()));
        assert!(first.get("git_sha").and_then(|v| v.as_str()).is_some());
        assert_eq!(
            first.get("schema").and_then(|v| v.as_f64()),
            Some(RESULTS_SCHEMA_VERSION as f64)
        );
        let second = Json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(second.get("run").and_then(|v| v.as_str()), Some("mine"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn format_durations() {
        assert!(fmt_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
