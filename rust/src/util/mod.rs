//! From-scratch substrates (the cargo registry is unreachable in this
//! environment — see DESIGN.md §Substrates for the inventory and the
//! crates each module replaces).

pub mod bench;
pub mod bf16;
pub mod cli;
pub mod json;
pub mod log;
pub mod quickcheck;
pub mod rng;
pub mod threadpool;
