//! From-scratch substrates (the cargo registry is unreachable in this
//! environment — see DESIGN.md §Substrates for the inventory and the
//! crates each module replaces).

pub mod bench;
pub mod bf16;
pub mod cli;
pub mod fault;
pub mod json;
pub mod log;
pub mod mmap;
pub mod quickcheck;
pub mod rng;
pub mod threadpool;

/// Lock a mutex, recovering from poisoning. A panic inside a worker
/// (real or injected) poisons any mutex it held; the data guarded by
/// the coordinator's mutexes stays structurally valid across a panicked
/// decode step (streams/queues are only mutated between steps), so
/// recovery is safe and keeps submit/shutdown paths alive.
pub fn lock_or_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
