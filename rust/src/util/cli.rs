//! Minimal CLI argument parser substrate (replaces clap — DESIGN.md
//! §Substrates). Supports subcommands, `--flag`, `--key value`,
//! `--key=value`, and positional arguments, with generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse raw argv (excluding the binary name). The first non-flag
    /// token becomes the subcommand; later non-flag tokens are positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless next token is another flag
                    let is_flag_next = iter
                        .peek()
                        .map(|n| n.starts_with("--"))
                        .unwrap_or(true);
                    if is_flag_next {
                        out.flags.insert(stripped.to_string(), "true".to_string());
                    } else {
                        out.flags.insert(stripped.to_string(), iter.next().unwrap());
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true" | "1" | "yes"))
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flag(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.flag(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flag(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        // NOTE: `--flag value` binds greedily; boolean flags must use
        // `--flag=true`, be last, or precede another --flag.
        let a = parse("distill run1 --config tinyglue --steps=200 --verbose");
        assert_eq!(a.command.as_deref(), Some("distill"));
        assert_eq!(a.flag("config"), Some("tinyglue"));
        assert_eq!(a.get_usize("steps", 0), 200);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["run1"]);
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("x --dry-run --out path");
        assert!(a.get_bool("dry-run"));
        assert_eq!(a.flag("out"), Some("path"));
    }

    #[test]
    fn trailing_boolean() {
        let a = parse("x --force");
        assert!(a.get_bool("force"));
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.get_str("missing", "d"), "d");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert!(!a.get_bool("missing"));
    }
}
