//! Deterministic fault injection for chaos testing the serving stack.
//!
//! Activation: `HAD_FAULT=site[:prob[:delay_ms]][,site...][,seed=N]`.
//! Each clause names an injection site (see the `SITE_*` constants) with
//! an optional firing probability (default 1.0) and, for delay-kind
//! sites, an injected latency in milliseconds (default 1). A `seed=N`
//! clause fixes the PRNG so a fault schedule replays bit-identically;
//! without it the seed defaults to 0.
//!
//! Example: `HAD_FAULT=decode_step:0.2:2,worker_panic:0.05,seed=42`
//! delays 20% of decode steps by 2 ms and panics 5% of worker-shard
//! step calls, with a reproducible draw sequence.
//!
//! The enable path mirrors `obs::span`: a single relaxed atomic load
//! when disabled, lazy env parsing on first use. Components hold an
//! `Option<Arc<FaultPlan>>` (resolved once at construction from either
//! an explicit plan or the environment) so tests can inject faults into
//! one server instance without a process-global toggle leaking into
//! concurrently running tests.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::util::rng::Rng;

/// Delay a decode/prefill step inside the scheduler tick.
pub const SITE_DECODE_STEP: &str = "decode_step";
/// Panic inside a worker shard's step closure (exercises `catch_unwind`
/// isolation and lock-poison recovery).
pub const SITE_WORKER_PANIC: &str = "worker_panic";
/// Report zero pool headroom to the admission gate for one round
/// (exercises deferral under pressure).
pub const SITE_POOL_PRESSURE: &str = "pool_pressure";
/// Treat the client as gone when emitting a token (exercises the
/// disconnect retirement path).
pub const SITE_CLIENT_DISCONNECT: &str = "client_disconnect";
/// Stall the scheduler's work-selection loop briefly (exercises
/// deadline and TTL enforcement under a slow scheduler).
pub const SITE_QUEUE_STALL: &str = "queue_stall";
/// Drop a just-accepted TCP connection at the HTTP listener (exercises
/// client retry behavior and accept-loop hygiene).
pub const SITE_NET_ACCEPT: &str = "net_accept";
/// Stall a chunk write to a streaming HTTP client (exercises write
/// deadlines and the slow-reader backpressure path over real sockets).
pub const SITE_NET_WRITE: &str = "net_write";
/// Fail a KV spill write in `store::SpillStore::put` (the pool must
/// degrade to plain destroy-on-evict, never wedge).
pub const SITE_SPILL_WRITE: &str = "spill_write";
/// Fail a KV hydrate read in `store::SpillStore::get` (the stream must
/// re-prefill or retire cleanly — corrupt KV is never served).
pub const SITE_SPILL_READ: &str = "spill_read";

const SITES: [&str; 9] = [
    SITE_DECODE_STEP,
    SITE_WORKER_PANIC,
    SITE_POOL_PRESSURE,
    SITE_CLIENT_DISCONNECT,
    SITE_QUEUE_STALL,
    SITE_NET_ACCEPT,
    SITE_NET_WRITE,
    SITE_SPILL_WRITE,
    SITE_SPILL_READ,
];

/// What a firing site should do. The kind is fixed per site: panics only
/// make sense where a `catch_unwind` boundary exists, denials only where
/// the caller has a refusal path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Sleep for the clause's configured duration before proceeding.
    Delay(Duration),
    /// Unwind; the site is expected to convert this into a stream error.
    Panic,
    /// Pretend the guarded resource is unavailable this round.
    Deny,
}

fn kind_for(site: &str, delay: Duration) -> Fault {
    match site {
        SITE_WORKER_PANIC => Fault::Panic,
        SITE_POOL_PRESSURE | SITE_CLIENT_DISCONNECT | SITE_NET_ACCEPT | SITE_SPILL_WRITE
        | SITE_SPILL_READ => Fault::Deny,
        _ => Fault::Delay(delay),
    }
}

#[derive(Clone, Debug)]
struct Clause {
    site: &'static str,
    prob: f64,
    fault: Fault,
}

/// A parsed fault schedule: which sites fire, with what probability, and
/// a seeded PRNG driving the draws. Cheap to share (`Arc`).
#[derive(Debug)]
pub struct FaultPlan {
    clauses: Vec<Clause>,
    rng: Mutex<Rng>,
    injected: AtomicU64,
}

impl FaultPlan {
    /// Parse a `HAD_FAULT` spec. Errors (rather than silently ignoring)
    /// on unknown sites or malformed clauses so a typo'd chaos run fails
    /// loudly instead of testing nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut clauses = Vec::new();
        let mut seed = 0u64;
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(v) = part.strip_prefix("seed=") {
                seed = v.parse().map_err(|_| format!("bad seed '{v}'"))?;
                continue;
            }
            let mut fields = part.split(':');
            let name = fields.next().unwrap_or("");
            let site = *SITES
                .iter()
                .find(|s| **s == name)
                .ok_or_else(|| format!("unknown fault site '{name}'"))?;
            let prob = match fields.next() {
                None => 1.0,
                Some(p) => {
                    let p: f64 = p.parse().map_err(|_| format!("bad probability '{p}'"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("probability {p} outside [0, 1]"));
                    }
                    p
                }
            };
            let delay_ms: u64 = match fields.next() {
                None => 1,
                Some(d) => d.parse().map_err(|_| format!("bad delay '{d}'"))?,
            };
            if fields.next().is_some() {
                return Err(format!("too many fields in clause '{part}'"));
            }
            clauses.push(Clause { site, prob, fault: kind_for(site, Duration::from_millis(delay_ms)) });
        }
        if clauses.is_empty() {
            return Err("no fault clauses in spec".to_string());
        }
        Ok(FaultPlan { clauses, rng: Mutex::new(Rng::new(seed)), injected: AtomicU64::new(0) })
    }

    /// Draw at a named site: `Some(fault)` when the site is configured
    /// and its probability fires this call. Sites not in the plan never
    /// fire and cost one linear scan of the (tiny) clause list.
    pub fn fire(&self, site: &str) -> Option<Fault> {
        let clause = self.clauses.iter().find(|c| c.site == site)?;
        let hit = clause.prob >= 1.0 || {
            let mut rng = self.rng.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            rng.next_f64() < clause.prob
        };
        if hit {
            self.injected.fetch_add(1, Ordering::Relaxed);
            Some(clause.fault)
        } else {
            None
        }
    }

    /// Total faults fired so far (all sites).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
}

// Env-gated global plan, mirroring obs::span's enable pattern:
// 0 = uninitialized, 1 = disabled, 2 = enabled.
static STATE: AtomicU8 = AtomicU8::new(0);
static PLAN: OnceLock<Option<Arc<FaultPlan>>> = OnceLock::new();

fn init() -> u8 {
    let plan = PLAN.get_or_init(|| match std::env::var("HAD_FAULT") {
        Ok(v) if !v.trim().is_empty() && v.trim() != "0" => match FaultPlan::parse(&v) {
            Ok(p) => Some(Arc::new(p)),
            Err(e) => {
                crate::log_warn!("HAD_FAULT: {e}; fault injection disabled");
                None
            }
        },
        _ => None,
    });
    let state = if plan.is_some() { 2 } else { 1 };
    STATE.store(state, Ordering::Release);
    state
}

/// The process-wide plan from `HAD_FAULT`, if set and well-formed.
/// One relaxed atomic load on the (common) disabled path.
pub fn from_env() -> Option<Arc<FaultPlan>> {
    let state = match STATE.load(Ordering::Relaxed) {
        0 => init(),
        s => s,
    };
    if state == 2 {
        PLAN.get().and_then(Clone::clone)
    } else {
        None
    }
}

/// Convenience for call sites holding an instance-scoped plan: draw at
/// `site` when a plan is present. `None` plan is a branch, no locking.
#[inline]
pub fn fire(plan: &Option<Arc<FaultPlan>>, site: &str) -> Option<Fault> {
    plan.as_ref().and_then(|p| p.fire(site))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse("decode_step:0.25:3,worker_panic:0.5,pool_pressure,seed=9").unwrap();
        assert_eq!(p.clauses.len(), 3);
        assert_eq!(p.clauses[0].site, SITE_DECODE_STEP);
        assert_eq!(p.clauses[0].prob, 0.25);
        assert_eq!(p.clauses[0].fault, Fault::Delay(Duration::from_millis(3)));
        assert_eq!(p.clauses[1].fault, Fault::Panic);
        assert_eq!(p.clauses[1].prob, 0.5);
        assert_eq!(p.clauses[2].fault, Fault::Deny);
        assert_eq!(p.clauses[2].prob, 1.0);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("not_a_site").is_err());
        assert!(FaultPlan::parse("decode_step:1.5").is_err());
        assert!(FaultPlan::parse("decode_step:0.5:x").is_err());
        assert!(FaultPlan::parse("decode_step:0.5:1:extra").is_err());
        assert!(FaultPlan::parse("seed=abc,decode_step").is_err());
    }

    #[test]
    fn unconfigured_sites_never_fire() {
        let p = FaultPlan::parse("worker_panic").unwrap();
        for _ in 0..32 {
            assert_eq!(p.fire(SITE_DECODE_STEP), None);
        }
        assert_eq!(p.injected(), 0);
    }

    #[test]
    fn probability_one_always_fires_and_counts() {
        let p = FaultPlan::parse("client_disconnect:1.0").unwrap();
        for _ in 0..5 {
            assert_eq!(p.fire(SITE_CLIENT_DISCONNECT), Some(Fault::Deny));
        }
        assert_eq!(p.injected(), 5);
    }

    #[test]
    fn net_sites_share_the_grammar_and_fixed_kinds() {
        // net_accept denies (the accept loop refuses the connection);
        // net_write delays (a stalled socket write), honoring delay_ms.
        let p = FaultPlan::parse("net_accept:0.5,net_write:0.25:7,seed=3").unwrap();
        assert_eq!(p.clauses[0].site, SITE_NET_ACCEPT);
        assert_eq!(p.clauses[0].fault, Fault::Deny);
        assert_eq!(p.clauses[1].site, SITE_NET_WRITE);
        assert_eq!(p.clauses[1].fault, Fault::Delay(Duration::from_millis(7)));
        let always = FaultPlan::parse("net_accept").unwrap();
        assert_eq!(always.fire(SITE_NET_ACCEPT), Some(Fault::Deny));
        assert_eq!(always.fire(SITE_NET_WRITE), None);
    }

    #[test]
    fn spill_sites_deny_so_callers_take_their_refusal_paths() {
        // spill_write fails the write (pool degrades to plain eviction);
        // spill_read fails the hydrate (stream re-prefills). Both are
        // refusals with an error path at the call site, hence Deny.
        let p = FaultPlan::parse("spill_write:0.5,spill_read,seed=1").unwrap();
        assert_eq!(p.clauses[0].site, SITE_SPILL_WRITE);
        assert_eq!(p.clauses[0].fault, Fault::Deny);
        assert_eq!(p.clauses[1].site, SITE_SPILL_READ);
        assert_eq!(p.clauses[1].fault, Fault::Deny);
        assert_eq!(p.fire(SITE_SPILL_READ), Some(Fault::Deny));
    }

    #[test]
    fn seeded_draws_replay_identically() {
        let draws = |seed: u64| -> Vec<bool> {
            let p = FaultPlan::parse(&format!("decode_step:0.5,seed={seed}")).unwrap();
            (0..64).map(|_| p.fire(SITE_DECODE_STEP).is_some()).collect()
        };
        assert_eq!(draws(42), draws(42));
        assert_ne!(draws(42), draws(43), "different seeds should diverge");
        let fired = draws(42).iter().filter(|b| **b).count();
        assert!(fired > 8 && fired < 56, "p=0.5 over 64 draws fired {fired}");
    }
}
