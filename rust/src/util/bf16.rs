//! bfloat16 helpers (replaces the `half` crate — DESIGN.md §Substrates).
//!
//! Used by the hardware simulator's BF16 MAC model and by the
//! integer-exactness argument behind the ±1-matmul mapping (DESIGN.md
//! §Hardware-Adaptation): bf16 has an 8-bit mantissa, so signed integer
//! accumulation is exact up to |x| <= 256 — which bounds d_head.

/// Round-to-nearest-even f32 -> bf16 (stored in the high 16 bits).
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    // NaN: preserve a quiet NaN
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7FFF + lsb);
    let _ = round_bit;
    (rounded >> 16) as u16
}

#[inline]
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// f32 -> bf16 -> f32 round trip (the precision a bf16 MXU sees).
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// Largest integer magnitude exactly representable in bf16 (2^8).
pub const BF16_EXACT_INT_MAX: i32 = 256;

/// True iff every integer in [-m, m] is exactly representable in bf16 —
/// the precondition for running binary score matmuls on the MXU.
pub fn integer_exact_up_to(m: i32) -> bool {
    m <= BF16_EXACT_INT_MAX
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small_integers_exact() {
        for i in -256..=256 {
            let x = i as f32;
            assert_eq!(bf16_round(x), x, "integer {i} must round-trip");
        }
    }

    #[test]
    fn beyond_exact_range_loses_integers() {
        // 257 = 0x101 needs 9 mantissa bits; bf16 rounds it.
        assert_ne!(bf16_round(257.0), 257.0);
        assert!(integer_exact_up_to(256));
        assert!(!integer_exact_up_to(257));
    }

    #[test]
    fn rounding_is_nearest_even() {
        // halfway cases round to even mantissa
        let x = f32::from_bits(0x3F80_8000); // 1.00390625: exactly halfway
        let r = bf16_round(x);
        assert!(r == 1.0 || r == f32::from_bits(0x3F81_0000));
        assert_eq!(bf16_round(1.0), 1.0);
    }

    #[test]
    fn nan_and_inf() {
        assert!(bf16_round(f32::NAN).is_nan());
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_round(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn sign_values_exact() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(-1.0), -1.0);
    }
}
