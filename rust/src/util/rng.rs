//! Deterministic PRNG substrate (SplitMix64 seeding + xoshiro256**).
//!
//! The cargo registry is unreachable in this environment, so `rand` is
//! replaced by this from-scratch implementation (DESIGN.md §Substrates).
//! xoshiro256** is the reference generator of Blackman & Vigna; SplitMix64
//! expands a single u64 seed into the 256-bit state, as the authors
//! recommend.

/// xoshiro256** generator. Not cryptographic; used for data synthesis,
/// parameter init, and property-test case generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-task / per-worker seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa-ish bits for a uniform float in [0,1)
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as u64;
            }
            // reject the biased low range
            if lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — init/datagen are not hot paths).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert_ne!(r.weighted(&[1.0, 0.0, 2.0]), 1);
        }
    }
}
