//! Thread-pool substrate (replaces tokio/rayon — DESIGN.md §Substrates).
//!
//! A fixed pool of workers over an mpsc channel, plus a scoped
//! `parallel_for` used by the coordinator's worker pool and benches. On
//! this single-core testbed parallelism buys little, but the coordinator's
//! design (leader + N workers) is preserved faithfully and is exercised by
//! the tests with >1 logical worker.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs run FIFO; `wait_idle` blocks until every
/// submitted job has finished (the barrier used by tests and shutdown).
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(n_workers: usize) -> Self {
        let n = n_workers.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("had-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // A panicking job must not wedge wait_idle.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => return, // channel closed: shutdown
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped parallel map over a slice: applies `f(index, &item)` with the
/// pool supplying the concurrency budget, collecting results in order.
/// Execution uses scoped threads (so `f` and the items may borrow stack
/// data); results go into per-item slots so no unsafe and no result
/// reordering.
pub fn parallel_map<T, R, F>(pool: &ThreadPool, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send + 'static,
    F: Fn(usize, &T) -> R + Sync,
{
    parallel_map_n(pool.n_workers(), items, f)
}

/// `parallel_map` with an explicit worker budget — for callers that want
/// bounded data parallelism without keeping a `ThreadPool` (and its
/// parked worker threads) alive between calls.
pub fn parallel_map_n<T, R, F>(n_workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send + 'static,
    F: Fn(usize, &T) -> R + Sync,
{
    let slots: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let n_workers = n_workers.max(1).min(items.len().max(1));
        let slots = &slots;
        let f = &f;
        let next = &next;
        for _ in 0..n_workers {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    return;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("slot filled"))
        .collect()
}

/// Scoped parallel mutation over a slice: applies `f(index, &mut item)`
/// with at most `n_workers` scoped threads, each owning one contiguous
/// chunk (static partition — right for work items of similar cost, like
/// the scheduler's one-decode-step-per-stream generation tick, where
/// work stealing would buy nothing but synchronization).
pub fn parallel_for_mut<T, F>(n_workers: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    if items.is_empty() {
        return;
    }
    let n_workers = n_workers.max(1).min(items.len());
    let chunk = items.len().div_ceil(n_workers);
    std::thread::scope(|scope| {
        for (ci, chunk_items) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (j, item) in chunk_items.iter_mut().enumerate() {
                    f(ci * chunk + j, item);
                }
            });
        }
    });
}

/// Split `n_items` into at most `n_shards` contiguous `(lo, hi)` ranges
/// whose starts are aligned to `align` (the kernel's query-block size, so
/// a shard never splits a tile). Ranges cover `0..n_items` exactly, in
/// order, each non-empty; fewer shards are returned when there are not
/// enough aligned units to go around.
pub fn shard_ranges(n_items: usize, n_shards: usize, align: usize) -> Vec<(usize, usize)> {
    let align = align.max(1);
    let n_units = n_items.div_ceil(align);
    let n_shards = n_shards.clamp(1, n_units.max(1));
    let base = n_units / n_shards;
    let extra = n_units % n_shards;
    let mut out = Vec::with_capacity(n_shards);
    let mut unit = 0usize;
    for s in 0..n_shards {
        let take = base + usize::from(s < extra);
        if take == 0 {
            continue;
        }
        let lo = unit * align;
        unit += take;
        let hi = (unit * align).min(n_items);
        out.push((lo, hi));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn panicking_job_does_not_wedge() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        pool.submit(|| {});
        pool.wait_idle();
    }

    #[test]
    fn parallel_map_order() {
        let pool = ThreadPool::new(3);
        let items: Vec<usize> = (0..50).collect();
        let out = parallel_map(&pool, &items, |i, &x| i * 1000 + x * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 1000 + i * 2);
        }
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = parallel_map(&pool, &[] as &[usize], |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_n_matches_serial_for_any_budget() {
        let items: Vec<usize> = (0..37).collect();
        for workers in [0usize, 1, 3, 64] {
            let out = parallel_map_n(workers, &items, |i, &x| i + x);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, 2 * i, "workers={workers}");
            }
        }
    }

    #[test]
    fn parallel_for_mut_touches_every_item_once() {
        for workers in [1usize, 2, 3, 64] {
            let mut items: Vec<usize> = (0..23).collect();
            parallel_for_mut(workers, &mut items, |i, x| {
                assert_eq!(*x, i, "index matches slot");
                *x += 100;
            });
            for (i, v) in items.iter().enumerate() {
                assert_eq!(*v, i + 100, "workers={workers}");
            }
        }
        let mut empty: Vec<usize> = Vec::new();
        parallel_for_mut(4, &mut empty, |_, _| panic!("no items"));
    }

    #[test]
    fn shard_ranges_cover_aligned_and_ordered() {
        for (n_items, n_shards, align) in [
            (0usize, 3usize, 4usize),
            (1, 3, 4),
            (7, 3, 4),
            (16, 4, 4),
            (17, 4, 4),
            (100, 3, 1),
            (5, 16, 4), // more shards than tiles
        ] {
            let shards = shard_ranges(n_items, n_shards, align);
            assert!(shards.len() <= n_shards.max(1));
            let mut next = 0usize;
            for &(lo, hi) in &shards {
                assert_eq!(lo, next, "contiguous coverage");
                assert!(lo < hi, "non-empty shard");
                assert_eq!(lo % align, 0, "aligned start");
                next = hi;
            }
            assert_eq!(next, n_items, "full coverage n={n_items} s={n_shards} a={align}");
        }
    }
}
