//! Thread-pool substrate (replaces tokio/rayon — DESIGN.md §Substrates).
//!
//! A fixed pool of workers over an mpsc channel, plus a scoped
//! `parallel_for` used by the coordinator's worker pool and benches. On
//! this single-core testbed parallelism buys little, but the coordinator's
//! design (leader + N workers) is preserved faithfully and is exercised by
//! the tests with >1 logical worker.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs run FIFO; `wait_idle` blocks until every
/// submitted job has finished (the barrier used by tests and shutdown).
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(n_workers: usize) -> Self {
        let n = n_workers.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("had-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // A panicking job must not wedge wait_idle.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => return, // channel closed: shutdown
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped parallel map over a slice: applies `f(index, &item)` on `pool`,
/// collecting results in order. Results are produced via per-item slots so
/// no unsafe and no result reordering.
pub fn parallel_map<T, R, F>(pool: &ThreadPool, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send + 'static,
    F: Fn(usize, &T) -> R + Sync,
{
    let slots: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let n_workers = pool.n_workers().min(items.len().max(1));
        let slots = &slots;
        let f = &f;
        let next = &next;
        for _ in 0..n_workers {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    return;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("slot filled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn panicking_job_does_not_wedge() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        pool.submit(|| {});
        pool.wait_idle();
    }

    #[test]
    fn parallel_map_order() {
        let pool = ThreadPool::new(3);
        let items: Vec<usize> = (0..50).collect();
        let out = parallel_map(&pool, &items, |i, &x| i * 1000 + x * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 1000 + i * 2);
        }
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = parallel_map(&pool, &[] as &[usize], |_, &x| x);
        assert!(out.is_empty());
    }
}
