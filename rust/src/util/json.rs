//! Minimal JSON substrate: parser + serializer (replaces serde_json —
//! DESIGN.md §Substrates). Parses the artifact manifest written by
//! python/compile/aot.py and serializes metrics/checkpoint metadata.
//!
//! Full RFC 8259 value model; numbers are f64 (the manifest only contains
//! integers well inside f64's exact range).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// path accessor: `j.at(&["configs", "tinyglue", "model"])`
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= 1;
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte {c:#x}"))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences verbatim
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// -- serialization -----------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["c"]).unwrap().as_str(), Some("x"));
    }

    #[test]
    fn unicode_escapes() {
        // \u escape, BMP
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        // \u surrogate pair
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("😀".into())
        );
        // raw multibyte UTF-8 passthrough
        assert_eq!(Json::parse("\"é😀\"").unwrap(), Json::Str("é😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01a").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",null,true],"obj":{"k":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn integer_display_is_exact() {
        assert_eq!(Json::Num(65536.0).to_string(), "65536");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
