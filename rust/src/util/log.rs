//! Tiny leveled logger substrate (replaces log/env_logger).
//!
//! Level comes from `HAD_LOG` (error|warn|info|debug|trace), default info.
//! Output goes to stderr with elapsed-time stamps so experiment harness
//! stdout stays machine-parseable.

use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static START_MS: AtomicU64 = AtomicU64::new(0);

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

pub fn init_from_env() -> Level {
    let var = std::env::var("HAD_LOG");
    let lvl = match var.as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        Ok(other) => {
            // Warn exactly once instead of silently defaulting, so a typo
            // like HAD_LOG=verbose doesn't masquerade as info forever.
            static WARNED: std::sync::atomic::AtomicBool =
                std::sync::atomic::AtomicBool::new(false);
            if !WARNED.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "[had] HAD_LOG={other:?} is not a level \
                     (error|warn|info|debug|trace); defaulting to info"
                );
            }
            Level::Info
        }
        Err(_) => Level::Info,
    };
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    START_MS.compare_exchange(0, now_ms(), Ordering::Relaxed, Ordering::Relaxed).ok();
    lvl
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        return init_from_env();
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

#[doc(hidden)]
pub fn emit(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    START_MS.compare_exchange(0, now_ms(), Ordering::Relaxed, Ordering::Relaxed).ok();
    let dt = now_ms().saturating_sub(START_MS.load(Ordering::Relaxed));
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>8.3}s {} {}] {}", dt as f64 / 1000.0, tag, module, msg);
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Trace, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    // LEVEL is process-global; serialize the tests that flip it.
    static LEVEL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn levels_ordered() {
        let _g = LEVEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn trace_macro_compiles_and_gates() {
        let _g = LEVEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_level(Level::Info);
        crate::log_trace!("suppressed at info: {}", 1);
        assert!(!enabled(Level::Trace));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info);
    }
}
