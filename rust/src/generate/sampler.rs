//! Token sampling over decode logits: greedy argmax, temperature
//! softmax, top-k truncation, and top-p (nucleus) truncation — all
//! driven by the deterministic `util::rng` generator so a (seed, params,
//! logit-stream) triple always reproduces the same token stream.
//!
//! Determinism is a serving contract here, not a convenience: the
//! continuous-batching coordinator and the direct single-stream engine
//! loop are property-tested to produce identical streams, and that only
//! holds if sampling is a pure function of the per-stream RNG state.
//! Ties in the logits are broken by ascending index everywhere (the
//! same rule `tensor::ops::argmax` uses), so greedy sampling is
//! bit-identical to repeated argmax over the decode logits.

use crate::tensor::ops::argmax;
use crate::util::rng::Rng;

/// Sampling knobs of one generation stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `0.0` means greedy argmax (no RNG draw, so a
    /// greedy stream consumes no randomness at all).
    pub temperature: f32,
    /// Keep only the `top_k` highest logits before sampling (`0` = off).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest logit-descending prefix whose
    /// probability mass reaches `top_p` (`1.0` = off).
    pub top_p: f32,
    /// Seed of the per-stream RNG (streams are independent: concurrent
    /// generations never share randomness).
    pub seed: u64,
}

impl SamplingParams {
    /// Deterministic argmax decoding.
    pub fn greedy() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams::greedy()
    }
}

/// One stream's sampler: params plus its private RNG stream.
#[derive(Clone, Debug)]
pub struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Sampler {
        assert!(
            params.temperature.is_finite() && params.temperature >= 0.0,
            "temperature must be finite and >= 0"
        );
        assert!(
            params.top_p > 0.0 && params.top_p <= 1.0,
            "top_p must be in (0, 1]"
        );
        Sampler { params, rng: Rng::new(params.seed) }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Draw the next token id from one row of logits. Greedy params take
    /// the argmax (first max wins, matching `ops::argmax`); otherwise the
    /// logits are temperature-softmaxed, truncated by top-k then top-p,
    /// and sampled from the renormalized distribution.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        assert!(!logits.is_empty(), "sampling over empty logits");
        if self.params.is_greedy() {
            return argmax(logits);
        }
        let t = self.params.temperature as f64;
        if self.params.top_k == 0 && self.params.top_p >= 1.0 {
            // no truncation active: a plain softmax draw needs no
            // ordering at all — one O(V) pass in index order
            let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
            let weights: Vec<f64> =
                logits.iter().map(|&l| ((l as f64 - m) / t).exp()).collect();
            let total: f64 = weights.iter().sum();
            let mut x = self.rng.next_f64() * total;
            for (i, w) in weights.iter().enumerate() {
                x -= w;
                if x <= 0.0 {
                    return i;
                }
            }
            return weights.len() - 1;
        }
        // truncating path: candidates ordered by logit descending, index
        // ascending on ties — a TOTAL order, so the top-k partition is
        // deterministic. With top_k set, the O(V) partition keeps the
        // per-token cost vocabulary-independent (only the kept k are
        // sorted); the k-free top-p path still sorts all V.
        let by_desc =
            |a: &usize, b: &usize| logits[*b].total_cmp(&logits[*a]).then(a.cmp(b));
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        let keep = if self.params.top_k > 0 {
            self.params.top_k.min(idx.len())
        } else {
            idx.len()
        };
        if keep < idx.len() {
            idx.select_nth_unstable_by(keep - 1, by_desc);
            idx.truncate(keep);
        }
        idx.sort_unstable_by(by_desc);
        // stable softmax over the kept candidates (f64 accumulation so
        // tiny tails don't vanish before the nucleus cut)
        let m = logits[idx[0]] as f64;
        let weights: Vec<f64> = idx[..keep]
            .iter()
            .map(|&i| ((logits[i] as f64 - m) / t).exp())
            .collect();
        let sum: f64 = weights.iter().sum();
        // nucleus: smallest descending prefix reaching top_p of the mass
        let mut cut = keep;
        if self.params.top_p < 1.0 {
            let mut acc = 0.0;
            for (j, w) in weights.iter().enumerate() {
                acc += w / sum;
                if acc >= self.params.top_p as f64 {
                    cut = j + 1;
                    break;
                }
            }
        }
        let total: f64 = weights[..cut].iter().sum();
        let mut x = self.rng.next_f64() * total;
        for (j, w) in weights[..cut].iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return idx[j];
            }
        }
        idx[cut - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(params: SamplingParams, rows: &[Vec<f32>]) -> Vec<usize> {
        let mut s = Sampler::new(params);
        rows.iter().map(|r| s.sample(r)).collect()
    }

    fn random_rows(seed: u64, n: usize, width: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..width).map(|_| rng.normal()).collect())
            .collect()
    }

    #[test]
    fn greedy_is_argmax_bit_for_bit() {
        let rows = random_rows(1, 64, 7);
        let got = stream(SamplingParams::greedy(), &rows);
        let want: Vec<usize> = rows.iter().map(|r| argmax(r)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn greedy_breaks_ties_like_argmax() {
        let mut s = Sampler::new(SamplingParams::greedy());
        assert_eq!(s.sample(&[1.0, 3.0, 3.0, 0.0]), 1, "first max wins");
    }

    #[test]
    fn same_seed_same_stream() {
        let params = SamplingParams { temperature: 0.8, top_k: 4, top_p: 0.9, seed: 42 };
        let rows = random_rows(2, 128, 9);
        assert_eq!(stream(params, &rows), stream(params, &rows));
    }

    #[test]
    fn top_k_restricts_support() {
        let params = SamplingParams { temperature: 1.0, top_k: 2, top_p: 1.0, seed: 3 };
        let mut s = Sampler::new(params);
        let logits = vec![0.0, 5.0, -1.0, 4.0, 0.5];
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 1 || t == 3, "sampled {t} outside the top-2 set");
        }
    }

    #[test]
    fn tiny_top_p_collapses_to_greedy() {
        // a nucleus smaller than the top token's mass keeps only it
        let params = SamplingParams { temperature: 1.0, top_k: 0, top_p: 1e-6, seed: 4 };
        let mut s = Sampler::new(params);
        let logits = vec![0.0, 3.0, 1.0];
        for _ in 0..100 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn high_temperature_reaches_the_tail() {
        let params = SamplingParams { temperature: 10.0, top_k: 0, top_p: 1.0, seed: 5 };
        let mut s = Sampler::new(params);
        let logits = vec![0.0, 1.0, 0.5];
        let mut seen = [false; 3];
        for _ in 0..500 {
            seen[s.sample(&logits)] = true;
        }
        assert!(seen.iter().all(|&b| b), "hot sampling must cover the support");
    }

    #[test]
    fn greedy_consumes_no_randomness() {
        // interleaving greedy draws must not perturb a sampled stream's
        // RNG — greedy never touches it
        let mut s = Sampler::new(SamplingParams::greedy());
        let before = s.rng.clone();
        s.sample(&[1.0, 2.0]);
        let mut a = before;
        let mut b = s.rng;
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    #[should_panic(expected = "top_p")]
    fn rejects_zero_top_p() {
        Sampler::new(SamplingParams { temperature: 1.0, top_k: 0, top_p: 0.0, seed: 0 });
    }
}
