//! Autoregressive generation subsystem: token-feedback decoding with
//! deterministic sampling and streaming output.
//!
//! Until this module the stack only answered classification-style turns
//! — one logit vector per submitted prefix. `generate` closes the token
//! feedback loop over the CPU serving backend: a [`GenerateRequest`]
//! (prompt, `max_new_tokens`, stop-token set, [`SamplingParams`]) drives
//! repeated one-token decode steps through `serve::HadBackend::decode`,
//! each step appending the sampled token's K/V into the session's
//! `kvcache::LayeredKv` page chains so the next step decodes exactly one
//! suffix token — and follow-up turns resume warm from everything the
//! stream generated.
//!
//! Two execution modes share [`GenState`], the one-step state machine:
//!
//! * [`engine::generate`] — the direct single-stream loop with a
//!   per-token callback (benches, oracles, embedded use).
//! * `coordinator::Server::submit_generate` — continuous batching: the
//!   scheduler holds many live streams, steps each one once per tick,
//!   admits new streams (prefill) in the same pass, and delivers
//!   [`StreamEvent`]s over a channel as tokens are produced.
//!
//! Sampling ([`sampler::Sampler`]) is greedy argmax, temperature,
//! top-k, or top-p, all driven per-stream by the deterministic
//! `util::rng` generator: the same seed and params always reproduce the
//! same token stream, and greedy generation is bit-identical to repeated
//! argmax over the decode logits. Streams retire with an explicit
//! [`StopReason`] — stop token, token budget, or serving pressure
//! ([`StopReason::Budget`] when the KV chain would outgrow the page
//! pool's byte budget or the router's context cap; the generated prefix
//! survives, the session is never reset mid-stream).

pub mod engine;
pub mod sampler;

pub use engine::{
    generate, GenLimits, GenState, GenerateOutput, GenerateRequest, StepOut, StopReason,
    StreamEvent,
};
pub use sampler::{Sampler, SamplingParams};
