//! The autoregressive generation loop over `serve::HadBackend::decode`.
//!
//! [`GenState`] is the unit both execution modes share: it owns the full
//! token sequence (admitted context + generated suffix), the stream's
//! [`Sampler`], and the stop conditions, and advances by exactly one
//! decode-and-sample step per [`GenState::step`] call. The direct
//! single-stream loop ([`generate`]) just calls `step` until the stream
//! retires; the coordinator's continuous-batching scheduler interleaves
//! `step` calls of many live streams, one step per stream per tick —
//! because each step is a pure function of (backend weights, stream
//! state, stream KV), the two modes are token-for-token identical, and
//! the property suite asserts exactly that.
//!
//! ## One step
//!
//! With `tokens[..n]` the sequence so far and `kv` holding a decoded
//! prefix of it, a step decodes the non-resident suffix (one token in
//! steady state; the whole context on the first step — the prefill),
//! captures logits at `n`, samples token `n+1` from them, and appends it
//! to the sequence. The sampled token's own K/V enter `kv` on the NEXT
//! step's decode, so the cache always holds exactly the positions whose
//! logits have been produced.
//!
//! ## Budgets
//!
//! [`GenLimits`] bounds a stream in both axes the serving stack
//! enforces: total sequence length (the router's largest context) and
//! resident KV bytes (the page pool's budget, computed EXACTLY via
//! [`LayeredKv::bytes_at`] before any page is allocated). A stream that
//! would cross either limit retires with [`StopReason::Budget`] — the
//! generated prefix stays valid and the session is never reset
//! mid-stream.
//!
//! Note on the token space: the distilled HAD model ends in a
//! classification head, so generation feeds class ids (`< n_classes`)
//! back as input tokens — the head doubles as a (small) next-token head.
//! An LM checkpoint with `head_w` tied to `tok_emb` drops in without any
//! change here.

use crate::binary::attention::Scratch;
use crate::generate::sampler::{Sampler, SamplingParams};
use crate::kvcache::LayeredKv;
use crate::serve::{AttnPath, HadBackend};

/// One generation request: the prompt extends the session context, then
/// up to `max_new_tokens` tokens are generated until a stop token (which
/// is emitted, then ends the stream) or a budget limit.
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Tokens that end the stream when generated (EOS set; may be empty).
    pub stop_tokens: Vec<i32>,
    pub sampling: SamplingParams,
}

impl GenerateRequest {
    /// Greedy request with no stop tokens (bench/demo shorthand).
    pub fn greedy(prompt: Vec<i32>, max_new_tokens: usize) -> GenerateRequest {
        GenerateRequest {
            prompt,
            max_new_tokens,
            stop_tokens: Vec::new(),
            sampling: SamplingParams::greedy(),
        }
    }
}

/// Why a stream retired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// A stop token was generated (it is included in the stream).
    StopToken,
    /// `max_new_tokens` were generated.
    MaxTokens,
    /// Context length or KV byte budget exhausted — the stream keeps
    /// everything generated so far instead of resetting the session.
    Budget,
    /// The client dropped its receiver mid-stream, or fell so far behind
    /// that its bounded event channel filled (coordinator only).
    Disconnected,
    /// The stream's wall-clock deadline (`GenLimits::deadline_ms`) or
    /// the admission queue's TTL elapsed before the stream finished.
    DeadlineExceeded,
    /// The stream's decode step panicked; the stream retires with the
    /// tokens generated so far and its KV is discarded (coordinator
    /// only — the panic is isolated, the server keeps running).
    Error,
    /// The server shut down and drained the stream before it finished
    /// (coordinator only).
    Shutdown,
}

impl StopReason {
    /// Every variant, for exhaustive wire-code round-trip tests.
    pub const ALL: [StopReason; 7] = [
        StopReason::StopToken,
        StopReason::MaxTokens,
        StopReason::Budget,
        StopReason::Disconnected,
        StopReason::DeadlineExceeded,
        StopReason::Error,
        StopReason::Shutdown,
    ];

    /// Stable machine-readable code carried in streamed `done` events
    /// over HTTP. Part of the wire contract: never rename a code —
    /// clients and `scripts/validate_net.py` key off these, not the
    /// human-facing `Display` strings.
    pub fn wire_code(self) -> &'static str {
        match self {
            StopReason::StopToken => "stop_token",
            StopReason::MaxTokens => "max_tokens",
            StopReason::Budget => "budget",
            StopReason::Disconnected => "disconnected",
            StopReason::DeadlineExceeded => "deadline_exceeded",
            StopReason::Error => "error",
            StopReason::Shutdown => "shutdown",
        }
    }

    /// Inverse of [`StopReason::wire_code`] (client-side decoding).
    pub fn from_wire_code(code: &str) -> Option<StopReason> {
        StopReason::ALL.into_iter().find(|r| r.wire_code() == code)
    }
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StopReason::StopToken => write!(f, "stop-token"),
            StopReason::MaxTokens => write!(f, "max-tokens"),
            StopReason::Budget => write!(f, "budget"),
            StopReason::Disconnected => write!(f, "disconnected"),
            StopReason::DeadlineExceeded => write!(f, "deadline-exceeded"),
            StopReason::Error => write!(f, "error"),
            StopReason::Shutdown => write!(f, "shutdown"),
        }
    }
}

/// Serving-side bounds a stream must stay inside while it grows.
#[derive(Clone, Copy, Debug)]
pub struct GenLimits {
    /// Longest total sequence (context + generated) a stream may reach —
    /// the coordinator uses its router's largest bucket, so a
    /// Budget-stopped stream's history stays routable for its next turn.
    pub max_total_tokens: usize,
    /// Resident-byte cap of the stream's `LayeredKv` — the coordinator
    /// uses the page pool's byte budget, so a stream never checks an
    /// over-budget state back in.
    ///
    /// This is a PER-STREAM bound and must stay a constant per stream —
    /// deriving it from other live streams' sizes would make a stream's
    /// Budget stop depend on scheduling interleaving, breaking the
    /// coordinator-equals-direct-engine determinism contract. The
    /// aggregate pool budget is enforced separately at ADMISSION: the
    /// scheduler reserves each stream's worst-case residency
    /// (`bytes_at(context + max_new_tokens)`, capped at this limit)
    /// before activating it, so the sum of checked-out bytes never
    /// exceeds the pool budget without touching per-stream limits.
    pub kv_budget_bytes: usize,
    /// Wall-clock deadline per stream, measured from submission: a
    /// stream still running after this many milliseconds retires with
    /// [`StopReason::DeadlineExceeded`]. `u64::MAX` disables it.
    /// Checked between steps, so one in-flight decode can overshoot.
    pub deadline_ms: u64,
}

impl GenLimits {
    /// No serving bounds (direct engine runs, tests).
    pub fn unbounded() -> GenLimits {
        GenLimits {
            max_total_tokens: usize::MAX,
            kv_budget_bytes: usize::MAX,
            deadline_ms: u64::MAX,
        }
    }
}

/// One token event of a generation stream, as delivered to clients of
/// `coordinator::Server::submit_generate` (and mirrored by the direct
/// loop's callback).
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// The `index`-th generated token (0-based) of the stream.
    Token { index: usize, token: i32 },
    /// The stream retired; `generated` tokens were emitted in total.
    Done { reason: StopReason, generated: usize, ttft_us: u128 },
}

/// Outcome of one [`GenState::step`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepOut {
    /// A token was produced; the stream continues.
    Token(i32),
    /// A token was produced and it finished the stream.
    Last(i32, StopReason),
    /// No token was produced; the stream retires.
    Done(StopReason),
}

/// A live generation stream: the full token sequence, its sampler, and
/// the stop conditions. Pure state — the backend and KV are passed into
/// each step, so the coordinator can hold many of these and shard steps
/// across workers.
#[derive(Clone, Debug)]
pub struct GenState {
    /// Admitted context followed by the generated suffix.
    tokens: Vec<i32>,
    context_len: usize,
    sampler: Sampler,
    max_new_tokens: usize,
    stop_tokens: Vec<i32>,
}

impl GenState {
    /// Build a stream over `history` (the session's prior context; empty
    /// for a fresh stream) extended by the request's prompt.
    pub fn new(history: Vec<i32>, req: &GenerateRequest) -> GenState {
        let mut tokens = history;
        tokens.extend_from_slice(&req.prompt);
        assert!(!tokens.is_empty(), "generation needs a non-empty context");
        let context_len = tokens.len();
        GenState {
            tokens,
            context_len,
            sampler: Sampler::new(req.sampling),
            max_new_tokens: req.max_new_tokens,
            stop_tokens: req.stop_tokens.clone(),
        }
    }

    /// Full sequence: context followed by everything generated so far.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Length of the admitted context (history + prompt).
    pub fn context_len(&self) -> usize {
        self.context_len
    }

    /// The generated suffix.
    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.context_len..]
    }

    pub fn n_generated(&self) -> usize {
        self.tokens.len() - self.context_len
    }

    /// The request's generation cap (used by the coordinator to reserve
    /// the stream's worst-case KV residency at admission).
    pub fn max_new_tokens(&self) -> usize {
        self.max_new_tokens
    }

    /// Decode up to `chunk` not-yet-resident context tokens into `kv`
    /// WITHOUT sampling — a resumable slice of the prefill, so a long
    /// admission contributes bounded work per scheduler tick instead of
    /// stalling every active stream. Returns `Some(reason)` if the
    /// stream should retire (same budget checks as [`GenState::step`],
    /// run before any page is allocated), `None` after decoding a chunk.
    /// Callers switch to `step` once `kv.len() + 1 >= tokens.len()`;
    /// causal decode is chunk-split invariant, so the resulting stream
    /// is bit-identical to an unchunked prefill.
    pub fn prefill_partial(
        &self,
        backend: &HadBackend,
        kv: &mut LayeredKv,
        limits: &GenLimits,
        chunk: usize,
        path: AttnPath,
        scratch: &mut Scratch,
    ) -> Option<StopReason> {
        if self.n_generated() >= self.max_new_tokens {
            return Some(StopReason::MaxTokens);
        }
        let len = self.tokens.len();
        if len >= limits.max_total_tokens || kv.bytes_at(len) > limits.kv_budget_bytes {
            return Some(StopReason::Budget);
        }
        let end = (kv.len() + chunk.max(1)).min(len - 1);
        debug_assert!(end > kv.len(), "prefill_partial on a warm stream");
        let mut s = crate::obs::span("prefill_chunk");
        s.set_payload((end - kv.len()) as u64);
        // empty capture list: pure KV production, no logits
        backend.decode_in(kv, &self.tokens[..end], &[], path, scratch);
        None
    }

    /// Advance the stream by one decode-and-sample step (see module
    /// docs). Budget checks run BEFORE the decode so a retiring stream
    /// never grows `kv` past the limits it is checked against.
    pub fn step(
        &mut self,
        backend: &HadBackend,
        kv: &mut LayeredKv,
        limits: &GenLimits,
        path: AttnPath,
        scratch: &mut Scratch,
    ) -> StepOut {
        if self.n_generated() >= self.max_new_tokens {
            // only reachable with max_new_tokens == 0 (or a step after
            // Last, which callers do not issue)
            return StepOut::Done(StopReason::MaxTokens);
        }
        let len = self.tokens.len();
        // `>=`, not `>`: the step would decode `len` positions and push a
        // token, leaving `len + 1` total — stopping at `len == max` keeps
        // a Budget-stopped stream's history within the cap (routable by
        // the bucket that admitted it) instead of one past it
        if len >= limits.max_total_tokens || kv.bytes_at(len) > limits.kv_budget_bytes {
            return StepOut::Done(StopReason::Budget);
        }
        // the first step of a cold/partially-resident stream prefils the
        // whole context; every later step decodes exactly one position
        let prefill = len.saturating_sub(kv.len()) > 1;
        let logits = {
            let mut s =
                crate::obs::span(if prefill { "prefill" } else { "decode_step" });
            s.set_payload(len.saturating_sub(kv.len()) as u64);
            let (mut caps, _stats) = backend.decode_in(kv, &self.tokens, &[len], path, scratch);
            caps.pop().expect("one capture requested").logits
        };
        let next = {
            let _s = crate::obs::span("sample");
            self.sampler.sample(&logits) as i32
        };
        self.tokens.push(next);
        if self.stop_tokens.contains(&next) {
            StepOut::Last(next, StopReason::StopToken)
        } else if self.n_generated() >= self.max_new_tokens {
            StepOut::Last(next, StopReason::MaxTokens)
        } else {
            StepOut::Token(next)
        }
    }
}

/// A finished stream's output.
#[derive(Clone, Debug)]
pub struct GenerateOutput {
    /// Generated tokens only (the context is the caller's).
    pub tokens: Vec<i32>,
    pub reason: StopReason,
}

/// The direct single-stream engine loop: run `req` to completion over
/// `kv`, invoking `on_token(index, token)` as each token is produced
/// (the streaming callback). `history` is the context the prompt
/// extends; pass `&[]` for a fresh stream. A `kv` already holding a
/// decoded prefix of `history + prompt` resumes warm, exactly like a
/// session turn.
pub fn generate(
    backend: &HadBackend,
    kv: &mut LayeredKv,
    history: &[i32],
    req: &GenerateRequest,
    limits: &GenLimits,
    mut on_token: impl FnMut(usize, i32),
) -> GenerateOutput {
    let mut state = GenState::new(history.to_vec(), req);
    let mut scratch = Scratch::default();
    let started = std::time::Instant::now();
    loop {
        if limits.deadline_ms != u64::MAX
            && started.elapsed().as_millis() as u64 >= limits.deadline_ms
        {
            return GenerateOutput {
                tokens: state.generated().to_vec(),
                reason: StopReason::DeadlineExceeded,
            };
        }
        let index = state.n_generated();
        match state.step(backend, kv, limits, AttnPath::Kernel, &mut scratch) {
            StepOut::Token(t) => on_token(index, t),
            StepOut::Last(t, reason) => {
                on_token(index, t);
                return GenerateOutput { tokens: state.generated().to_vec(), reason };
            }
            StepOut::Done(reason) => {
                return GenerateOutput { tokens: state.generated().to_vec(), reason };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvCacheConfig;
    use crate::runtime::{ConfigEntry, ModelCfg};
    use crate::serve::{token_config_entry, ServeModel};
    use crate::tensor::ops::argmax;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ConfigEntry {
        token_config_entry(
            "gen_tiny",
            ModelCfg {
                n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 48,
                n_classes: 4, vocab: 24, input_dim: 0, n_top: 6, block_q: 16,
            },
        )
    }

    fn backend() -> HadBackend {
        let cfg = tiny_cfg();
        let model = ServeModel::random(&cfg, 0x9E4E).unwrap();
        HadBackend::new(model, &KvCacheConfig { page_tokens: 4, ..Default::default() })
    }

    fn toks(seed: u64, n: usize) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(24) as i32).collect()
    }

    #[test]
    fn stop_wire_codes_round_trip_and_stay_stable() {
        for r in StopReason::ALL {
            assert_eq!(StopReason::from_wire_code(r.wire_code()), Some(r));
        }
        // pin the published strings — renaming one is a breaking change
        assert_eq!(StopReason::StopToken.wire_code(), "stop_token");
        assert_eq!(StopReason::MaxTokens.wire_code(), "max_tokens");
        assert_eq!(StopReason::Budget.wire_code(), "budget");
        assert_eq!(StopReason::Disconnected.wire_code(), "disconnected");
        assert_eq!(StopReason::DeadlineExceeded.wire_code(), "deadline_exceeded");
        assert_eq!(StopReason::Error.wire_code(), "error");
        assert_eq!(StopReason::Shutdown.wire_code(), "shutdown");
        assert_eq!(StopReason::from_wire_code("nonsense"), None);
        let codes: std::collections::BTreeSet<_> =
            StopReason::ALL.iter().map(|r| r.wire_code()).collect();
        assert_eq!(codes.len(), StopReason::ALL.len(), "codes must be distinct");
    }

    #[test]
    fn greedy_equals_repeated_argmax_over_decode() {
        let b = backend();
        let prompt = toks(1, 9);
        let req = GenerateRequest::greedy(prompt.clone(), 7);
        let mut kv = b.fresh_kv();
        let out = generate(&b, &mut kv, &[], &req, &GenLimits::unbounded(), |_, _| {});
        assert_eq!(out.reason, StopReason::MaxTokens);
        assert_eq!(out.tokens.len(), 7);
        // oracle: the raw decode + argmax feedback loop
        let mut seq = prompt;
        let mut okv = b.fresh_kv();
        for &got in &out.tokens {
            let (caps, _) = b.decode(&mut okv, &seq, &[seq.len()]);
            let want = argmax(&caps.last().unwrap().logits) as i32;
            assert_eq!(got, want, "greedy generation must equal repeated argmax");
            seq.push(want);
        }
    }

    #[test]
    fn each_step_decodes_one_suffix_token() {
        let b = backend();
        let req = GenerateRequest::greedy(toks(2, 6), 5);
        let mut state = GenState::new(Vec::new(), &req);
        let mut kv = b.fresh_kv();
        let mut scratch = Scratch::default();
        // prefill step decodes the whole prompt
        state.step(&b, &mut kv, &GenLimits::unbounded(), AttnPath::Kernel, &mut scratch);
        assert_eq!(kv.len(), 6);
        // every later step decodes exactly the one appended token
        for expect in 7..=9 {
            state.step(&b, &mut kv, &GenLimits::unbounded(), AttnPath::Kernel, &mut scratch);
            assert_eq!(kv.len(), expect, "suffix-only decode per step");
        }
        assert_eq!(state.n_generated(), 4);
    }

    #[test]
    fn stop_token_ends_the_stream_and_is_emitted() {
        let b = backend();
        let prompt = toks(3, 8);
        // find what greedy generates first, then make THAT the stop token
        let first = {
            let req = GenerateRequest::greedy(prompt.clone(), 1);
            let mut kv = b.fresh_kv();
            generate(&b, &mut kv, &[], &req, &GenLimits::unbounded(), |_, _| {}).tokens[0]
        };
        let req = GenerateRequest {
            prompt: prompt.clone(),
            max_new_tokens: 10,
            stop_tokens: vec![first],
            sampling: SamplingParams::greedy(),
        };
        let mut kv = b.fresh_kv();
        let mut streamed = Vec::new();
        let out = generate(&b, &mut kv, &[], &req, &GenLimits::unbounded(), |i, t| {
            streamed.push((i, t));
        });
        assert_eq!(out.reason, StopReason::StopToken);
        assert_eq!(out.tokens, vec![first], "stop token is included, then the stream ends");
        assert_eq!(streamed, vec![(0, first)], "callback saw exactly the emitted stream");
    }

    #[test]
    fn byte_budget_retires_with_budget_before_exceeding() {
        let b = backend();
        let prompt = toks(4, 4);
        // geometry: 2 layers x 2 heads, d_head 16, page_tokens 4
        // -> one page costs 4 * (8 + 64) = 288 B per chain, 4 chains
        let kv0 = b.fresh_kv();
        let two_pages = kv0.bytes_at(8);
        assert_eq!(two_pages, 2 * 4 * 288);
        let limits = GenLimits { kv_budget_bytes: two_pages, ..GenLimits::unbounded() };
        let mut kv = b.fresh_kv();
        let req = GenerateRequest::greedy(prompt, 100);
        let out = generate(&b, &mut kv, &[], &req, &limits, |_, _| {});
        assert_eq!(out.reason, StopReason::Budget);
        // steps may decode while len <= 8; the step at len 9 retires, so
        // exactly tokens 5..=9 were sampled (5 generated), kv holds 8
        assert_eq!(out.tokens.len(), 5);
        assert_eq!(kv.len(), 8);
        assert!(kv.bytes() <= two_pages, "the stream never grew past its budget");
    }

    #[test]
    fn context_cap_retires_with_budget() {
        let b = backend();
        let limits = GenLimits { max_total_tokens: 10, ..GenLimits::unbounded() };
        let mut kv = b.fresh_kv();
        let mut state = GenState::new(Vec::new(), &GenerateRequest::greedy(toks(5, 6), 100));
        let mut out_tokens = Vec::new();
        let mut scratch = Scratch::default();
        let reason = loop {
            match state.step(&b, &mut kv, &limits, AttnPath::Kernel, &mut scratch) {
                StepOut::Token(t) => out_tokens.push(t),
                StepOut::Last(t, r) => {
                    out_tokens.push(t);
                    break r;
                }
                StepOut::Done(r) => break r,
            }
        };
        assert_eq!(reason, StopReason::Budget);
        // decodes allowed while len < 10 (len 6..=9) -> 4 tokens, and the
        // final sequence sits exactly AT the cap, still routable
        assert_eq!(out_tokens.len(), 4);
        assert_eq!(state.tokens().len(), 10);
    }

    #[test]
    fn zero_budget_generates_nothing() {
        let b = backend();
        let req = GenerateRequest::greedy(toks(6, 5), 0);
        let mut kv = b.fresh_kv();
        let out = generate(&b, &mut kv, &[], &req, &GenLimits::unbounded(), |_, _| {
            panic!("no token may be emitted")
        });
        assert_eq!(out.reason, StopReason::MaxTokens);
        assert!(out.tokens.is_empty());
        assert!(kv.is_empty(), "no decode ran");
    }

    #[test]
    fn warm_history_resume_matches_cold() {
        // generating after a prior turn (history resident in kv) must
        // equal generating over the concatenated context from scratch
        let b = backend();
        let history = toks(7, 10);
        let prompt = toks(8, 4);
        let req = GenerateRequest::greedy(prompt.clone(), 4);

        let mut warm_kv = b.fresh_kv();
        b.decode(&mut warm_kv, &history, &[history.len()]); // prior turn
        let warm = generate(&b, &mut warm_kv, &history, &req, &GenLimits::unbounded(), |_, _| {});

        let mut cold_kv = b.fresh_kv();
        let cold = generate(&b, &mut cold_kv, &history, &req, &GenLimits::unbounded(), |_, _| {});
        assert_eq!(warm.tokens, cold.tokens, "warm resume must not change the stream");
    }

    #[test]
    #[should_panic(expected = "non-empty context")]
    fn rejects_empty_context() {
        let req = GenerateRequest::greedy(Vec::new(), 3);
        GenState::new(Vec::new(), &req);
    }
}
