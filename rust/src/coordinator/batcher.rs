//! Dynamic batcher: per-bucket queues with a size-or-deadline flush
//! policy (the standard continuous-batching admission scheme, static
//! shapes per bucket because PJRT executables are shape-specialized).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::request::{GenAdmit, Request};
use crate::coordinator::router::Bucket;

/// Flush policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// flush as soon as this many requests are queued (the bucket's batch)
    pub max_batch: usize,
    /// flush a non-empty queue after this long even if not full
    pub max_wait: Duration,
    /// admission bound per bucket (backpressure)
    pub queue_cap: usize,
    /// worker budget for the CPU kernel pass backing batch execution: a
    /// drained batch's session requests are sharded across this many
    /// scoped threads for blocked XNOR-popcount scoring (per-request
    /// kernel timing lands in Metrics)
    pub kernel_workers: usize,
    /// continuous-batching ticket count: how many generation streams may
    /// be live at once. Each live stream contributes one decode step per
    /// scheduler tick; admitted streams beyond this wait in the
    /// `StreamQueue` until a ticket frees up.
    pub max_streams: usize,
    /// longest not-yet-resident context suffix a stream may decode in a
    /// single scheduler tick: longer prefills are split into chunks of
    /// this many tokens so one long admission cannot stall every active
    /// stream for a whole context's worth of decode (fair ticks)
    pub prefill_chunk: usize,
    /// per-stream event channel bound: a client that falls this many
    /// undelivered `StreamEvent`s behind is treated as disconnected
    /// (slow-reader policy) instead of buffering without bound
    pub stream_event_cap: usize,
    /// how long an admitted stream may wait un-activated in the
    /// `StreamQueue` before it is retired with
    /// `StopReason::DeadlineExceeded`; once the queue HEAD is older than
    /// this, new submissions are rejected with `RejectReason::Timeout`
    pub queue_ttl: Duration,
    /// wall-clock deadline per stream (submission -> retirement), carried
    /// into `GenLimits::deadline_ms`; `u64::MAX` disables it
    pub stream_deadline_ms: u64,
    /// on shutdown, how long in-flight streams may keep stepping before
    /// the scheduler force-retires them with `StopReason::Shutdown`
    pub drain_grace: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_cap: 256,
            kernel_workers: 2,
            max_streams: 8,
            prefill_chunk: 64,
            stream_event_cap: 256,
            queue_ttl: Duration::from_secs(30),
            stream_deadline_ms: u64::MAX,
            drain_grace: Duration::from_secs(5),
        }
    }
}

/// One bucket's admission queue.
pub struct BucketQueue {
    pub bucket: Bucket,
    pub policy: BatchPolicy,
    queue: VecDeque<Request>,
    oldest: Option<Instant>,
}

impl BucketQueue {
    pub fn new(bucket: Bucket, mut policy: BatchPolicy) -> BucketQueue {
        policy.max_batch = policy.max_batch.min(bucket.batch);
        BucketQueue { bucket, policy, queue: VecDeque::new(), oldest: None }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Try to admit; returns the request back on overflow (backpressure).
    pub fn push(&mut self, req: Request) -> Result<(), Request> {
        if self.queue.len() >= self.policy.queue_cap {
            return Err(req);
        }
        if self.queue.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.queue.push_back(req);
        Ok(())
    }

    /// Should the queue flush now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.policy.max_batch.min(self.bucket.batch) {
            return true;
        }
        match self.oldest {
            Some(t) => now.duration_since(t) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Time until the deadline flush would fire (for scheduler sleeps).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        if self.queue.is_empty() {
            return None;
        }
        let t = self.oldest?;
        let elapsed = now.duration_since(t);
        Some(self.policy.max_wait.saturating_sub(elapsed))
    }

    /// Take up to one bucket-batch of requests.
    pub fn drain_batch(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.bucket.batch);
        let out: Vec<Request> = self.queue.drain(..n).collect();
        self.oldest = if self.queue.is_empty() {
            None
        } else {
            Some(Instant::now())
        };
        out
    }
}

/// Bounded FIFO admission queue for generation streams:
/// `Server::submit_generate` pushes, the scheduler pops streams into its
/// active set as continuous-batching tickets (`BatchPolicy::max_streams`)
/// free up. Overflow returns the admission for side-effect-free
/// rejection, mirroring `BucketQueue::push`.
pub struct StreamQueue {
    queue: VecDeque<GenAdmit>,
    cap: usize,
}

impl StreamQueue {
    pub fn new(cap: usize) -> StreamQueue {
        StreamQueue { queue: VecDeque::new(), cap: cap.max(1) }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// At capacity: the next `push` would be rejected. Admission checks
    /// this up front so destructive side effects (context-overflow
    /// restarts) never fire on a turn that is then rejected.
    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.cap
    }

    /// Try to admit; returns the stream back on overflow (backpressure).
    pub fn push(&mut self, admit: GenAdmit) -> Result<(), GenAdmit> {
        if self.queue.len() >= self.cap {
            return Err(admit);
        }
        self.queue.push_back(admit);
        Ok(())
    }

    /// Next waiting stream, FIFO.
    pub fn pop(&mut self) -> Option<GenAdmit> {
        self.queue.pop_front()
    }

    /// The queue head (next stream to activate), if any. Admission uses
    /// its age to detect a stalled scheduler (`RejectReason::Timeout`).
    pub fn front(&self) -> Option<&GenAdmit> {
        self.queue.front()
    }

    /// Take every queued stream (drain shutdown: each is retired with an
    /// explicit reason instead of being silently dropped).
    pub fn drain_all(&mut self) -> Vec<GenAdmit> {
        self.queue.drain(..).collect()
    }
}

/// Assemble a padded (batch, n_ctx) i32 tensor from requests. Slots beyond
/// the real requests repeat row 0 (keeps logits well-defined; their
/// outputs are discarded). Returns (flat tokens, real count).
pub fn assemble_padded(
    requests: &[Request],
    n_ctx: usize,
    batch: usize,
    pad_token: i32,
) -> (Vec<i32>, usize) {
    assert!(!requests.is_empty() && requests.len() <= batch);
    let mut xs = vec![pad_token; batch * n_ctx];
    for (b, req) in requests.iter().enumerate() {
        let n = req.tokens.len().min(n_ctx);
        xs[b * n_ctx..b * n_ctx + n].copy_from_slice(&req.tokens[..n]);
    }
    // duplicate row 0 into unused slots
    let row0: Vec<i32> = xs[..n_ctx].to_vec();
    for b in requests.len()..batch {
        xs[b * n_ctx..(b + 1) * n_ctx].copy_from_slice(&row0);
    }
    (xs, requests.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, len: usize) -> Request {
        let (tx, _rx) = channel();
        Request {
            id,
            tokens: vec![1; len],
            arrival: Instant::now(),
            reply: tx,
            session: None,
            trace: crate::obs::SpanId::NONE,
        }
    }

    fn bucket() -> Bucket {
        Bucket { config: "longqa_128".into(), n_ctx: 128, batch: 4 }
    }

    #[test]
    fn default_policy_backs_execution_with_workers() {
        let p = BatchPolicy::default();
        assert!(p.kernel_workers >= 1, "batch execution needs a worker pool");
        assert!(p.max_streams >= 1, "continuous batching needs at least one ticket");
        // queue knobs unchanged by the kernel pool addition
        assert_eq!(p.max_batch, 8);
        assert_eq!(p.queue_cap, 256);
        // robustness knobs: bounded prefill work, bounded event buffers,
        // finite queue TTL, no per-stream deadline unless asked for
        assert!(p.prefill_chunk >= 1);
        assert!(p.stream_event_cap >= 1);
        assert!(p.queue_ttl > Duration::ZERO);
        assert_eq!(p.stream_deadline_ms, u64::MAX);
        assert!(p.drain_grace > Duration::ZERO);
    }

    #[test]
    fn stream_queue_is_fifo_and_bounded() {
        use crate::generate::{GenState, GenerateRequest};
        let admit = |id: u64| {
            let (tx, _rx) = std::sync::mpsc::sync_channel(8);
            GenAdmit {
                id,
                session: id,
                state: GenState::new(vec![1, 2], &GenerateRequest::greedy(vec![3], 4)),
                reply: tx,
                arrival: Instant::now(),
                admitted_len: 3,
                trace: crate::obs::SpanId::NONE,
            }
        };
        let mut q = StreamQueue::new(2);
        assert!(q.is_empty());
        q.push(admit(0)).map_err(|_| ()).unwrap();
        q.push(admit(1)).map_err(|_| ()).unwrap();
        let back = q.push(admit(2));
        assert_eq!(back.map(|_| ()).unwrap_err().id, 2, "overflow hands the stream back");
        assert_eq!(q.pop().unwrap().id, 0, "FIFO");
        assert_eq!(q.pop().unwrap().id, 1);
        assert!(q.pop().is_none());

        // front() peeks without consuming; drain_all() empties the queue
        let mut q = StreamQueue::new(4);
        q.push(admit(7)).map_err(|_| ()).unwrap();
        q.push(admit(8)).map_err(|_| ()).unwrap();
        assert_eq!(q.front().unwrap().id, 7);
        assert_eq!(q.len(), 2, "front() does not consume");
        let drained = q.drain_all();
        assert_eq!(drained.iter().map(|a| a.id).collect::<Vec<_>>(), vec![7, 8]);
        assert!(q.is_empty());
    }

    #[test]
    fn flushes_when_full() {
        let mut q = BucketQueue::new(bucket(), BatchPolicy::default());
        let now = Instant::now();
        for i in 0..3 {
            q.push(req(i, 64)).unwrap();
        }
        assert!(!q.ready(now));
        q.push(req(3, 64)).unwrap();
        assert!(q.ready(Instant::now()));
        let batch = q.drain_batch();
        assert_eq!(batch.len(), 4);
        assert!(q.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut q = BucketQueue::new(
            bucket(),
            BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
        );
        q.push(req(0, 64)).unwrap();
        assert!(!q.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(2));
        assert!(q.ready(Instant::now()));
    }

    #[test]
    fn backpressure_rejects() {
        let mut q = BucketQueue::new(
            bucket(),
            BatchPolicy { queue_cap: 2, ..Default::default() },
        );
        q.push(req(0, 8)).unwrap();
        q.push(req(1, 8)).unwrap();
        assert!(q.push(req(2, 8)).is_err());
    }

    #[test]
    fn drain_respects_bucket_batch() {
        let mut q = BucketQueue::new(bucket(), BatchPolicy { queue_cap: 100, ..Default::default() });
        for i in 0..10 {
            q.push(req(i, 8)).unwrap();
        }
        let b = q.drain_batch();
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 6);
        // FIFO order preserved
        assert_eq!(b[0].id, 0);
        assert_eq!(b[3].id, 3);
    }

    #[test]
    fn assemble_pads_and_duplicates() {
        let reqs = vec![req(0, 5), req(1, 200)];
        let (xs, real) = assemble_padded(&reqs, 128, 4, 0);
        assert_eq!(real, 2);
        assert_eq!(xs.len(), 4 * 128);
        // row 0: 5 tokens then pad
        assert_eq!(xs[4], 1);
        assert_eq!(xs[5], 0);
        // row 1: truncated to n_ctx
        assert!(xs[128..256].iter().all(|&t| t == 1));
        // rows 2,3 = row 0
        assert_eq!(&xs[256..384], &xs[..128]);
        assert_eq!(&xs[384..512], &xs[..128]);
    }
}
