//! The serving engine: router + dynamic batcher + PJRT engine thread.
//!
//! Architecture (single PJRT device, per DESIGN.md):
//!
//!   clients --submit()--> shared bucket queues --scheduler thread-->
//!     assemble padded batch --> EngineHandle (PJRT thread) -->
//!     logits --> per-request reply channels ; Metrics throughout
//!
//! Backpressure: bounded per-bucket admission queues; `submit` rejects
//! with `QueueFull` rather than queueing unboundedly.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::batcher::{assemble_padded, BatchPolicy, BucketQueue};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{RejectReason, Request, Response};
use crate::coordinator::router::Router;
use crate::log_info;
use crate::log_warn;
use crate::model::Checkpoint;
use crate::runtime::{EngineHandle, HostTensor, Manifest};
use crate::tensor::ops::argmax;

/// Weights + calibration served for one bucket.
#[derive(Clone)]
pub struct ServingModel {
    pub params: Vec<HostTensor>,
    pub sigma_q: Vec<f32>,
    pub sigma_k: Vec<f32>,
    pub n_top: f32,
    /// forward artifact name within the bucket's config ("fwd_had", ...)
    pub fwd: String,
}

impl ServingModel {
    pub fn from_checkpoint(ckpt: &Checkpoint, n_top: f32, fwd: &str) -> ServingModel {
        ServingModel {
            params: ckpt.params.tensors.clone(),
            sigma_q: ckpt.sigma_q.clone(),
            sigma_k: ckpt.sigma_k.clone(),
            n_top,
            fwd: fwd.to_string(),
        }
    }

    /// Randomly initialized model (latency/throughput demos where accuracy
    /// is irrelevant).
    pub fn random(
        manifest: &Manifest,
        config: &str,
        seed: u64,
        fwd: &str,
    ) -> Result<ServingModel> {
        let cfg = manifest.config(config)?;
        let mut rng = crate::util::rng::Rng::new(seed);
        let params = crate::model::ParamSet::init(cfg, &mut rng);
        Ok(ServingModel {
            params: params.tensors,
            sigma_q: vec![1.0; cfg.model.n_layers],
            sigma_k: vec![1.0; cfg.model.n_layers],
            n_top: cfg.model.n_top as f32,
            fwd: fwd.to_string(),
        })
    }
}

struct Shared {
    queues: Mutex<Vec<BucketQueue>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

pub struct Server {
    router: Router,
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    scheduler: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the scheduler thread. `models[i]` corresponds to
    /// `router.buckets()[i]`.
    pub fn start(
        engine: EngineHandle,
        router: Router,
        models: Vec<ServingModel>,
        policy: BatchPolicy,
    ) -> Result<Server> {
        anyhow::ensure!(
            models.len() == router.buckets().len(),
            "one ServingModel per bucket required"
        );
        let queues: Vec<BucketQueue> = router
            .buckets()
            .iter()
            .map(|b| BucketQueue::new(b.clone(), policy))
            .collect();
        let shared = Arc::new(Shared {
            queues: Mutex::new(queues),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(Metrics::default());

        let sched_shared = Arc::clone(&shared);
        let sched_metrics = Arc::clone(&metrics);
        let scheduler = std::thread::Builder::new()
            .name("had-scheduler".into())
            .spawn(move || scheduler_main(sched_shared, engine, models, sched_metrics))
            .context("spawning scheduler")?;

        Ok(Server {
            router,
            shared,
            metrics,
            next_id: AtomicU64::new(0),
            scheduler: Some(scheduler),
        })
    }

    /// Submit a request; returns the reply channel.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<Receiver<Response>, RejectReason> {
        if self.shared.shutdown.load(Ordering::Relaxed) {
            return Err(RejectReason::ShuttingDown);
        }
        let bucket_idx = {
            let b = self.router.route(tokens.len())?;
            self.router
                .buckets()
                .iter()
                .position(|x| x == b)
                .expect("bucket index")
        };
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            arrival: Instant::now(),
            reply: tx,
        };
        let mut queues = self.shared.queues.lock().unwrap();
        match queues[bucket_idx].push(req) {
            Ok(()) => {
                self.shared.cv.notify_one();
                Ok(rx)
            }
            Err(_req) => {
                self.metrics.record_reject();
                Err(RejectReason::QueueFull)
            }
        }
    }

    /// Blocking convenience: submit and wait for the response.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let rx = self
            .submit(tokens)
            .map_err(|r| anyhow::anyhow!("rejected: {r}"))?;
        rx.recv().context("server dropped the request")
    }

    pub fn router(&self) -> &Router {
        &self.router
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        if let Some(t) = self.scheduler.take() {
            let _ = t.join();
        }
    }
}

fn scheduler_main(
    shared: Arc<Shared>,
    engine: EngineHandle,
    models: Vec<ServingModel>,
    metrics: Arc<Metrics>,
) {
    let mut served = 0u64;
    loop {
        // collect a ready batch under the lock
        let work: Option<(usize, Vec<Request>)> = {
            let mut queues = shared.queues.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) {
                    // drain everything remaining before exit
                    if let Some(i) = (0..queues.len()).find(|&i| !queues[i].is_empty()) {
                        let reqs = queues[i].drain_batch();
                        break Some((i, reqs));
                    }
                    break None;
                }
                let now = Instant::now();
                if let Some(i) = (0..queues.len()).find(|&i| queues[i].ready(now)) {
                    let reqs = queues[i].drain_batch();
                    break Some((i, reqs));
                }
                // sleep until the nearest deadline (or a notify)
                let timeout = queues
                    .iter()
                    .filter_map(|q| q.next_deadline(now))
                    .min()
                    .unwrap_or(std::time::Duration::from_millis(50));
                let (q, _tmo) = shared
                    .cv
                    .wait_timeout(queues, timeout.max(std::time::Duration::from_micros(100)))
                    .unwrap();
                queues = q;
            }
        };
        let Some((idx, reqs)) = work else { break };
        let model = &models[idx];
        let bucket = {
            let queues = shared.queues.lock().unwrap();
            queues[idx].bucket.clone()
        };

        // assemble and execute OUTSIDE the queue lock
        let (xs, real) = assemble_padded(&reqs, bucket.n_ctx, bucket.batch, crate::data::PAD);
        let mut inputs: Vec<HostTensor> = model.params.clone();
        inputs.push(HostTensor::i32(vec![bucket.batch, bucket.n_ctx], xs));
        inputs.push(HostTensor::vec_f32(model.sigma_q.clone()));
        inputs.push(HostTensor::vec_f32(model.sigma_k.clone()));
        inputs.push(HostTensor::scalar_f32(model.n_top));
        let artifact = format!("{}__{}", bucket.config, model.fwd);

        match engine.exec(&artifact, inputs) {
            Ok(out) => {
                let logits = out[0].as_f32().unwrap_or(&[]);
                let n_classes = logits.len() / bucket.batch.max(1);
                // record metrics BEFORE replying: a client that sees its
                // response must also see it in a subsequent snapshot
                let lats: Vec<u128> =
                    reqs.iter().map(|r| r.arrival.elapsed().as_micros()).collect();
                metrics.record_batch(&lats, real);
                for ((b, req), latency_us) in reqs.iter().enumerate().zip(&lats) {
                    let row = &logits[b * n_classes..(b + 1) * n_classes];
                    let _ = req.reply.send(Response {
                        id: req.id,
                        pred: argmax(row) as i32,
                        logits: row.to_vec(),
                        bucket: bucket.config.clone(),
                        latency_us: *latency_us,
                        batch_occupancy: real,
                    });
                    served += 1;
                }
            }
            Err(e) => {
                log_warn!("batch execution failed on {artifact}: {e:#}");
                // drop reply senders: clients observe disconnection
            }
        }
    }
    log_info!("scheduler exiting after {served} responses");
}
