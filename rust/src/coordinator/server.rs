//! The serving engine: router + dynamic batcher + execution backend.
//!
//! Architecture (per DESIGN.md, updated for the CPU serving backend):
//!
//!   clients --submit()--> shared bucket queues --scheduler thread-->
//!     drain batch --> execution backend --> logits -->
//!     per-request reply channels ; Metrics throughout
//!
//! Two execution backends:
//!
//! * **CPU** (the serving path): `serve::HadBackend` runs the real HAD
//!   transformer decode per request over per-layer packed KV pages. A
//!   batch's sessions are checked out of the byte-budgeted pool, their
//!   suffixes decoded in parallel across `kernel_workers` threads (only
//!   the appended tokens are executed — resident per-layer pages are
//!   reused in place), and checked back in. `Response.logits` ARE the
//!   backend's logits. The PJRT engine can ride along as an optional
//!   per-batch cross-check (`Server::builder(..).cross_check(..)`) but
//!   is no longer on the decode path.
//!
//! CPU servers are configured through one builder —
//! `Server::builder(backend, router, policy)` with `.kv(cfg)`,
//! `.spill(store)`, `.chaos(plan)`, `.cross_check(engine, models)` and
//! `.prefix_sharing(true)` — replacing the old per-feature CPU
//! constructor family. With prefix sharing enabled, sealed full KV
//! stripes gain a content-hash identity and N concurrent streams over
//! one identical prompt pay its prefill exactly once (the others adopt
//! the published pages), bit-identically to unshared serving.
//! * **PJRT** (legacy / artifact environments): padded full-sequence
//!   re-execution through `runtime::engine`, kept for comparing the CPU
//!   backend against lowered artifacts.
//!
//! Backpressure: bounded per-bucket admission queues; `submit` rejects
//! with `QueueFull` rather than queueing unboundedly.

use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::batcher::{assemble_padded, BatchPolicy, BucketQueue, StreamQueue};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{GenAdmit, RejectReason, Request, Response, SessionInfo};
use crate::coordinator::router::Router;
use crate::generate::{
    GenLimits, GenState, GenerateOutput, GenerateRequest, StepOut, StopReason, StreamEvent,
};
use crate::kvcache::{CacheStats, KvCacheConfig, LayeredKv, PagePool};
use crate::log_info;
use crate::log_warn;
use crate::model::Checkpoint;
use crate::runtime::{EngineHandle, HostTensor, Manifest};
use crate::serve::{AttnPath, HadBackend, ScratchPool};
use crate::tensor::ops::argmax;
use crate::util::fault::{self, Fault, FaultPlan};
use crate::util::lock_or_recover;
use crate::util::threadpool::{parallel_for_mut, parallel_map_n};

/// Weights + calibration served for one bucket on the PJRT path (and by
/// the CPU path's optional cross-check).
#[derive(Clone)]
pub struct ServingModel {
    pub params: Vec<HostTensor>,
    pub sigma_q: Vec<f32>,
    pub sigma_k: Vec<f32>,
    pub n_top: f32,
    /// forward artifact name within the bucket's config ("fwd_had", ...)
    pub fwd: String,
}

impl ServingModel {
    pub fn from_checkpoint(ckpt: &Checkpoint, n_top: f32, fwd: &str) -> ServingModel {
        ServingModel {
            params: ckpt.params.tensors.clone(),
            sigma_q: ckpt.sigma_q.clone(),
            sigma_k: ckpt.sigma_k.clone(),
            n_top,
            fwd: fwd.to_string(),
        }
    }

    /// Randomly initialized model (latency/throughput demos where accuracy
    /// is irrelevant).
    pub fn random(
        manifest: &Manifest,
        config: &str,
        seed: u64,
        fwd: &str,
    ) -> Result<ServingModel> {
        let cfg = manifest.config(config)?;
        let mut rng = crate::util::rng::Rng::new(seed);
        let params = crate::model::ParamSet::init(cfg, &mut rng);
        Ok(ServingModel {
            params: params.tensors,
            sigma_q: vec![1.0; cfg.model.n_layers],
            sigma_k: vec![1.0; cfg.model.n_layers],
            n_top: cfg.model.n_top as f32,
            fwd: fwd.to_string(),
        })
    }
}

/// PJRT cross-check attachment for the CPU path.
struct CrossCheck {
    engine: EngineHandle,
    /// one model per router bucket, matching the backend's weights
    models: Vec<ServingModel>,
}

/// Which execution backend the scheduler drives.
enum Exec {
    Cpu { backend: Arc<HadBackend>, check: Option<CrossCheck> },
    Pjrt { engine: EngineHandle, models: Vec<ServingModel> },
}

/// Per-session token history plus LRU bookkeeping.
struct History {
    tokens: Vec<i32>,
    last_used: u64,
}

/// Session-side coordinator state: per-session token histories (the
/// context a turn extends) and the byte-budgeted pool of per-layer
/// decode states the CPU backend checks out per batch.
///
/// There is no featurizer here any more: K/V rows are produced by the
/// real per-layer projections inside `HadBackend::decode`, and they are
/// produced at decode time, not admission time — admission only extends
/// the token history. The pool therefore holds `LayeredKv` entries whose
/// decoded token ids are verified against the request before any
/// incremental resume (`serve` module docs).
///
/// Boundedness: pool bytes are budget-enforced at check-in; histories
/// (4 B/token) carry their own LRU token budget, sized as a small
/// fraction of the KV budget, and a history evicted there drops its pool
/// entry too — an evicted session's next turn starts a fresh context
/// (`cached_tokens == 0` tells the client to resend what it needs).
pub struct SessionStore {
    pool: PagePool<LayeredKv>,
    histories: HashMap<u64, History>,
    clock: u64,
    hist_tokens: usize,
    max_history_tokens: usize,
}

/// Everything a [`SessionStore`] needs at construction: KV sizing, an
/// optional disk spill tier (budget pressure spills cold full stripes
/// instead of destroying sessions), and whether cross-session prefix
/// sharing is on.
#[derive(Clone, Default)]
pub struct SessionStoreConfig {
    pub kv: KvCacheConfig,
    pub spill: Option<Arc<crate::store::SpillStore>>,
    pub prefix_sharing: bool,
}

impl From<KvCacheConfig> for SessionStoreConfig {
    fn from(kv: KvCacheConfig) -> SessionStoreConfig {
        SessionStoreConfig { kv, ..Default::default() }
    }
}

impl SessionStore {
    pub fn new(cfg: SessionStoreConfig) -> SessionStore {
        // token ids cost 4 B vs >= ~100 B/token of per-layer KV state, so
        // a small slice of the byte budget bounds histories comfortably
        let max_history_tokens = (cfg.kv.byte_budget / 16).max(4096);
        let mut pool = PagePool::new(cfg.kv);
        pool.set_spill(cfg.spill);
        pool.set_prefix_sharing(cfg.prefix_sharing);
        SessionStore {
            pool,
            histories: HashMap::new(),
            clock: 0,
            hist_tokens: 0,
            max_history_tokens,
        }
    }

    /// Tokens the session has accumulated across turns.
    pub fn history_len(&self, session_id: u64) -> usize {
        self.histories.get(&session_id).map_or(0, |h| h.tokens.len())
    }

    pub fn tokens(&self, session_id: u64) -> &[i32] {
        self.histories
            .get(&session_id)
            .map_or(&[] as &[i32], |h| h.tokens.as_slice())
    }

    /// Admit one turn: extend the session's history. `cached_tokens` is
    /// the context length already held for the session (whether its KV
    /// pages are still resident is the decode pass's business — if they
    /// were evicted, decode re-executes and the turn is merely slower,
    /// never wrong).
    pub fn admit(&mut self, session_id: u64, append: &[i32]) -> SessionInfo {
        self.clock += 1;
        let now = self.clock;
        let hist = self
            .histories
            .entry(session_id)
            .or_insert(History { tokens: Vec::new(), last_used: now });
        hist.last_used = now;
        let cached = hist.tokens.len();
        hist.tokens.extend_from_slice(append);
        self.hist_tokens += append.len();
        self.evict_histories(session_id);
        SessionInfo { id: session_id, cached_tokens: cached, appended_tokens: append.len() }
    }

    /// Enforce the history token budget by LRU eviction (never the
    /// session just touched). An evicted history's pool entry goes too:
    /// per-layer pages for a context nobody can extend are dead budget.
    fn evict_histories(&mut self, protect: u64) {
        while self.hist_tokens > self.max_history_tokens {
            let victim = self
                .histories
                .iter()
                .filter(|(&id, _)| id != protect)
                .min_by_key(|(_, h)| h.last_used)
                .map(|(&id, _)| id);
            let Some(id) = victim else { break };
            self.drop_session_state(id);
        }
    }

    fn drop_session_state(&mut self, session_id: u64) {
        if let Some(h) = self.histories.remove(&session_id) {
            self.hist_tokens -= h.tokens.len();
        }
        self.pool.remove(session_id);
    }

    /// Check a session's decode state OUT for a batch decode (its bytes
    /// leave the pool accounting until `checkin`).
    ///
    /// Hydrate-before-decode invariant: attention never touches a
    /// non-resident page, so any stripes living in the spill tier are
    /// read back here, bit-identically. A failed read (fault injection,
    /// corruption) leaves the cache truncated to the prefix before the
    /// bad stripe — the decode re-prefills the difference instead of
    /// ever serving corrupt KV.
    pub fn checkout(&mut self, session_id: u64) -> Option<LayeredKv> {
        let mut kv = self.pool.take(session_id)?;
        if !kv.fully_resident() {
            match self.pool.spill_store().cloned() {
                Some(store) => {
                    let (pages_in, failures) = kv.hydrate(&store);
                    self.pool.note_hydrate(pages_in, failures);
                }
                // spill tier detached with stripes still out: nothing to
                // read them from — restart the context (stripes spill
                // oldest-first, so there is no usable resident prefix)
                None => kv.truncate(0),
            }
        }
        Some(kv)
    }

    /// Return a decode state to the pool: records the hit/miss outcome
    /// the decode observed, enforces the byte budget, and drops the
    /// histories of any sessions evicted to make room.
    pub fn checkin(&mut self, session_id: u64, mut kv: LayeredKv, hit: bool) {
        // prefix sharing: every full private stripe this decode produced
        // becomes adoptable by identical prompts (no-op when sharing is
        // off or everything is already shared/spilled)
        self.pool.publish_prefix(&mut kv);
        self.pool.record_lookup(hit);
        let evicted = self.pool.insert(session_id, kv);
        for id in evicted {
            if let Some(h) = self.histories.remove(&id) {
                self.hist_tokens -= h.tokens.len();
            }
        }
    }

    pub fn pool(&self) -> &PagePool<LayeredKv> {
        &self.pool
    }

    /// Adopt registry stripes matching a prefix of `tokens` into a
    /// checked-out KV (bounded by `max_tokens`). Returns tokens adopted;
    /// 0 whenever sharing is off or nothing matches.
    pub fn seed_prefix(
        &mut self,
        kv: &mut LayeredKv,
        tokens: &[i32],
        max_tokens: usize,
    ) -> usize {
        self.pool.seed_prefix(kv, tokens, max_tokens)
    }

    /// Publish a checked-out KV's full private stripes to the registry
    /// (mid-stream counterpart of the publish `checkin` performs).
    pub fn publish_prefix(&mut self, kv: &mut LayeredKv) {
        self.pool.publish_prefix(kv)
    }

    /// Does the registry cover every full stripe of `tokens` below
    /// `max_tokens`? (Vacuously true with sharing off.)
    pub fn prefix_covered(
        &self,
        geom: &crate::kvcache::StripeGeom,
        tokens: &[i32],
        max_tokens: usize,
    ) -> bool {
        self.pool.prefix_covered(geom, tokens, max_tokens)
    }

    /// First-prefiller election for identical concurrent prompts:
    /// `None` means `stream` holds the claim, `Some(holder)` that
    /// another stream is already prefilling this prompt.
    pub fn try_claim(&mut self, key: u64, stream: u64) -> Option<u64> {
        self.pool.try_claim(key, stream)
    }

    pub fn release_claim(&mut self, key: u64, stream: u64) {
        self.pool.release_claim(key, stream)
    }

    /// Drop a checked-out KV that will never be checked back in
    /// (poisoned stream, stale history): its spill tags and shared
    /// registry references flow back instead of leaking.
    pub fn discard_kv(&mut self, kv: LayeredKv) {
        self.pool.discard(kv)
    }

    /// Undo one `admit` (queue-full rollback): restore the history to the
    /// length captured before the turn. The pool is untouched — decode
    /// never saw the rejected turn. When the session was absent before
    /// (`hist_before == 0`) it is dropped outright.
    pub fn rollback_turn(&mut self, session_id: u64, hist_before: usize) {
        if hist_before == 0 {
            self.drop_session_state(session_id);
            return;
        }
        if let Some(h) = self.histories.get_mut(&session_id) {
            if h.tokens.len() > hist_before {
                self.hist_tokens -= h.tokens.len() - hist_before;
                h.tokens.truncate(hist_before);
            }
        }
    }

    /// Conversation over: drop history and pages (not counted as eviction).
    pub fn end_session(&mut self, session_id: u64) {
        self.drop_session_state(session_id);
    }

    /// Extend a session's history with tokens the GENERATION loop
    /// produced (they never passed through `submit_*` admission): same
    /// LRU/budget bookkeeping as `admit`, but no cache counters — from
    /// the client's perspective nothing was resubmitted. No-op when the
    /// session's history is gone (evicted mid-stream): the generated
    /// tokens were still streamed, the session just restarts cold.
    pub fn append_generated(&mut self, session_id: u64, tokens: &[i32]) {
        if tokens.is_empty() {
            return;
        }
        self.clock += 1;
        let now = self.clock;
        let Some(hist) = self.histories.get_mut(&session_id) else { return };
        hist.last_used = now;
        hist.tokens.extend_from_slice(tokens);
        self.hist_tokens += tokens.len();
        self.evict_histories(session_id);
    }
}

struct Shared {
    queues: Mutex<Vec<BucketQueue>>,
    /// admitted generation streams waiting for a continuous-batching
    /// ticket (lock order: queues before streams, never the reverse)
    streams: Mutex<StreamQueue>,
    cv: Condvar,
    shutdown: AtomicBool,
}

pub struct Server {
    router: Router,
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    sessions: Arc<Mutex<SessionStore>>,
    next_id: AtomicU64,
    scheduler: Option<std::thread::JoinHandle<()>>,
    /// generation needs the CPU backend (the PJRT path has no token loop)
    cpu: bool,
    /// admission-side knobs (event channel bound, queue TTL)
    policy: BatchPolicy,
}

/// One-stop configuration for a CPU server, replacing the old
/// six-way per-feature constructor family (with-kv / chaos / spill /
/// spill-chaos / cross-checked variants) with one composable builder.
/// Every knob is optional; `start()` launches the scheduler.
///
/// Defaults match the old bare constructor: default KV sizing, faults from
/// the process-wide `HAD_FAULT` plan, spill tier from `HAD_STORE=dir`,
/// no cross-check, prefix sharing off.
pub struct ServerBuilder {
    backend: HadBackend,
    router: Router,
    policy: BatchPolicy,
    kv: KvCacheConfig,
    spill: Option<Arc<crate::store::SpillStore>>,
    chaos: Option<Arc<FaultPlan>>,
    cross_check: Option<(EngineHandle, Vec<ServingModel>)>,
    prefix_sharing: bool,
}

impl ServerBuilder {
    /// Explicit KV-cache sizing (byte budget, page size, bf16 values).
    pub fn kv(mut self, kv: KvCacheConfig) -> ServerBuilder {
        self.kv = kv;
        self
    }

    /// Explicit KV spill store: budget pressure spills cold stripes to
    /// disk instead of destroying sessions, and checkouts hydrate them
    /// back. Without this, the server picks the tier up from
    /// `HAD_STORE=dir`.
    pub fn spill(mut self, store: Arc<crate::store::SpillStore>) -> ServerBuilder {
        self.spill = Some(store);
        self
    }

    /// Instance-scoped fault-injection plan (chaos testing): only THIS
    /// server's hot paths draw from the plan, so concurrently running
    /// servers (e.g. other tests in the same process) are unaffected.
    /// Without this, the process-wide `HAD_FAULT` plan applies. Pass an
    /// `Arc` to share the plan with a `SpillStore` so its
    /// `spill_write`/`spill_read` sites fire too.
    pub fn chaos(mut self, plan: impl Into<Arc<FaultPlan>>) -> ServerBuilder {
        self.chaos = Some(plan.into());
        self
    }

    /// PJRT engine as a per-batch cross-check: every served batch is
    /// also executed through the bucket's lowered artifact and the
    /// logits difference is logged. The engine is OFF the decode path —
    /// an exec failure logs a warning and serving continues.
    pub fn cross_check(
        mut self,
        engine: EngineHandle,
        models: Vec<ServingModel>,
    ) -> ServerBuilder {
        self.cross_check = Some((engine, models));
        self
    }

    /// Cross-session prefix sharing: sealed full KV stripes get a
    /// content-hash identity and identical prompts adopt each other's
    /// pages instead of re-prefilling (bit-identical either way).
    pub fn prefix_sharing(mut self, on: bool) -> ServerBuilder {
        self.prefix_sharing = on;
        self
    }

    pub fn start(self) -> Result<Server> {
        let check = match self.cross_check {
            Some((engine, models)) => {
                anyhow::ensure!(
                    models.len() == self.router.buckets().len(),
                    "one cross-check ServingModel per bucket required"
                );
                Some(CrossCheck { engine, models })
            }
            None => None,
        };
        let faults = match self.chaos {
            Some(plan) => Some(plan),
            None => fault::from_env(),
        };
        // explicit store wins; otherwise the opt-in env tier
        let spill = match self.spill {
            Some(store) => Some(store),
            None => crate::store::SpillStore::from_env(faults.clone()),
        };
        Server::start_inner_full(
            Exec::Cpu { backend: Arc::new(self.backend), check },
            self.router,
            self.policy,
            self.kv,
            faults,
            spill,
            self.prefix_sharing,
        )
    }
}

impl Server {
    /// Configure a CPU server — `submit`/`submit_session` return the
    /// backend's real logits. See [`ServerBuilder`] for the knobs.
    pub fn builder(backend: HadBackend, router: Router, policy: BatchPolicy) -> ServerBuilder {
        ServerBuilder {
            backend,
            router,
            policy,
            kv: KvCacheConfig::default(),
            spill: None,
            chaos: None,
            cross_check: None,
            prefix_sharing: false,
        }
    }

    /// Start on the legacy PJRT path: `models[i]` corresponds to
    /// `router.buckets()[i]` and batches execute as padded full-sequence
    /// artifact calls. Kept for artifact environments that compare the
    /// CPU backend against lowered graphs.
    pub fn start(
        engine: EngineHandle,
        router: Router,
        models: Vec<ServingModel>,
        policy: BatchPolicy,
    ) -> Result<Server> {
        Server::start_with_kv(engine, router, models, policy, KvCacheConfig::default())
    }

    /// PJRT path with explicit KV-cache sizing.
    pub fn start_with_kv(
        engine: EngineHandle,
        router: Router,
        models: Vec<ServingModel>,
        policy: BatchPolicy,
        kv: KvCacheConfig,
    ) -> Result<Server> {
        anyhow::ensure!(
            models.len() == router.buckets().len(),
            "one ServingModel per bucket required"
        );
        Server::start_inner(Exec::Pjrt { engine, models }, router, policy, kv)
    }

    fn start_inner(
        exec: Exec,
        router: Router,
        policy: BatchPolicy,
        kv: KvCacheConfig,
    ) -> Result<Server> {
        // opt-in disk spill tier (`HAD_STORE=dir`); the builder bypasses
        // this and passes its explicit store directly
        let faults = fault::from_env();
        let spill = crate::store::SpillStore::from_env(faults.clone());
        Server::start_inner_full(exec, router, policy, kv, faults, spill, false)
    }

    #[allow(clippy::too_many_arguments)]
    fn start_inner_full(
        exec: Exec,
        router: Router,
        policy: BatchPolicy,
        kv: KvCacheConfig,
        faults: Option<Arc<FaultPlan>>,
        spill: Option<Arc<crate::store::SpillStore>>,
        prefix_sharing: bool,
    ) -> Result<Server> {
        let queues: Vec<BucketQueue> = router
            .buckets()
            .iter()
            .map(|b| BucketQueue::new(b.clone(), policy))
            .collect();
        let shared = Arc::new(Shared {
            queues: Mutex::new(queues),
            streams: Mutex::new(StreamQueue::new(policy.queue_cap)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(Metrics::default());
        let sessions = Arc::new(Mutex::new(SessionStore::new(SessionStoreConfig {
            kv,
            spill,
            prefix_sharing,
        })));
        let cpu = matches!(exec, Exec::Cpu { .. });
        // generation streams grow inside the server-wide bounds: the
        // largest routed context, the page pool's byte budget, and the
        // policy's wall-clock deadline
        let limits = GenLimits {
            max_total_tokens: router.max_ctx(),
            kv_budget_bytes: kv.byte_budget,
            deadline_ms: policy.stream_deadline_ms,
        };

        let sched_shared = Arc::clone(&shared);
        let sched_metrics = Arc::clone(&metrics);
        let sched_sessions = Arc::clone(&sessions);
        let scheduler = std::thread::Builder::new()
            .name("had-scheduler".into())
            .spawn(move || {
                scheduler_main(
                    sched_shared,
                    exec,
                    sched_metrics,
                    sched_sessions,
                    policy,
                    limits,
                    faults,
                )
            })
            .context("spawning scheduler")?;

        Ok(Server {
            router,
            shared,
            metrics,
            sessions,
            next_id: AtomicU64::new(0),
            scheduler: Some(scheduler),
            cpu,
            policy,
        })
    }

    /// Submit a request; returns the reply channel.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<Receiver<Response>, RejectReason> {
        if self.shared.shutdown.load(Ordering::Relaxed) {
            return Err(RejectReason::ShuttingDown);
        }
        let admit_start = Instant::now();
        let trace = crate::obs::sample_request();
        let bucket_idx = self.router.route_idx(tokens.len())?;
        let (tx, rx) = channel();
        let n_tokens = tokens.len();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            arrival: admit_start,
            reply: tx,
            session: None,
            trace,
        };
        let mut queues = lock_or_recover(&self.shared.queues);
        match queues[bucket_idx].push(req) {
            Ok(()) => {
                self.shared.cv.notify_one();
                drop(queues);
                crate::obs::record(
                    trace,
                    "admission",
                    admit_start,
                    admit_start.elapsed().as_micros() as u64,
                    n_tokens as u64,
                );
                Ok(rx)
            }
            Err(_req) => {
                self.metrics.record_reject();
                Err(RejectReason::QueueFull)
            }
        }
    }

    /// Submit one turn of a multi-turn session: `append_tokens` extends
    /// the session's history and the request executes over the full
    /// sequence, routed by total length (`Router::route_session_idx`).
    /// On the CPU path the batch decode touches only the non-resident
    /// suffix of the sequence (per-layer pages from earlier turns are
    /// reused in place).
    ///
    /// Rejection is side-effect-free: admission only extends the token
    /// history under the sessions lock — the global queue lock is taken
    /// just for the push, and a `QueueFull` push rolls the turn back —
    /// so a rejected turn can simply be retried with the same
    /// `append_tokens`.
    pub fn submit_session(
        &self,
        session_id: u64,
        append_tokens: Vec<i32>,
    ) -> Result<Receiver<Response>, RejectReason> {
        if self.shared.shutdown.load(Ordering::Relaxed) {
            return Err(RejectReason::ShuttingDown);
        }
        let admit_start = Instant::now();
        let trace = crate::obs::sample_request();
        let mut store = lock_or_recover(&self.sessions);
        let mut hist_before = store.history_len(session_id);
        let bucket_idx = match self
            .router
            .route_session_idx(hist_before, append_tokens.len())
        {
            Ok(i) => i,
            Err(RejectReason::TooLong) if hist_before > 0 => {
                // Context overflow: the accumulated history no longer fits
                // any bucket. Restart the session's context with this turn
                // (the same fresh-context semantics as an eviction;
                // `cached_tokens == 0` tells the client) instead of
                // wedging the session id in permanent rejection. Routing
                // by the append alone is checked FIRST so an oversized
                // append still rejects without side effects.
                let idx = self.router.route_idx(append_tokens.len())?;
                store.end_session(session_id);
                hist_before = 0;
                idx
            }
            Err(e) => return Err(e),
        };
        let info = store.admit(session_id, &append_tokens);
        let tokens = store.tokens(session_id).to_vec();

        let (tx, rx) = channel();
        let n_tokens = tokens.len();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            arrival: admit_start,
            reply: tx,
            session: Some(info),
            trace,
        };
        let pushed = {
            let mut queues = lock_or_recover(&self.shared.queues);
            match queues[bucket_idx].push(req) {
                Ok(()) => {
                    self.shared.cv.notify_one();
                    true
                }
                Err(_req) => false,
            }
        };
        if !pushed {
            store.rollback_turn(session_id, hist_before);
            drop(store);
            self.metrics.record_reject();
            return Err(RejectReason::QueueFull);
        }
        self.metrics.record_session(info.cached_tokens, info.appended_tokens);
        drop(store);
        crate::obs::record(
            trace,
            "admission",
            admit_start,
            admit_start.elapsed().as_micros() as u64,
            n_tokens as u64,
        );
        Ok(rx)
    }

    /// Blocking convenience: submit and wait for the response.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let rx = self
            .submit(tokens)
            .map_err(|r| anyhow::anyhow!("rejected: {r}"))?;
        rx.recv().context("server dropped the request")
    }

    /// Blocking convenience for one session turn.
    pub fn infer_session(&self, session_id: u64, append_tokens: Vec<i32>) -> Result<Response> {
        let rx = self
            .submit_session(session_id, append_tokens)
            .map_err(|r| anyhow::anyhow!("rejected: {r}"))?;
        rx.recv().context("server dropped the request")
    }

    /// Submit a generation stream on a session: the prompt extends the
    /// session's history (exactly like a `submit_session` turn), then the
    /// continuous-batching scheduler generates up to `max_new_tokens`
    /// tokens, delivering each as a [`StreamEvent::Token`] on the
    /// returned channel the moment it is produced and closing with
    /// [`StreamEvent::Done`] and a stop reason. Generated tokens join the
    /// session's history and per-layer KV pages, so a follow-up turn (or
    /// stream) resumes warm from everything generated here.
    ///
    /// Admission mirrors `submit_session`: routed by total prefill
    /// length, context-overflow restarts the session's context, a full
    /// stream queue rejects side-effect-free with `QueueFull`. CPU
    /// backend only (`Unsupported` on the PJRT path).
    pub fn submit_generate(
        &self,
        session_id: u64,
        req: GenerateRequest,
    ) -> Result<Receiver<StreamEvent>, RejectReason> {
        if !self.cpu {
            return Err(RejectReason::Unsupported);
        }
        if self.shared.shutdown.load(Ordering::Relaxed) {
            return Err(RejectReason::ShuttingDown);
        }
        let admit_start = Instant::now();
        let trace = crate::obs::sample_request();
        let mut store = lock_or_recover(&self.sessions);
        // backpressure FIRST: stream pushes are serialized under the
        // sessions lock and the scheduler only ever pops, so a non-full
        // queue here guarantees the push below succeeds — which keeps the
        // destructive overflow-restart from firing on a turn that is
        // then rejected anyway
        {
            let streams = lock_or_recover(&self.shared.streams);
            if streams.is_full() {
                drop(streams);
                self.metrics.record_reject();
                return Err(RejectReason::QueueFull);
            }
            // stalled-scheduler admission control: if the queue HEAD has
            // already waited past the TTL, anything admitted behind it
            // would only time out too — reject fast instead
            if streams.front().is_some_and(|f| f.arrival.elapsed() >= self.policy.queue_ttl) {
                drop(streams);
                self.metrics.record_reject();
                return Err(RejectReason::Timeout);
            }
        }
        let mut hist_before = store.history_len(session_id);
        if hist_before + req.prompt.len() == 0 {
            return Err(RejectReason::EmptyGeneration);
        }
        match self
            .router
            .route_session_idx(hist_before, req.prompt.len())
        {
            Ok(_) => {}
            Err(RejectReason::TooLong) if hist_before > 0 => {
                // same context-overflow restart as submit_session: an
                // oversized (or empty — nothing to restart FROM) prompt
                // still rejects without side effects
                if req.prompt.is_empty() {
                    return Err(RejectReason::EmptyGeneration);
                }
                self.router.route_idx(req.prompt.len())?;
                store.end_session(session_id);
                hist_before = 0;
            }
            Err(e) => return Err(e),
        }
        let history = store.tokens(session_id).to_vec();
        let state = GenState::new(history, &req);
        let admitted_len = state.context_len();
        let info = store.admit(session_id, &req.prompt);

        let (tx, rx) = sync_channel(self.policy.stream_event_cap.max(1));
        let admit = GenAdmit {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            session: session_id,
            state,
            reply: tx,
            arrival: admit_start,
            admitted_len,
            trace,
        };
        let pushed = lock_or_recover(&self.shared.streams).push(admit).is_ok();
        if !pushed {
            // unreachable given the capacity check above, but kept so a
            // future re-entrant push source degrades to a clean reject
            store.rollback_turn(session_id, hist_before);
            drop(store);
            self.metrics.record_reject();
            return Err(RejectReason::QueueFull);
        }
        self.metrics.record_session(info.cached_tokens, info.appended_tokens);
        drop(store);
        crate::obs::record(
            trace,
            "admission",
            admit_start,
            admit_start.elapsed().as_micros() as u64,
            admitted_len as u64,
        );
        // notify under the queues mutex (the condvar's mutex): without
        // it, a notify racing the scheduler's "streams empty" check and
        // its wait_timeout would be lost and the admission would stall
        // for the full fallback timeout
        let _guard = lock_or_recover(&self.shared.queues);
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Blocking convenience: run a generation stream to completion and
    /// collect its tokens.
    pub fn generate_session(
        &self,
        session_id: u64,
        req: GenerateRequest,
    ) -> Result<GenerateOutput> {
        let rx = self
            .submit_generate(session_id, req)
            .map_err(|r| anyhow::anyhow!("rejected: {r}"))?;
        let mut tokens = Vec::new();
        for event in rx.iter() {
            match event {
                StreamEvent::Token { token, .. } => tokens.push(token),
                StreamEvent::Done { reason, .. } => {
                    return Ok(GenerateOutput { tokens, reason })
                }
            }
        }
        anyhow::bail!("server dropped the stream")
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Shared handle to the session store (demos, draining, inspection).
    pub fn sessions(&self) -> Arc<Mutex<SessionStore>> {
        Arc::clone(&self.sessions)
    }

    /// Snapshot of the page-pool counters (CPU path; the PJRT path keeps
    /// no pages, so its stats stay zero).
    pub fn cache_stats(&self) -> CacheStats {
        lock_or_recover(&self.sessions).pool().stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        if let Some(t) = self.scheduler.take() {
            let _ = t.join();
        }
    }
}

/// One request's decode product. Timing fields are `None` when no
/// forward ran for the slot (empty token sequences) so the metrics only
/// ever aggregate measured samples; `Response` reports unmeasured slots
/// as 0.
struct Served {
    logits: Vec<f32>,
    kernel_us: Option<u128>,
    decode_us: Option<u128>,
}

/// Decode one drained batch on the CPU backend, sessions sharded across
/// `workers` scoped threads. Returns one `Served` per request slot;
/// `None` for slots whose shard panicked mid-decode (the panic is
/// caught, the shard's requests get no response — their clients observe
/// a dropped reply channel — and the rest of the batch is unaffected).
///
/// Grouping: all of a session's requests land in ONE job (they are
/// prefixes of the same history, so one incremental decode serves them
/// all, capturing logits at each request's length); sessionless requests
/// decode statelessly, one job each. The sessions lock is held only to
/// check a session's `LayeredKv` out of the pool and back in — the
/// decode itself runs lock-free, so concurrent admissions never stall
/// behind model execution. Every job borrows its attention scratch from
/// the scheduler's shared `ScratchPool` (grown buffers are reused across
/// jobs and ticks instead of allocated per decode).
fn decode_pass(
    workers: usize,
    sessions: &Mutex<SessionStore>,
    backend: &HadBackend,
    reqs: &[Request],
    metrics: &Metrics,
    scratch_pool: &ScratchPool,
) -> Vec<Option<Served>> {
    struct Job {
        session: Option<u64>,
        /// request slots, sorted by token length ascending
        slots: Vec<usize>,
    }
    let mut by_session: HashMap<u64, Vec<usize>> = HashMap::new();
    let mut jobs: Vec<Job> = Vec::new();
    for (slot, r) in reqs.iter().enumerate() {
        match r.session {
            Some(s) => by_session.entry(s.id).or_default().push(slot),
            None => jobs.push(Job { session: None, slots: vec![slot] }),
        }
    }
    for (id, mut slots) in by_session {
        slots.sort_by_key(|&s| reqs[s].tokens.len());
        jobs.push(Job { session: Some(id), slots });
    }

    let outputs: Vec<Vec<(usize, Served)>> = parallel_map_n(workers, &jobs, |_, job| {
        // panic isolation: a poisoned shard loses its own slots (and its
        // session's checked-out pages — the session restarts cold), never
        // the batch or the scheduler
        std::panic::catch_unwind(AssertUnwindSafe(|| decode_job(
            job.session,
            &job.slots,
            sessions,
            backend,
            reqs,
            metrics,
            scratch_pool,
        )))
        .unwrap_or_else(|_| {
            log_warn!("decode shard panicked; {} request(s) dropped", job.slots.len());
            metrics.record_decode_error();
            Vec::new()
        })
    });

    let mut served: Vec<Option<Served>> = (0..reqs.len()).map(|_| None).collect();
    for group in outputs {
        for (slot, s) in group {
            // unmeasured slots (empty sequences) stay out of the timing
            // aggregates — kernel/decode percentiles only ever see
            // samples a forward actually produced
            if let Some(us) = s.kernel_us {
                metrics.record_kernel(us);
            }
            if let Some(us) = s.decode_us {
                metrics.record_decode(us);
            }
            served[slot] = Some(s);
        }
    }
    served
}

/// One decode shard: all of one session's slots (or one sessionless
/// slot), factored out of `decode_pass` so its body sits cleanly inside
/// the per-shard `catch_unwind` boundary.
fn decode_job(
    session: Option<u64>,
    slots: &[usize],
    sessions: &Mutex<SessionStore>,
    backend: &HadBackend,
    reqs: &[Request],
    metrics: &Metrics,
    scratch_pool: &ScratchPool,
) -> Vec<(usize, Served)> {
    let longest = *slots.last().expect("non-empty job");
    let tokens = &reqs[longest].tokens;
    // a job serves several slots of one session; attribute its spans
    // to the first sampled request in the group (explicit SpanId
    // handoff — the worker thread is freshly spawned per pass)
    let job_trace = slots
        .iter()
        .map(|&s| reqs[s].trace)
        .find(|t| !t.is_none())
        .unwrap_or(crate::obs::SpanId::NONE);
    let _trace_scope = crate::obs::enter(job_trace);
    let empty = || Served {
        logits: vec![0.0; backend.n_classes()],
        kernel_us: None,
        decode_us: None,
    };
    // Same-session requests are normally prefixes of one incremental
    // decode. A request whose tokens are NOT a prefix of the group's
    // longest sequence (its history was evicted and restarted between
    // the two admissions) is served by its own stateless decode
    // instead of someone else's context.
    let mut stray: Vec<(usize, Served)> = Vec::new();
    let mut main_slots: Vec<usize> = Vec::new();
    for &s in slots {
            let t = &reqs[s].tokens;
        if tokens[..t.len().min(tokens.len())] == t[..] {
            main_slots.push(s);
        } else {
            let mut scratch_kv = backend.fresh_kv();
            let (mut caps, stats) = scratch_pool.with(|sc| {
                backend.decode_in(&mut scratch_kv, t, &[t.len()], AttnPath::Kernel, sc)
            });
            stray.push((s, Served {
                logits: caps.pop().expect("one capture requested").logits,
                kernel_us: Some(stats.attn_us),
                decode_us: Some(stats.decode_us),
            }));
        }
    }
    let mut capture: Vec<usize> = main_slots
        .iter()
        .map(|&s| reqs[s].tokens.len())
        .filter(|&l| l > 0)
        .collect();
    capture.dedup(); // slots are length-sorted

    if tokens.is_empty() {
        // nothing to decode (empty first turn / empty request):
        // resident state, if any, is left untouched
        return main_slots.iter().map(|&s| (s, empty())).chain(stray).collect();
    }

    let mut kv = {
        let mut co = crate::obs::span("kv_checkout");
        let kv = match session {
            Some(id) => {
                let mut store = lock_or_recover(sessions);
                let mut kv = store.checkout(id).unwrap_or_else(|| backend.fresh_kv());
                // prefix sharing: adopt registry stripes below the first
                // capture point (the logits at a capture length need the
                // row AT that length decoded here, not adopted)
                if let Some(&first) = capture.first() {
                    let cap = first.min(tokens.len()).saturating_sub(1);
                    store.seed_prefix(&mut kv, tokens, cap);
                }
                kv
            }
            None => backend.fresh_kv(),
        };
        co.set_payload(kv.len() as u64);
        kv
    };
    let was_resident = !kv.is_empty();
    let (caps, stats) = scratch_pool.with(|sc| {
        backend.decode_in(&mut kv, tokens, &capture, AttnPath::Kernel, sc)
    });
    if let Some(id) = session {
        let mut ci = crate::obs::span("kv_checkin");
        ci.set_payload(kv.len() as u64);
        let mut store = lock_or_recover(sessions);
        // a resume is a cache hit; a reset (or cold start) a miss
        store.checkin(id, kv, was_resident && stats.resumed_at > 0);
        metrics.update_cache_pool(store.pool().bytes(), store.pool().stats().evictions);
        metrics.sync_spill(&store.pool().stats());
    }

    main_slots
        .iter()
        .map(|&slot| {
            let len = reqs[slot].tokens.len();
            if len == 0 {
                return (slot, empty());
            }
            let cap = caps
                .iter()
                .find(|c| c.len == len)
                .expect("a capture for every requested length");
            (
                slot,
                Served {
                    logits: cap.logits.clone(),
                    kernel_us: Some(cap.attn_us),
                    decode_us: Some(cap.decode_us),
                },
            )
        })
        .chain(stray)
        .collect()
}

/// Reply to every request of a batch. Records latencies BEFORE replying
/// (a client that sees its response must also see it in a subsequent
/// metrics snapshot); `row` supplies each slot's
/// `(logits, kernel_us, decode_us)`, or `None` for a slot whose decode
/// shard panicked — its reply sender is dropped unsent, so the client
/// observes disconnection rather than fabricated logits. Shared by the
/// CPU and PJRT arms so the Response contract cannot drift between them.
fn reply_batch(
    reqs: &[Request],
    bucket: &crate::coordinator::router::Bucket,
    metrics: &Metrics,
    served: &mut u64,
    mut row: impl FnMut(usize) -> Option<(Vec<f32>, u128, u128)>,
) {
    let lats: Vec<u128> = reqs.iter().map(|r| r.arrival.elapsed().as_micros()).collect();
    metrics.record_batch(&lats, reqs.len());
    for ((b, req), latency_us) in reqs.iter().enumerate().zip(&lats) {
        // the request umbrella span: recorded under the id handed out by
        // sample_request at admission, so every stage span already points
        // at it
        crate::obs::record_as(
            req.trace,
            crate::obs::SpanId::NONE,
            "request",
            req.arrival,
            *latency_us as u64,
            req.tokens.len() as u64,
        );
        let Some((logits, kernel_us, decode_us)) = row(b) else { continue };
        let _ = req.reply.send(Response {
            id: req.id,
            pred: argmax(&logits) as i32,
            logits,
            bucket: bucket.config.clone(),
            latency_us: *latency_us,
            batch_occupancy: reqs.len(),
            cached_tokens: req.session.map_or(0, |s| s.cached_tokens),
            kernel_us,
            decode_us,
        });
        *served += 1;
    }
}

/// Execute one batch through a bucket's lowered artifact (the PJRT
/// path's whole decode; the CPU path's optional cross-check). Returns
/// the flat logits and the row width.
fn pjrt_exec(
    engine: &EngineHandle,
    model: &ServingModel,
    bucket: &crate::coordinator::router::Bucket,
    reqs: &[Request],
) -> Result<(Vec<f32>, usize)> {
    let (xs, _real) = assemble_padded(reqs, bucket.n_ctx, bucket.batch, crate::data::PAD);
    let mut inputs: Vec<HostTensor> = model.params.clone();
    inputs.push(HostTensor::i32(vec![bucket.batch, bucket.n_ctx], xs));
    inputs.push(HostTensor::vec_f32(model.sigma_q.clone()));
    inputs.push(HostTensor::vec_f32(model.sigma_k.clone()));
    inputs.push(HostTensor::scalar_f32(model.n_top));
    let artifact = format!("{}__{}", bucket.config, model.fwd);
    let out = engine.exec(&artifact, inputs)?;
    let logits = out[0].as_f32().context("f32 logits")?.to_vec();
    let n_classes = logits.len() / bucket.batch.max(1);
    Ok((logits, n_classes))
}

/// One live generation stream inside the scheduler: its state machine,
/// its checked-out per-layer KV (held for the stream's whole lifetime —
/// its bytes leave the pool accounting until retirement checks it back
/// in), and the stream's timing bookkeeping.
struct ActiveGen {
    admit: GenAdmit,
    kv: LayeredKv,
    /// the checkout found a usable resident prefix (pool-hit accounting)
    resumed: bool,
    /// this tick's step result, parked between the parallel step pass and
    /// the serial emit/retire pass
    pending: Option<StepOut>,
    /// worst-case bytes this stream may hold, reserved against the pool
    /// budget at activation and released at retirement (aggregate
    /// admission control: sum of reserves never exceeds the budget)
    reserve: usize,
    /// a decode shard panicked while stepping this stream — its KV is in
    /// an unknown state and must be dropped, never checked back in
    poisoned: bool,
    /// prefix-sharing claim key for this stream's prompt. When `waiting`
    /// is false and this is `Some`, the stream HOLDS the claim (it is
    /// the elected prefiller) and must release it at retirement.
    claim: Option<u64>,
    /// parked: an identical prompt is being prefilled by another stream;
    /// this one skips its step each tick until the registry covers its
    /// shareable prefix (or the claim frees and it takes over)
    waiting: bool,
    ttft_us: u128,
    last_token_at: Option<Instant>,
}

/// What one scheduler iteration found to do.
enum Work {
    /// a bucket queue flushed a batch (classification-style turns)
    Batch(usize, Vec<Request>),
    /// no batch, but generation work exists (admissions and/or steps)
    Tick,
    /// shutdown with everything drained
    Exit,
}

/// Emit one generated token to the stream's client, recording TTFT on
/// the first and inter-token latency on the rest. Returns false when the
/// client has dropped its receiver, or when the bounded event channel is
/// full — a reader that has fallen `stream_event_cap` events behind is
/// disconnected rather than wedging the scheduler (the stream retires as
/// Disconnected either way).
fn emit_token(
    g: &mut ActiveGen,
    token: i32,
    metrics: &Metrics,
    faults: &Option<Arc<FaultPlan>>,
) -> bool {
    if fault::fire(faults, fault::SITE_CLIENT_DISCONNECT).is_some() {
        metrics.record_fault();
        return false;
    }
    let index = g.admit.state.n_generated() - 1;
    let now = Instant::now();
    match g.last_token_at {
        None => {
            g.ttft_us = now.duration_since(g.admit.arrival).as_micros();
            metrics.record_first_token(g.ttft_us);
        }
        Some(prev) => metrics.record_inter_token(now.duration_since(prev).as_micros()),
    }
    g.last_token_at = Some(now);
    match g.admit.reply.try_send(StreamEvent::Token { index, token }) {
        Ok(()) => true,
        Err(TrySendError::Full(_)) => {
            metrics.record_slow_reader();
            false
        }
        Err(TrySendError::Disconnected(_)) => false,
    }
}

/// Retire a finished stream: fold its generated tokens into the session
/// history and check its KV back into the pool — but only if the history
/// is still exactly the context this stream extended (an eviction or an
/// interleaved turn on the same session id invalidates the resume, in
/// which case the pages are dropped and the session restarts cold on its
/// next turn). Closes the client channel with the stop reason.
fn retire_stream(
    g: ActiveGen,
    reason: StopReason,
    sessions: &Mutex<SessionStore>,
    metrics: &Metrics,
) {
    let ActiveGen { admit, kv, resumed, poisoned, claim, ttft_us, .. } = g;
    let generated = admit.state.n_generated();
    {
        let mut store = lock_or_recover(sessions);
        // release the prompt claim so a parked identical-prompt stream
        // can take over (no-op when this stream never held it)
        if let Some(key) = claim {
            store.release_claim(key, admit.id);
        }
        let intact =
            store.tokens(admit.session) == &admit.state.tokens()[..admit.admitted_len];
        if intact {
            store.append_generated(admit.session, admit.state.generated());
        }
        // a poisoned stream's KV is in an unknown state, and a stream
        // whose history was rewritten under it must not check stale
        // pages in — discard instead (checkout already removed the bytes
        // from the pool accounting; discard returns the KV's spill tags
        // and shared-registry references so neither leaks)
        if intact && !poisoned {
            store.checkin(admit.session, kv, resumed);
        } else {
            store.discard_kv(kv);
        }
        metrics.update_cache_pool(store.pool().bytes(), store.pool().stats().evictions);
        metrics.sync_spill(&store.pool().stats());
    }
    metrics.record_stream_retired(reason);
    // the stream umbrella span, under the id sample_request allocated at
    // admission (mirrors reply_batch's "request" span)
    crate::obs::record_as(
        admit.trace,
        crate::obs::SpanId::NONE,
        "stream",
        admit.arrival,
        admit.arrival.elapsed().as_micros() as u64,
        generated as u64,
    );
    // best-effort: a full (slow-reader) or dropped channel must not
    // block the scheduler on its own retirement path
    let _ = admit.reply.try_send(StreamEvent::Done { reason, generated, ttft_us });
}

/// Retire a stream that never activated (queue TTL expiry or a drain
/// shutdown caught it still in the admission queue): it holds no KV and
/// generated nothing, so this only records the retirement and closes the
/// client channel with the reason.
fn retire_unactivated(admit: GenAdmit, reason: StopReason, metrics: &Metrics) {
    metrics.record_stream_retired(reason);
    crate::obs::record_as(
        admit.trace,
        crate::obs::SpanId::NONE,
        "stream",
        admit.arrival,
        admit.arrival.elapsed().as_micros() as u64,
        0,
    );
    let _ = admit.reply.try_send(StreamEvent::Done { reason, generated: 0, ttft_us: 0 });
}

fn scheduler_main(
    shared: Arc<Shared>,
    exec: Exec,
    metrics: Arc<Metrics>,
    sessions: Arc<Mutex<SessionStore>>,
    policy: BatchPolicy,
    limits: GenLimits,
    faults: Option<Arc<FaultPlan>>,
) {
    let kernel_workers = policy.kernel_workers.max(1);
    let max_streams = policy.max_streams.max(1);
    let prefill_chunk = policy.prefill_chunk.max(1);
    let mut served = 0u64;
    // grown attention buffers shared by every decode job — batch decodes
    // and generation steps — across all ticks
    let scratch_pool = ScratchPool::new();
    // cross-session prefix sharing on? (fixed at construction; read once
    // so the steady-state tick never touches the sessions lock for it)
    let sharing = lock_or_recover(&sessions).pool().prefix_sharing();
    // live generation streams (continuous batching: one step per tick)
    let mut active: Vec<ActiveGen> = Vec::new();
    // geometry probe for worst-case byte reservations (CPU path only —
    // generation never runs on the PJRT path)
    let probe_kv = match &exec {
        Exec::Cpu { backend, .. } => Some(backend.fresh_kv()),
        #[allow(unreachable_patterns)]
        _ => None,
    };
    // worst-case bytes a stream can grow to: its context plus its full
    // max_new_tokens allowance, clamped to the routed context cap and
    // the pool budget (a single stream is always admissible)
    let reserve_for = |state: &GenState| -> usize {
        let Some(probe) = &probe_kv else { return 0 };
        let cap = (state.tokens().len() + state.max_new_tokens())
            .min(limits.max_total_tokens);
        probe.bytes_at(cap).min(limits.kv_budget_bytes)
    };
    // sum of active streams' reservations — admission control keeps this
    // at or under the pool budget, closing the max_streams x per-stream
    // budget over-commit hole
    let mut reserved = 0usize;
    // drain-shutdown bookkeeping: when shutdown is flagged, live streams
    // get drain_grace to finish naturally before being force-retired
    let mut shutdown_at: Option<Instant> = None;
    // periodic registry snapshots ride the scheduler loop when tracing
    let mut last_snap = Instant::now();
    // admission-queue depth observed at the moment work was selected
    let mut queue_depth_now = 0usize;
    loop {
        if let Some(Fault::Delay(d)) = fault::fire(&faults, fault::SITE_QUEUE_STALL) {
            metrics.record_fault();
            std::thread::sleep(d);
        }
        // collect work under the lock: a flushed batch wins; otherwise a
        // tick runs if any stream is live or waiting; otherwise sleep
        let mut admits: Vec<GenAdmit> = Vec::new();
        let mut pending_reserve = 0usize;
        let work: Work = {
            let mut queues = lock_or_recover(&shared.queues);
            loop {
                let shutting = shared.shutdown.load(Ordering::Relaxed);
                let now = Instant::now();
                queue_depth_now = queues.iter().map(|q| q.len()).sum();
                // stream admissions are collected BEFORE the batch check
                // so sustained batch traffic (a queue ready on every
                // iteration) cannot starve queued streams: a Work::Batch
                // iteration still carries its admissions into the tick
                {
                    let mut streams = lock_or_recover(&shared.streams);
                    while active.len() + admits.len() < max_streams {
                        let Some(front) = streams.front() else { break };
                        // TTL-expired admissions hold no reservation:
                        // they are popped unconditionally and retired at
                        // activation time below
                        if front.arrival.elapsed() < policy.queue_ttl {
                            let need = reserve_for(&front.state);
                            let headroom =
                                if fault::fire(&faults, fault::SITE_POOL_PRESSURE).is_some() {
                                    metrics.record_fault();
                                    0
                                } else {
                                    limits.kv_budget_bytes
                                };
                            if reserved + pending_reserve + need > headroom {
                                // would over-commit the pool: defer until
                                // a live stream retires and releases its
                                // reservation
                                metrics.record_admission_deferral();
                                break;
                            }
                            pending_reserve += need;
                        }
                        match streams.pop() {
                            Some(a) => admits.push(a),
                            None => break,
                        }
                    }
                }
                // at shutdown, drain any non-empty queue immediately
                if let Some(i) = (0..queues.len())
                    .find(|&i| if shutting { !queues[i].is_empty() } else { queues[i].ready(now) })
                {
                    let reqs = queues[i].drain_batch();
                    break Work::Batch(i, reqs);
                }
                if !admits.is_empty() || !active.is_empty() {
                    break Work::Tick;
                }
                if shutting {
                    // queues drained, no admissions (max_streams >= 1
                    // guarantees the stream queue emptied above), no live
                    // streams: done
                    break Work::Exit;
                }
                // sleep until the nearest deadline (or a notify)
                let timeout = queues
                    .iter()
                    .filter_map(|q| q.next_deadline(now))
                    .min()
                    .unwrap_or(std::time::Duration::from_millis(50));
                let (q, _tmo) = shared
                    .cv
                    .wait_timeout(queues, timeout.max(std::time::Duration::from_micros(100)))
                    .unwrap_or_else(|e| e.into_inner());
                queues = q;
            }
        };
        let batch: Option<(usize, Vec<Request>)> = match work {
            Work::Exit => break,
            Work::Batch(idx, reqs) => Some((idx, reqs)),
            Work::Tick => None,
        };
        // graceful drain: stamp the moment shutdown was first observed;
        // live streams get drain_grace from here to finish naturally
        if shutdown_at.is_none() && shared.shutdown.load(Ordering::Relaxed) {
            shutdown_at = Some(Instant::now());
        }

        // 1. batch execution OUTSIDE the queue lock (unchanged contract)
        if let Some((idx, reqs)) = batch {
            let bucket = {
                let queues = lock_or_recover(&shared.queues);
                queues[idx].bucket.clone()
            };
            run_batch(
                &exec, idx, &bucket, reqs, kernel_workers, &sessions, &metrics,
                &scratch_pool, &mut served,
            );
        }

        // periodic registry snapshots for the exporter (tracing only —
        // one cheap Instant check otherwise)
        if crate::obs::tracing() && last_snap.elapsed().as_millis() >= 500 {
            crate::obs::write_metrics_snapshot(metrics.registry());
            last_snap = Instant::now();
        }

        // 2. generation tick (CPU backend only; submit_generate rejects
        // on the PJRT path, so admits/active stay empty there)
        let Exec::Cpu { backend, .. } = &exec else { continue };
        // force-drain: past the grace window, everything still live or
        // queued retires with StopReason::Shutdown so shutdown cannot
        // hang on a wedged or long-running stream
        if shutdown_at.is_some_and(|t| t.elapsed() >= policy.drain_grace) {
            for a in admits.drain(..) {
                retire_unactivated(a, StopReason::Shutdown, &metrics);
            }
            for a in lock_or_recover(&shared.streams).drain_all() {
                retire_unactivated(a, StopReason::Shutdown, &metrics);
            }
            for g in active.drain(..) {
                reserved = reserved.saturating_sub(g.reserve);
                retire_stream(g, StopReason::Shutdown, &sessions, &metrics);
                served += 1;
            }
            continue;
        }
        // 2a. activate admissions: check each stream's session KV out of
        // the pool; prefill happens as the stream's first step below
        for a in admits {
            // queue-TTL expiry: the stream waited too long to activate;
            // retire it without touching the pool
            if a.arrival.elapsed() >= policy.queue_ttl {
                retire_unactivated(a, StopReason::DeadlineExceeded, &metrics);
                continue;
            }
            crate::obs::record(
                a.trace,
                "queue_wait",
                a.arrival,
                a.arrival.elapsed().as_micros() as u64,
                0,
            );
            let reserve = reserve_for(&a.state);
            reserved += reserve;
            let (kv, resumed, claim, waiting) = {
                let _scope = crate::obs::enter(a.trace);
                let mut co = crate::obs::span("kv_checkout");
                let mut store = lock_or_recover(&sessions);
                let mut kv = store
                    .checkout(a.session)
                    .unwrap_or_else(|| backend.fresh_kv());
                co.set_payload(kv.len() as u64);
                let toks = a.state.tokens();
                let resumed = if !kv.is_empty() && kv.is_prefix_of(toks) {
                    if kv.len() >= toks.len() {
                        // fully resident (continue-generation after a turn
                        // that decoded the whole context): drop just the last
                        // row so the first step re-decodes ONE token instead
                        // of tripping the capture-at-resident-length reset
                        // and re-prefilling everything
                        kv.truncate(toks.len() - 1);
                    }
                    true
                } else {
                    if !kv.is_empty() {
                        // stale resident pages (history diverged): release
                        // them now so the stream's real footprint stays at or
                        // under its reservation from the first step on
                        kv.truncate(0);
                    }
                    false
                };
                // prefix sharing: adopt whatever the registry already
                // covers (the last token always decodes here — its step
                // produces the first sampled logits), then elect a
                // prefiller when shareable stripes remain: the claim
                // winner prefills for everyone, identical-prompt
                // followers park until its stripes publish
                let mut claim = None;
                let mut waiting = false;
                if sharing && !toks.is_empty() {
                    let cap = toks.len() - 1;
                    store.seed_prefix(&mut kv, toks, cap);
                    let geom = kv.stripe_geom();
                    if kv.len() < (cap / geom.page_tokens) * geom.page_tokens {
                        let key = crate::kvcache::prompt_claim_key(&geom, toks);
                        claim = Some(key);
                        waiting = store.try_claim(key, a.id).is_some();
                    }
                }
                (kv, resumed, claim, waiting)
            };
            active.push(ActiveGen {
                admit: a,
                kv,
                resumed,
                pending: None,
                reserve,
                poisoned: false,
                claim,
                waiting,
                ttft_us: 0,
                last_token_at: None,
            });
        }
        // parked identical-prompt followers: wake the moment the elected
        // prefiller's published stripes cover the shareable prefix, or
        // take the claim over if it retired without publishing (serial
        // area — the sessions lock is never taken inside the step pass)
        if sharing && active.iter().any(|g| g.waiting) {
            let mut store = lock_or_recover(&sessions);
            for g in active.iter_mut().filter(|g| g.waiting) {
                let toks = g.admit.state.tokens();
                let cap = toks.len() - 1;
                let geom = g.kv.stripe_geom();
                if store.prefix_covered(&geom, toks, cap) {
                    store.seed_prefix(&mut g.kv, toks, cap);
                    g.waiting = false;
                    g.claim = None; // never held — nothing to release
                } else if let Some(key) = g.claim {
                    if store.try_claim(key, g.admit.id).is_none() {
                        // the prefiller is gone: this stream owns the
                        // prefill now (claim held; release at retirement)
                        g.waiting = false;
                    }
                }
            }
        }
        if active.is_empty() {
            continue;
        }
        let tick_start = Instant::now();
        let mut tick_span = crate::obs::root_span("tick");
        tick_span.set_payload(active.len() as u64);
        // 2b. one decode step per live stream, sharded across workers
        // (newly admitted streams prefill in this same pass). Each
        // stream's work is bounded per tick: a long prompt prefills in
        // prefill_chunk-token slices (pure KV production — the captures
        // slice is empty, so chunking is bit-identical to one-shot
        // prefill) before its first real sampling step runs. The whole
        // step runs under catch_unwind so one poisoned shard retires its
        // own stream instead of killing the scheduler.
        parallel_for_mut(kernel_workers, &mut active, |_, g| {
            if limits.deadline_ms != u64::MAX
                && g.admit.arrival.elapsed().as_millis() as u64 >= limits.deadline_ms
            {
                g.pending = Some(StepOut::Done(StopReason::DeadlineExceeded));
                return;
            }
            if g.waiting {
                // parked on another stream's prefill: no step this tick
                // (pending stays None, so the serial pass skips it too);
                // the deadline check above still bounds the wait
                return;
            }
            if let Some(Fault::Delay(d)) = fault::fire(&faults, fault::SITE_DECODE_STEP) {
                metrics.record_fault();
                std::thread::sleep(d);
            }
            let inject_panic =
                matches!(fault::fire(&faults, fault::SITE_WORKER_PANIC), Some(Fault::Panic));
            if inject_panic {
                metrics.record_fault();
            }
            let stepped = std::panic::catch_unwind(AssertUnwindSafe(|| {
                if inject_panic {
                    panic!("injected worker panic");
                }
                let _scope = crate::obs::enter(g.admit.trace);
                let mut scratch = scratch_pool.checkout();
                let remaining = g.admit.state.tokens().len().saturating_sub(g.kv.len());
                let out = if remaining > prefill_chunk {
                    match g.admit.state.prefill_partial(
                        backend,
                        &mut g.kv,
                        &limits,
                        prefill_chunk,
                        AttnPath::Kernel,
                        &mut scratch,
                    ) {
                        Some(reason) => Some(StepOut::Done(reason)),
                        None => None,
                    }
                } else {
                    Some(g.admit.state.step(
                        backend,
                        &mut g.kv,
                        &limits,
                        AttnPath::Kernel,
                        &mut scratch,
                    ))
                };
                scratch_pool.checkin(scratch);
                out
            }));
            match stepped {
                Ok(out) => g.pending = out,
                Err(_) => {
                    g.poisoned = true;
                    g.pending = Some(StepOut::Done(StopReason::Error));
                }
            }
        });
        // 2c. serial emit/retire pass (token order within a stream is
        // preserved; streams retire the moment their stop fires). A
        // stream with no pending result spent its tick on a prefill
        // chunk and simply continues next tick.
        let mut i = 0;
        while i < active.len() {
            let Some(out) = active[i].pending.take() else {
                i += 1;
                continue;
            };
            if active[i].poisoned {
                log_warn!(
                    "generation shard panicked; stream {} retired with StopReason::Error",
                    active[i].admit.id
                );
                metrics.record_decode_error();
            }
            let mut finish: Option<StopReason> = None;
            match out {
                StepOut::Token(t) => {
                    if !emit_token(&mut active[i], t, &metrics, &faults) {
                        finish = Some(StopReason::Disconnected);
                    }
                }
                StepOut::Last(t, reason) => {
                    emit_token(&mut active[i], t, &metrics, &faults);
                    finish = Some(reason);
                }
                StepOut::Done(reason) => finish = Some(reason),
            }
            if let Some(reason) = finish {
                let g = active.swap_remove(i);
                reserved = reserved.saturating_sub(g.reserve);
                retire_stream(g, reason, &sessions, &metrics);
                served += 1;
            } else {
                i += 1;
            }
        }
        // publish newly filled stripes of live streams so parked
        // identical-prompt followers can adopt mid-generation (steady
        // state: no stream has a publishable stripe and the sessions
        // lock is never taken)
        if sharing
            && active
                .iter()
                .any(|g| !g.waiting && !g.kv.publishable_stripes().is_empty())
        {
            let mut store = lock_or_recover(&sessions);
            for g in active.iter_mut().filter(|g| !g.waiting) {
                store.publish_prefix(&mut g.kv);
            }
        }
        drop(tick_span);
        metrics.record_tick(
            tick_start.elapsed().as_micros(),
            queue_depth_now,
            active.len(),
        );
    }
    log_info!("scheduler exiting after {served} responses");
}

/// Execute one flushed batch on whichever backend the server runs
/// (verbatim the pre-generation scheduler body, factored out so the tick
/// loop stays readable).
#[allow(clippy::too_many_arguments)]
fn run_batch(
    exec: &Exec,
    idx: usize,
    bucket: &crate::coordinator::router::Bucket,
    reqs: Vec<Request>,
    kernel_workers: usize,
    sessions: &Mutex<SessionStore>,
    metrics: &Metrics,
    scratch_pool: &ScratchPool,
    served: &mut u64,
) {
    // queue wait ends the moment the batch starts executing; sampled
    // requests get it as a retrospective span under their umbrella id
    for r in &reqs {
        crate::obs::record(
            r.trace,
            "queue_wait",
            r.arrival,
            r.arrival.elapsed().as_micros() as u64,
            0,
        );
    }
    match exec {
            Exec::Cpu { backend, check } => {
                let outs = decode_pass(
                    kernel_workers,
                    sessions,
                    backend,
                    &reqs,
                    metrics,
                    scratch_pool,
                );
                if let Some(cc) = check {
                    match pjrt_exec(&cc.engine, &cc.models[idx], bucket, &reqs) {
                        Ok((logits, n_classes)) => {
                            let max_diff = reqs
                                .iter()
                                .enumerate()
                                .filter_map(|(b, _)| outs[b].as_ref().map(|s| (b, s)))
                                .flat_map(|(b, s)| {
                                    let row = &logits[b * n_classes..(b + 1) * n_classes];
                                    row.iter()
                                        .zip(&s.logits)
                                        .map(|(x, y)| (x - y).abs())
                                })
                                .fold(0.0f32, f32::max);
                            log_info!(
                                "cross-check {}: max |pjrt - backend| = {max_diff:.3e}",
                                bucket.config
                            );
                        }
                        Err(e) => {
                            log_warn!("cross-check unavailable on {}: {e:#}", bucket.config)
                        }
                    }
                }
                reply_batch(&reqs, bucket, metrics, served, |b| {
                    outs[b].as_ref().map(|s| {
                        (s.logits.clone(), s.kernel_us.unwrap_or(0), s.decode_us.unwrap_or(0))
                    })
                });
            }
            Exec::Pjrt { engine, models } => {
                match pjrt_exec(engine, &models[idx], bucket, &reqs) {
                    Ok((logits, n_classes)) => {
                        reply_batch(&reqs, bucket, metrics, served, |b| {
                            Some((logits[b * n_classes..(b + 1) * n_classes].to_vec(), 0, 0))
                        });
                    }
                    Err(e) => {
                        log_warn!("batch execution failed on {}: {e:#}", bucket.config);
                        // drop reply senders: clients observe disconnection
                    }
                }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvGeom;
    use crate::runtime::{ConfigEntry, ModelCfg};
    use crate::serve::{token_config_entry, ServeModel};

    fn tiny_model_cfg() -> ConfigEntry {
        token_config_entry(
            "serve_srv",
            ModelCfg {
                n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 32,
                n_classes: 3, vocab: 24, input_dim: 0, n_top: 8, block_q: 16,
            },
        )
    }

    fn tiny_backend(kv: &KvCacheConfig) -> HadBackend {
        HadBackend::new(ServeModel::random(&tiny_model_cfg(), 0xBEEF).unwrap(), kv)
    }

    fn kv_cfg(byte_budget: usize) -> KvCacheConfig {
        KvCacheConfig { page_tokens: 4, byte_budget, ..Default::default() }
    }

    /// bytes of one fully-decoded n-token session for the tiny geometry
    fn session_bytes(backend: &HadBackend, n_tokens: usize) -> usize {
        let KvGeom { n_layers, n_heads, d_head } = backend.geom();
        let pages = n_tokens.div_ceil(4);
        n_layers * n_heads * pages * 4 * (8 + d_head * 4)
    }

    #[test]
    fn session_store_incremental_admission() {
        let mut store = SessionStore::new(kv_cfg(1 << 20).into());
        let a = store.admit(42, &[1, 2, 3, 4]);
        assert_eq!((a.cached_tokens, a.appended_tokens), (0, 4));
        let b = store.admit(42, &[5, 6]);
        assert_eq!((b.cached_tokens, b.appended_tokens), (4, 2));
        assert_eq!(store.history_len(42), 6);
        assert_eq!(store.tokens(42), &[1, 2, 3, 4, 5, 6]);
        store.end_session(42);
        assert_eq!(store.history_len(42), 0);
    }

    #[test]
    fn rollback_restores_history() {
        let mut store = SessionStore::new(kv_cfg(1 << 20).into());
        store.admit(1, &[1, 2, 3]);
        store.admit(1, &[4, 5]);
        store.rollback_turn(1, 3);
        assert_eq!(store.tokens(1), &[1, 2, 3]);
        // rollback of a first turn drops the session outright
        store.admit(2, &[9]);
        store.rollback_turn(2, 0);
        assert_eq!(store.history_len(2), 0);
        assert_eq!(store.hist_tokens, 3, "token accounting survives rollbacks");
    }

    #[test]
    fn history_budget_evicts_lru_sessions() {
        let mut store = SessionStore::new(kv_cfg(1 << 20).into());
        store.max_history_tokens = 10;
        store.admit(1, &[0; 4]);
        store.admit(2, &[0; 4]);
        store.admit(3, &[0; 4]); // 12 > 10: session 1 (LRU) evicted
        assert_eq!(store.history_len(1), 0);
        assert_eq!(store.history_len(2), 4);
        assert_eq!(store.hist_tokens, 8);
        // the protected (current) session survives even when oversized
        store.admit(4, &[0; 64]);
        assert_eq!(store.history_len(4), 64);
    }

    #[test]
    fn checkin_evictions_drop_their_histories() {
        let kv = kv_cfg(1); // tiny budget: any insert evicts the rest
        let backend = tiny_backend(&kv);
        let mut store = SessionStore::new(kv.into());
        store.admit(1, &[1, 2, 3]);
        store.admit(2, &[4, 5, 6]);
        let mut kv1 = backend.fresh_kv();
        backend.decode(&mut kv1, &[1, 2, 3], &[3]);
        store.checkin(1, kv1, false);
        let mut kv2 = backend.fresh_kv();
        backend.decode(&mut kv2, &[4, 5, 6], &[3]);
        store.checkin(2, kv2, false);
        // budget of 1 byte: checking session 2 in evicted session 1,
        // which must drop session 1's history too (fresh-context restart)
        assert_eq!(store.history_len(1), 0);
        assert_eq!(store.history_len(2), 3);
        assert!(store.pool().stats().evictions >= 1);
    }

    #[test]
    fn decode_pass_serves_backend_logits_per_slot() {
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let sessions = Mutex::new(SessionStore::new(kv.into()));
        let metrics = Metrics::default();
        let mk = |id: u64, tokens: Vec<i32>, session: Option<SessionInfo>| {
            let (tx, rx) = channel();
            std::mem::forget(rx); // keep the reply channel alive
            Request {
                id,
                tokens,
                arrival: Instant::now(),
                reply: tx,
                session,
                trace: crate::obs::SpanId::NONE,
            }
        };
        let info = sessions.lock().unwrap().admit(3, &[1, 2, 3, 4, 5]);
        let session_tokens = sessions.lock().unwrap().tokens(3).to_vec();
        let plain_tokens = vec![7i32, 8, 9];
        let reqs = vec![
            mk(0, plain_tokens.clone(), None),
            mk(1, session_tokens.clone(), Some(info)),
        ];
        let pool = ScratchPool::new();
        let outs = decode_pass(2, &sessions, &backend, &reqs, &metrics, &pool);
        assert_eq!(outs.len(), 2);
        assert!(pool.parked() >= 1, "decode jobs return their scratch buffers");
        // both requests get REAL logits: bit-identical to a direct
        // backend forward of the same tokens
        assert_eq!(outs[0].as_ref().unwrap().logits, backend.forward_logits(&plain_tokens));
        assert_eq!(outs[1].as_ref().unwrap().logits, backend.forward_logits(&session_tokens));
        assert_eq!(metrics.snapshot().decode_requests, 2);
        // session state is resident now; a follow-up turn resumes (hit)
        let info2 = sessions.lock().unwrap().admit(3, &[6, 7]);
        let session_tokens2 = sessions.lock().unwrap().tokens(3).to_vec();
        let reqs2 = vec![mk(2, session_tokens2.clone(), Some(info2))];
        let outs2 = decode_pass(2, &sessions, &backend, &reqs2, &metrics, &pool);
        assert_eq!(outs2[0].as_ref().unwrap().logits, backend.forward_logits(&session_tokens2));
        let stats = sessions.lock().unwrap().pool().stats();
        assert_eq!((stats.hits, stats.misses), (1, 1), "turn 2 resumed from turn 1's pages");
        assert_eq!(
            sessions.lock().unwrap().pool().cached_tokens(3),
            7,
            "pool holds the full decoded context"
        );
        assert_eq!(
            sessions.lock().unwrap().pool().bytes(),
            session_bytes(&backend, 7),
            "pool accounting matches the per-layer page layout"
        );
    }

    #[test]
    fn decode_pass_groups_same_session_requests() {
        // two turns of one session drained into the same batch: one
        // incremental decode serves both, logits captured at each length
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let sessions = Mutex::new(SessionStore::new(kv.into()));
        let metrics = Metrics::default();
        let mk = |id: u64, tokens: Vec<i32>, session: Option<SessionInfo>| {
            let (tx, rx) = channel();
            std::mem::forget(rx);
            Request {
                id,
                tokens,
                arrival: Instant::now(),
                reply: tx,
                session,
                trace: crate::obs::SpanId::NONE,
            }
        };
        let i1 = sessions.lock().unwrap().admit(9, &[1, 2, 3]);
        let t1 = sessions.lock().unwrap().tokens(9).to_vec();
        let i2 = sessions.lock().unwrap().admit(9, &[4, 5]);
        let t2 = sessions.lock().unwrap().tokens(9).to_vec();
        let reqs = vec![mk(0, t2.clone(), Some(i2)), mk(1, t1.clone(), Some(i1))];
        let outs = decode_pass(1, &sessions, &backend, &reqs, &metrics, &ScratchPool::new());
        assert_eq!(outs[0].as_ref().unwrap().logits, backend.forward_logits(&t2));
        assert_eq!(outs[1].as_ref().unwrap().logits, backend.forward_logits(&t1));
        assert_eq!(sessions.lock().unwrap().pool().cached_tokens(9), 5);
    }

    #[test]
    fn empty_append_is_a_pure_history_hit() {
        let mut store = SessionStore::new(kv_cfg(1 << 20).into());
        store.admit(9, &[1, 2]);
        let a = store.admit(9, &[]);
        assert_eq!((a.cached_tokens, a.appended_tokens), (2, 0));
        assert_eq!(store.tokens(9), &[1, 2]);
    }

    #[test]
    fn append_generated_extends_history_without_cache_counters() {
        let mut store = SessionStore::new(kv_cfg(1 << 20).into());
        store.admit(5, &[1, 2, 3]);
        store.append_generated(5, &[7, 8]);
        assert_eq!(store.tokens(5), &[1, 2, 3, 7, 8]);
        assert_eq!(store.hist_tokens, 5, "generated tokens count toward the budget");
        // absent session: no-op (evicted mid-stream)
        store.append_generated(99, &[1]);
        assert_eq!(store.history_len(99), 0);
        assert_eq!(store.hist_tokens, 5);
    }

    fn gen_server(kv: KvCacheConfig, max_streams: usize) -> Server {
        let router = Router::new(vec![Bucket {
            config: "serve_srv".into(),
            n_ctx: 32,
            batch: 4,
        }]);
        Server::builder(
            tiny_backend(&kv),
            router,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                max_streams,
                ..Default::default()
            },
        )
        .kv(kv)
        .start()
        .expect("server start")
    }

    use crate::coordinator::router::Bucket;
    use crate::generate::{GenerateRequest, StopReason};

    #[test]
    fn generate_streams_tokens_and_extends_the_session() {
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let server = gen_server(kv, 4);
        let prompt = vec![1i32, 2, 3, 4, 5, 6];
        let rx = server
            .submit_generate(7, GenerateRequest::greedy(prompt.clone(), 5))
            .expect("admitted");
        let mut tokens = Vec::new();
        let mut done = None;
        for event in rx.iter() {
            match event {
                StreamEvent::Token { index, token } => {
                    assert_eq!(index, tokens.len(), "tokens stream in order");
                    tokens.push(token);
                }
                StreamEvent::Done { reason, generated, .. } => {
                    assert_eq!(generated, tokens.len());
                    done = Some(reason);
                    break;
                }
            }
        }
        assert_eq!(done, Some(StopReason::MaxTokens));
        assert_eq!(tokens.len(), 5);
        // token-for-token identical to the direct single-stream loop
        let mut okv = backend.fresh_kv();
        let oracle = crate::generate::generate(
            &backend,
            &mut okv,
            &[],
            &GenerateRequest::greedy(prompt.clone(), 5),
            &crate::generate::GenLimits {
                max_total_tokens: 32,
                kv_budget_bytes: 1 << 20,
                ..crate::generate::GenLimits::unbounded()
            },
            |_, _| {},
        );
        assert_eq!(tokens, oracle.tokens);
        // the generated tokens joined the session: a follow-up turn's
        // logits equal a fresh forward over prompt + generated + append
        let append = vec![9i32, 10];
        let resp = server.infer_session(7, append.clone()).expect("turn served");
        let mut full = prompt;
        full.extend_from_slice(&tokens);
        full.extend_from_slice(&append);
        assert_eq!(resp.logits, backend.forward_logits(&full));
        assert_eq!(resp.cached_tokens, 6 + 5, "history includes the generated tokens");
        let snap = server.metrics.snapshot();
        assert_eq!(snap.gen_streams, 1);
        assert_eq!(snap.gen_tokens, 5);
        assert!(snap.ttft_p99_us > 0);
    }

    #[test]
    fn generate_rejects_bad_admissions() {
        let kv = kv_cfg(1 << 20);
        let server = gen_server(kv, 2);
        assert!(matches!(
            server.submit_generate(1, GenerateRequest::greedy(Vec::new(), 4)),
            Err(RejectReason::EmptyGeneration)
        ));
        assert!(
            matches!(
                server.submit_generate(1, GenerateRequest::greedy(vec![0; 33], 4)),
                Err(RejectReason::TooLong)
            ),
            "prompt longer than every bucket"
        );
        assert_eq!(server.sessions().lock().unwrap().history_len(1), 0, "no side effects");
    }

    #[test]
    fn mid_stream_budget_pressure_stops_without_resetting_the_session() {
        // regression: context overflow mid-generation must retire the
        // stream with StopReason::Budget, keeping the session history +
        // generated prefix, instead of the old silent restart
        let kv = kv_cfg(1 << 20);
        let server = gen_server(kv, 2); // bucket n_ctx = 32 caps streams
        let prompt: Vec<i32> = (0..28).collect();
        let out = server
            .generate_session(3, GenerateRequest::greedy(prompt, 100))
            .expect("stream served");
        assert_eq!(out.reason, StopReason::Budget);
        // decodes allowed while len < 32: tokens sampled at len 28..=31,
        // leaving the history exactly AT the context cap
        assert_eq!(out.tokens.len(), 4);
        let store = server.sessions();
        let hist = store.lock().unwrap().history_len(3);
        assert_eq!(hist, 32, "history keeps prompt AND generated prefix, within the cap");
        assert_eq!(server.metrics.snapshot().gen_budget_stops, 1);
    }

    #[test]
    fn kv_byte_budget_stops_generation_mid_stream() {
        // 2 layers x 2 heads x d_head 16, page_tokens 4 -> 288 B per
        // chain-page; budget of 2 pages/chain = 2304 B total
        let kv = kv_cfg(2 * 4 * 288);
        let backend = tiny_backend(&kv);
        assert_eq!(backend.fresh_kv().bytes_at(8), 2 * 4 * 288);
        let server = gen_server(kv, 2);
        let out = server
            .generate_session(4, GenerateRequest::greedy(vec![1, 2, 3, 4], 100))
            .expect("stream served");
        assert_eq!(out.reason, StopReason::Budget);
        assert_eq!(out.tokens.len(), 5, "decodes allowed while bytes_at(len) fits 2 pages");
        // the stream's pages were checked in intact (no silent reset)
        assert_eq!(server.sessions().lock().unwrap().history_len(4), 9);
        assert_eq!(server.cache_stats().misses, 1, "one cold stream, never restarted");
    }

    #[test]
    fn empty_prompt_continue_resumes_without_reprefill() {
        // a generation that merely CONTINUES a fully-decoded session
        // (empty prompt after a classification turn) must count as a
        // pool hit and produce the same tokens as a cold stream over the
        // same context
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let server = gen_server(kv, 2);
        let context = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
        server.infer_session(6, context.clone()).expect("turn served");
        let out = server
            .generate_session(6, GenerateRequest::greedy(Vec::new(), 4))
            .expect("continue stream served");
        assert_eq!(out.reason, StopReason::MaxTokens);
        let mut okv = backend.fresh_kv();
        let oracle = crate::generate::generate(
            &backend,
            &mut okv,
            &context,
            &GenerateRequest::greedy(Vec::new(), 4),
            &crate::generate::GenLimits {
                max_total_tokens: 32,
                kv_budget_bytes: 1 << 20,
                ..crate::generate::GenLimits::unbounded()
            },
            |_, _| {},
        );
        assert_eq!(out.tokens, oracle.tokens);
        let stats = server.cache_stats();
        assert_eq!(
            (stats.hits, stats.misses),
            (1, 1),
            "the continue stream is a HIT on the turn's resident pages"
        );
    }

    #[test]
    fn concurrent_streams_interleave_and_stay_deterministic() {
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let server = gen_server(kv, 4);
        let mk_req = |seed: u64| GenerateRequest {
            prompt: vec![seed as i32 % 8, 3, 1 + seed as i32 % 5, 4],
            max_new_tokens: 6,
            stop_tokens: Vec::new(),
            sampling: crate::generate::SamplingParams {
                temperature: 0.7,
                top_k: 2,
                top_p: 1.0,
                seed,
            },
        };
        // submit all before draining: all streams live simultaneously
        let rxs: Vec<_> = (0..3u64)
            .map(|sid| (sid, server.submit_generate(sid, mk_req(sid)).expect("admitted")))
            .collect();
        for (sid, rx) in rxs {
            let mut tokens = Vec::new();
            for event in rx.iter() {
                match event {
                    StreamEvent::Token { token, .. } => tokens.push(token),
                    StreamEvent::Done { reason, .. } => {
                        assert_eq!(reason, StopReason::MaxTokens);
                        break;
                    }
                }
            }
            let mut okv = backend.fresh_kv();
            let oracle = crate::generate::generate(
                &backend,
                &mut okv,
                &[],
                &mk_req(sid),
                &crate::generate::GenLimits {
                    max_total_tokens: 32,
                    kv_budget_bytes: 1 << 20,
                    ..crate::generate::GenLimits::unbounded()
                },
                |_, _| {},
            );
            assert_eq!(
                tokens, oracle.tokens,
                "stream {sid} must match the direct engine under interleaving"
            );
        }
        assert_eq!(server.metrics.snapshot().gen_streams, 3);
    }

    fn gen_server_policy(kv: KvCacheConfig, policy: BatchPolicy) -> Server {
        let router = Router::new(vec![Bucket {
            config: "serve_srv".into(),
            n_ctx: 32,
            batch: 4,
        }]);
        Server::builder(tiny_backend(&kv), router, policy).kv(kv).start().expect("server start")
    }

    #[test]
    fn aggregate_admission_defers_streams_beyond_pool_budget() {
        // budget fits exactly ONE stream's worst-case reservation:
        // 4 prompt + 4 new = 8 tokens -> 2 pages x (2 layers x 2 heads)
        // chains x 288 B/page. max_streams alone (4) would over-commit.
        let budget = 2 * 4 * 288;
        let kv = kv_cfg(budget);
        let backend = tiny_backend(&kv);
        assert_eq!(backend.fresh_kv().bytes_at(8), budget);
        let server = gen_server(kv, 4);
        let rx1 = server
            .submit_generate(1, GenerateRequest::greedy(vec![1, 2, 3, 4], 4))
            .expect("admitted");
        let rx2 = server
            .submit_generate(2, GenerateRequest::greedy(vec![4, 3, 2, 1], 4))
            .expect("admitted");
        let collect = |rx: Receiver<StreamEvent>| {
            let mut tokens = Vec::new();
            for event in rx.iter() {
                match event {
                    StreamEvent::Token { token, .. } => tokens.push(token),
                    StreamEvent::Done { reason, .. } => return (tokens, reason),
                }
            }
            panic!("server dropped the stream");
        };
        let (t1, r1) = collect(rx1);
        let (t2, r2) = collect(rx2);
        assert_eq!((r1, r2), (StopReason::MaxTokens, StopReason::MaxTokens));
        // serialized by the reservation, NOT truncated: both streams run
        // to completion token-identical to the direct single-stream loop
        for (prompt, tokens) in [(vec![1i32, 2, 3, 4], &t1), (vec![4i32, 3, 2, 1], &t2)] {
            let mut okv = backend.fresh_kv();
            let oracle = crate::generate::generate(
                &backend,
                &mut okv,
                &[],
                &GenerateRequest::greedy(prompt, 4),
                &crate::generate::GenLimits {
                    max_total_tokens: 32,
                    kv_budget_bytes: budget,
                    ..crate::generate::GenLimits::unbounded()
                },
                |_, _| {},
            );
            assert_eq!(tokens, &oracle.tokens);
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.gen_streams, 2);
        assert!(
            snap.admission_deferrals > 0,
            "the second stream must wait for the first's reservation"
        );
    }

    #[test]
    fn deadline_exceeded_retires_stream() {
        let kv = kv_cfg(1 << 20);
        let server = gen_server_policy(
            kv,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                stream_deadline_ms: 0,
                ..Default::default()
            },
        );
        let out = server
            .generate_session(1, GenerateRequest::greedy(vec![1, 2, 3], 8))
            .expect("stream served");
        assert_eq!(out.reason, StopReason::DeadlineExceeded);
        assert!(out.tokens.is_empty(), "a zero deadline fires before the first step");
        let snap = server.metrics.snapshot();
        assert_eq!(snap.deadline_exceeded, 1);
        assert_eq!(snap.gen_streams, 1);
    }

    #[test]
    fn slow_reader_is_disconnected_not_wedged() {
        let kv = kv_cfg(1 << 20);
        let server = gen_server_policy(
            kv,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                stream_event_cap: 1,
                ..Default::default()
            },
        );
        // never read the channel: it fills after one token and the
        // stream must retire as Disconnected instead of wedging the tick
        let rx = server
            .submit_generate(1, GenerateRequest::greedy(vec![1, 2, 3], 8))
            .expect("admitted");
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let snap = server.metrics.snapshot();
            if snap.gen_streams == 1 {
                assert!(snap.slow_reader_disconnects >= 1);
                break;
            }
            assert!(
                Instant::now() < deadline,
                "stream never retired: scheduler wedged behind a slow reader"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        drop(rx);
    }

    #[test]
    fn chunked_prefill_streams_identical_tokens() {
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let server = gen_server_policy(
            kv,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                prefill_chunk: 2,
                ..Default::default()
            },
        );
        let prompt: Vec<i32> = (0..12).map(|i| i % 8).collect();
        let out = server
            .generate_session(5, GenerateRequest::greedy(prompt.clone(), 6))
            .expect("stream served");
        assert_eq!(out.reason, StopReason::MaxTokens);
        let mut okv = backend.fresh_kv();
        let oracle = crate::generate::generate(
            &backend,
            &mut okv,
            &[],
            &GenerateRequest::greedy(prompt, 6),
            &crate::generate::GenLimits {
                max_total_tokens: 32,
                kv_budget_bytes: 1 << 20,
                ..crate::generate::GenLimits::unbounded()
            },
            |_, _| {},
        );
        assert_eq!(
            out.tokens, oracle.tokens,
            "chunked prefill must be bit-identical to one-shot prefill"
        );
    }

    #[test]
    fn worker_panic_is_isolated_and_counted() {
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let router = Router::new(vec![Bucket {
            config: "serve_srv".into(),
            n_ctx: 32,
            batch: 4,
        }]);
        let server = Server::builder(
            tiny_backend(&kv),
            router,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                ..Default::default()
            },
        )
        .kv(kv)
        .chaos(FaultPlan::parse("worker_panic").expect("plan"))
        .start()
        .expect("server start");
        let out = server
            .generate_session(1, GenerateRequest::greedy(vec![1, 2, 3], 4))
            .expect("stream served");
        assert_eq!(out.reason, StopReason::Error);
        assert!(out.tokens.is_empty());
        // the scheduler survived the poisoned shard: a classification
        // turn on the same server still serves real logits
        let resp = server.infer_session(2, vec![1, 2, 3]).expect("turn served");
        assert_eq!(resp.logits, backend.forward_logits(&[1, 2, 3]));
        let snap = server.metrics.snapshot();
        assert_eq!(snap.stream_errors, 1);
        assert!(snap.faults_injected >= 1);
    }

    #[test]
    fn drop_drains_live_streams_with_shutdown_reason() {
        let kv = kv_cfg(1 << 20);
        let router = Router::new(vec![Bucket {
            config: "serve_srv".into(),
            n_ctx: 32,
            batch: 4,
        }]);
        // slow every step down so the stream is still live at drop time
        let server = Server::builder(
            tiny_backend(&kv),
            router,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                drain_grace: std::time::Duration::ZERO,
                ..Default::default()
            },
        )
        .kv(kv)
        .chaos(FaultPlan::parse("decode_step:1.0:20").expect("plan"))
        .start()
        .expect("server start");
        let metrics = Arc::clone(&server.metrics);
        let rx = server
            .submit_generate(1, GenerateRequest::greedy(vec![1, 2, 3], 100))
            .expect("admitted");
        drop(server); // shutdown: zero grace forces the live stream out
        let mut reason = None;
        for event in rx.iter() {
            if let StreamEvent::Done { reason: r, .. } = event {
                reason = Some(r);
                break;
            }
        }
        assert_eq!(reason, Some(StopReason::Shutdown));
        assert_eq!(metrics.snapshot().drain_shutdowns, 1);
    }

    fn spill_server(kv: KvCacheConfig) -> (Server, Arc<crate::store::SpillStore>) {
        let dir = std::env::temp_dir().join("had-spill-server-test");
        let spill =
            Arc::new(crate::store::SpillStore::create(&dir, None).expect("spill store"));
        let router = Router::new(vec![Bucket {
            config: "serve_srv".into(),
            n_ctx: 32,
            batch: 4,
        }]);
        let server = Server::builder(
            tiny_backend(&kv),
            router,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                max_streams: 4,
                ..Default::default()
            },
        )
        .kv(kv)
        .spill(Arc::clone(&spill))
        .start()
        .expect("server start");
        (server, spill)
    }

    #[test]
    fn spilled_session_hydrates_with_bit_identical_logits() {
        // budget fits exactly ONE 8-token session (2 stripes x 4 chains
        // x 288 B): admitting a second session forces the first's
        // stripes to the disk tier instead of destroying it
        let budget = 2 * 4 * 288;
        let kv = kv_cfg(budget);
        let backend = tiny_backend(&kv);
        let (server, spill) = spill_server(kv);
        let t1: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        server.infer_session(1, t1.clone()).expect("turn 1");
        server.infer_session(2, vec![9, 10, 11, 12, 13, 14, 15, 16]).expect("turn 2");
        let stats = server.cache_stats();
        assert!(stats.spill_pages_out > 0, "budget pressure spilled, stats: {stats:?}");
        assert_eq!(stats.evictions, 0, "spilling replaced eviction");
        assert!(spill.live_records() > 0, "stripes live on disk");
        // the follow-up turn hydrates session 1 and its logits are
        // bit-identical to a fresh forward over the full history
        let append = vec![3i32, 1];
        let resp = server.infer_session(1, append.clone()).expect("turn 3");
        let mut full = t1;
        full.extend_from_slice(&append);
        assert_eq!(resp.logits, backend.forward_logits(&full));
        assert_eq!(resp.cached_tokens, 8, "session resumed from history, not restarted");
        let stats = server.cache_stats();
        assert!(stats.hydrate_hits >= 1, "checkout hydrated, stats: {stats:?}");
        assert!(stats.spill_pages_in >= 8, "both stripes came back, stats: {stats:?}");
        assert_eq!(stats.store_checksum_failures, 0);
        // the pool counters land in the metrics registry under pinned
        // names (the /v1/metrics and metrics.jsonl wire contract)
        let snap = server.metrics.snapshot();
        assert!(snap.spill_pages_out > 0 && snap.spill_pages_in >= 8);
        assert!(snap.hydrate_hits >= 1);
        assert_eq!(snap.store_checksum_failures, 0);
    }

    #[test]
    fn continue_stream_over_hydrated_kv_is_token_identical() {
        // budget = the continuing stream's final state (3 stripes); a
        // middle turn on another session spills stream 1's stripes, so
        // the continuation must hydrate before decoding
        let budget = 3 * 4 * 288;
        let kv = kv_cfg(budget);
        let backend = tiny_backend(&kv);
        let (server, _spill) = spill_server(kv);
        let prompt = vec![1i32, 2, 3, 4];
        let out_a = server
            .generate_session(1, GenerateRequest::greedy(prompt.clone(), 4))
            .expect("stream A");
        assert_eq!(out_a.reason, StopReason::MaxTokens);
        server.infer_session(2, vec![5, 6, 7, 8, 9, 10, 11, 12]).expect("pressure turn");
        assert!(server.cache_stats().spill_pages_out > 0, "stream A's stripes spilled");
        let out_b = server
            .generate_session(1, GenerateRequest::greedy(Vec::new(), 3))
            .expect("continue stream");
        assert_eq!(out_b.reason, StopReason::MaxTokens);
        // token-for-token identical to the direct loop over the same
        // context — the hydrated pages ARE the original pages
        let mut context = prompt;
        context.extend_from_slice(&out_a.tokens);
        let mut okv = backend.fresh_kv();
        let oracle = crate::generate::generate(
            &backend,
            &mut okv,
            &context,
            &GenerateRequest::greedy(Vec::new(), 3),
            &crate::generate::GenLimits {
                max_total_tokens: 32,
                kv_budget_bytes: budget,
                ..crate::generate::GenLimits::unbounded()
            },
            |_, _| {},
        );
        assert_eq!(out_b.tokens, oracle.tokens, "hydrated continuation must not drift");
        let stats = server.cache_stats();
        assert!(stats.hydrate_hits >= 1, "continuation hydrated, stats: {stats:?}");
        assert_eq!(stats.store_checksum_failures, 0);
    }

    fn sharing_server(kv: KvCacheConfig, max_streams: usize) -> Server {
        let router = Router::new(vec![Bucket {
            config: "serve_srv".into(),
            n_ctx: 32,
            batch: 4,
        }]);
        Server::builder(
            tiny_backend(&kv),
            router,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                max_streams,
                ..Default::default()
            },
        )
        .kv(kv)
        .prefix_sharing(true)
        .start()
        .expect("server start")
    }

    fn collect_stream(rx: Receiver<StreamEvent>) -> (Vec<i32>, StopReason) {
        let mut tokens = Vec::new();
        for event in rx.iter() {
            match event {
                StreamEvent::Token { token, .. } => tokens.push(token),
                StreamEvent::Done { reason, .. } => return (tokens, reason),
            }
        }
        panic!("server dropped the stream");
    }

    #[test]
    fn identical_prompt_streams_share_one_prefill_bit_identically() {
        // N concurrent streams over ONE identical prompt: the elected
        // prefiller pays the prompt's prefill, the others adopt its
        // published stripes — tokens bit-identical to the sharing-off
        // baseline, and the pool drains to zero once every session ends
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let prompt: Vec<i32> = (0..12).map(|i| i % 8).collect();
        let n = 4u64;

        let baseline = gen_server(kv, n as usize); // sharing OFF
        let shared = sharing_server(kv, n as usize); // sharing ON
        let run = |server: &Server| -> Vec<Vec<i32>> {
            let rxs: Vec<_> = (1..=n)
                .map(|sid| {
                    server
                        .submit_generate(sid, GenerateRequest::greedy(prompt.clone(), 4))
                        .expect("admitted")
                })
                .collect();
            rxs.into_iter()
                .map(|rx| {
                    let (tokens, reason) = collect_stream(rx);
                    assert_eq!(reason, StopReason::MaxTokens);
                    tokens
                })
                .collect()
        };
        let base_tokens = run(&baseline);
        let shared_tokens = run(&shared);
        assert_eq!(
            shared_tokens, base_tokens,
            "prefix sharing must be bit-identical to unshared serving"
        );
        for t in &shared_tokens[1..] {
            assert_eq!(t, &shared_tokens[0], "identical prompts generate identically");
        }

        // prompt stripes below the last token: floor(11 / 4) = 2 stripes
        // of 4 tokens; every follower adopts exactly those 8 tokens
        let stats = shared.cache_stats();
        assert!(stats.shared_pages > 0, "stripes published, stats: {stats:?}");
        assert_eq!(
            stats.prefix_tokens_reused,
            (n - 1) * 8,
            "each follower adopts the shareable prompt prefix exactly once"
        );
        assert!(stats.prefix_hits >= n - 1, "stats: {stats:?}");
        let base_stats = baseline.cache_stats();
        assert_eq!(
            (base_stats.shared_pages, base_stats.prefix_hits, base_stats.prefix_tokens_reused),
            (0, 0, 0),
            "sharing off: counters stay zero"
        );

        // every stream retired warm: ending the sessions must drain both
        // the private pool AND the shared registry to zero bytes
        let store = shared.sessions();
        let mut store = store.lock().unwrap();
        assert!(store.pool().bytes() > 0);
        for sid in 1..=n {
            store.end_session(sid);
        }
        assert_eq!(store.pool().bytes(), 0, "shared pages drain with their last reference");
        drop(store);

        // one ordinary turn on a fresh session over the same sequence:
        // logits equal a fresh forward over it
        let mut full = prompt;
        full.extend_from_slice(&base_tokens[0]);
        let resp = baseline.infer_session(9, full.clone()).expect("turn served");
        assert_eq!(resp.logits, backend.forward_logits(&full));
    }

    #[test]
    fn divergence_after_sharing_is_copy_on_write() {
        // a continue-generation stream truncates INSIDE a shared stripe
        // (dropping the last row to re-decode it): the cut must copy the
        // stripe private (COW) and stay token-identical to the direct
        // loop — per-session determinism is untouched by sharing
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let server = sharing_server(kv, 2);
        let context = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
        server.infer_session(6, context.clone()).expect("turn served");
        // the turn's checkin published both full stripes
        assert!(server.cache_stats().shared_pages >= 8);
        let out = server
            .generate_session(6, GenerateRequest::greedy(Vec::new(), 4))
            .expect("continue stream served");
        assert_eq!(out.reason, StopReason::MaxTokens);
        let mut okv = backend.fresh_kv();
        let oracle = crate::generate::generate(
            &backend,
            &mut okv,
            &context,
            &GenerateRequest::greedy(Vec::new(), 4),
            &crate::generate::GenLimits {
                max_total_tokens: 32,
                kv_budget_bytes: 1 << 20,
                ..crate::generate::GenLimits::unbounded()
            },
            |_, _| {},
        );
        assert_eq!(out.tokens, oracle.tokens, "COW divergence must not drift");
        let stats = server.cache_stats();
        assert!(
            stats.cow_copies >= 4,
            "truncate(7) cut inside shared stripe 1 -> one copy per chain, stats: {stats:?}"
        );
    }

    #[test]
    fn shared_entry_survives_spill_while_unreferenced_and_rehydrates() {
        // a shared entry whose last referencing session ends spills ONCE
        // to the disk tier (instead of being destroyed); a later
        // identical prompt hydrates and adopts it bit-identically
        let dir = std::env::temp_dir().join("had-prefix-spill-server-test");
        let spill =
            Arc::new(crate::store::SpillStore::create(&dir, None).expect("spill store"));
        let kv = kv_cfg(1 << 20);
        let backend = tiny_backend(&kv);
        let router = Router::new(vec![Bucket {
            config: "serve_srv".into(),
            n_ctx: 32,
            batch: 4,
        }]);
        let server = Server::builder(
            tiny_backend(&kv),
            router,
            BatchPolicy {
                max_wait: std::time::Duration::from_millis(1),
                max_streams: 4,
                ..Default::default()
            },
        )
        .kv(kv)
        .spill(Arc::clone(&spill))
        .prefix_sharing(true)
        .start()
        .expect("server start");

        let prompt = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
        server.infer_session(1, prompt.clone()).expect("turn served");
        assert!(server.cache_stats().shared_pages >= 8, "both stripes published");
        // last reference gone: the registry entries spill to disk
        server.sessions().lock().unwrap().end_session(1);
        let stats = server.cache_stats();
        assert!(
            stats.spill_pages_out >= 8,
            "zero-ref shared entries spill once, stats: {stats:?}"
        );
        assert!(spill.live_records() > 0, "entries live on disk");
        assert_eq!(
            server.sessions().lock().unwrap().pool().bytes(),
            0,
            "nothing resident while unreferenced"
        );
        // an identical prompt on a NEW session hydrates + adopts the
        // spilled prefix (only the stripe below the last token: tokens
        // 0..4), and generates exactly what a cold loop would
        let out = server
            .generate_session(2, GenerateRequest::greedy(prompt.clone(), 3))
            .expect("stream served");
        assert_eq!(out.reason, StopReason::MaxTokens);
        let mut okv = backend.fresh_kv();
        let oracle = crate::generate::generate(
            &backend,
            &mut okv,
            &[],
            &GenerateRequest::greedy(prompt, 3),
            &crate::generate::GenLimits {
                max_total_tokens: 32,
                kv_budget_bytes: 1 << 20,
                ..crate::generate::GenLimits::unbounded()
            },
            |_, _| {},
        );
        assert_eq!(out.tokens, oracle.tokens, "hydrated adoption must not drift");
        let stats = server.cache_stats();
        assert!(stats.prefix_hits >= 1, "stats: {stats:?}");
        assert!(stats.spill_pages_in >= 4, "stripe 0 hydrated, stats: {stats:?}");
        assert_eq!(stats.store_checksum_failures, 0);
    }
}
