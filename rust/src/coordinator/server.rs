//! The serving engine: router + dynamic batcher + PJRT engine thread.
//!
//! Architecture (single PJRT device, per DESIGN.md):
//!
//!   clients --submit()--> shared bucket queues --scheduler thread-->
//!     assemble padded batch --> EngineHandle (PJRT thread) -->
//!     logits --> per-request reply channels ; Metrics throughout
//!
//! Backpressure: bounded per-bucket admission queues; `submit` rejects
//! with `QueueFull` rather than queueing unboundedly.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::binary::HadAttnConfig;
use crate::coordinator::batcher::{assemble_padded, BatchPolicy, BucketQueue};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{RejectReason, Request, Response, SessionInfo};
use crate::coordinator::router::Router;
use crate::kvcache::{CacheStats, KvCacheConfig, PagePool, SessionKv};
use crate::log_info;
use crate::log_warn;
use crate::model::Checkpoint;
use crate::runtime::{EngineHandle, HostTensor, Manifest};
use crate::tensor::ops::argmax;
use crate::tensor::Mat;
use crate::util::threadpool::parallel_map_n;

/// Weights + calibration served for one bucket.
#[derive(Clone)]
pub struct ServingModel {
    pub params: Vec<HostTensor>,
    pub sigma_q: Vec<f32>,
    pub sigma_k: Vec<f32>,
    pub n_top: f32,
    /// forward artifact name within the bucket's config ("fwd_had", ...)
    pub fwd: String,
}

impl ServingModel {
    pub fn from_checkpoint(ckpt: &Checkpoint, n_top: f32, fwd: &str) -> ServingModel {
        ServingModel {
            params: ckpt.params.tensors.clone(),
            sigma_q: ckpt.sigma_q.clone(),
            sigma_k: ckpt.sigma_k.clone(),
            n_top,
            fwd: fwd.to_string(),
        }
    }

    /// Randomly initialized model (latency/throughput demos where accuracy
    /// is irrelevant).
    pub fn random(
        manifest: &Manifest,
        config: &str,
        seed: u64,
        fwd: &str,
    ) -> Result<ServingModel> {
        let cfg = manifest.config(config)?;
        let mut rng = crate::util::rng::Rng::new(seed);
        let params = crate::model::ParamSet::init(cfg, &mut rng);
        Ok(ServingModel {
            params: params.tensors,
            sigma_q: vec![1.0; cfg.model.n_layers],
            sigma_k: vec![1.0; cfg.model.n_layers],
            n_top: cfg.model.n_top as f32,
            fwd: fwd.to_string(),
        })
    }
}

/// Token vocabulary of the session featurizer (matches `data`'s configs).
pub const SESSION_VOCAB: usize = 256;
/// Head geometry of the admission-side packed KV pages.
pub const SESSION_KEY_DIM: usize = 64;
pub const SESSION_VAL_DIM: usize = 64;
/// Query rows the scheduler's kernel pass featurizes per session request
/// (a decode-style block over the turn's most recent tokens).
const KERNEL_QUERY_ROWS: usize = 8;
/// Top-N the scheduler's kernel pass keeps (clamped to the context).
const KERNEL_TOP_N: usize = 32;

/// Session-side admission state: per-session token histories plus the
/// byte-budgeted page pool holding each session's packed K/V.
///
/// K/V rows come from a fixed embedding-style featurizer (a seeded random
/// projection per vocabulary entry) — the admission-path stand-in for the
/// model's per-layer K/V projections until a full CPU-bitpacked serving
/// backend lands (ROADMAP §KV cache & sessions). The work it models is
/// real: each turn binarizes/packs exactly the non-resident suffix, and
/// the resident pages are scoreable with `had_attention_paged`.
pub struct SessionStore {
    pool: PagePool,
    histories: HashMap<u64, Vec<i32>>,
    key_emb: Mat,
    val_emb: Mat,
}

/// Map tokens to rows of one embedding table (row = token % vocab) — the
/// key-only half, enough for query featurization.
fn featurize_one(emb: &Mat, tokens: &[i32]) -> Mat {
    let mut out = Mat::zeros(tokens.len(), emb.cols);
    for (i, &t) in tokens.iter().enumerate() {
        let row = t.rem_euclid(SESSION_VOCAB as i32) as usize;
        out.row_mut(i).copy_from_slice(emb.row(row));
    }
    out
}

/// Map tokens to K/V rows via the embedding tables (row = token % vocab).
/// Free function so `admit` can featurize a borrowed history slice.
fn featurize(key_emb: &Mat, val_emb: &Mat, tokens: &[i32]) -> (Mat, Mat) {
    (featurize_one(key_emb, tokens), featurize_one(val_emb, tokens))
}

impl SessionStore {
    pub fn new(cfg: KvCacheConfig, d: usize, d_v: usize, seed: u64) -> SessionStore {
        let mut rng = crate::util::rng::Rng::new(seed);
        SessionStore {
            pool: PagePool::new(cfg),
            histories: HashMap::new(),
            key_emb: Mat::random(SESSION_VOCAB, d, &mut rng, 1.0),
            val_emb: Mat::random(SESSION_VOCAB, d_v, &mut rng, 1.0),
        }
    }

    /// Tokens the session has accumulated across turns.
    pub fn history_len(&self, session_id: u64) -> usize {
        self.histories.get(&session_id).map_or(0, Vec::len)
    }

    pub fn tokens(&self, session_id: u64) -> &[i32] {
        self.histories
            .get(&session_id)
            .map_or(&[] as &[i32], |v| v.as_slice())
    }

    /// Admit one turn: extend the history, then binarize-pack exactly the
    /// non-resident suffix.
    ///
    /// Histories live exactly as long as the session's pages: when the
    /// pool evicts a session its token history is dropped too, so the
    /// store is bounded by the byte budget rather than by how many
    /// distinct session ids clients ever used. An evicted session's next
    /// turn therefore starts a fresh context (`cached_tokens == 0` in
    /// the response tells the client to resend context if it needs the
    /// old prefix).
    pub fn admit(&mut self, session_id: u64, append: &[i32]) -> SessionInfo {
        let cached = self.pool.cached_tokens(session_id);
        if cached == 0 {
            // absent or evicted: restart the history with this turn
            self.histories.remove(&session_id);
        }
        let hist = self.histories.entry(session_id).or_default();
        hist.extend_from_slice(append);
        let appended_tokens = hist.len() - cached;
        if appended_tokens > 0 {
            let (k, v) = featurize(&self.key_emb, &self.val_emb, &hist[cached..]);
            self.pool.append(session_id, &k, &v);
        }
        // drop histories of sessions the pool just evicted (boundedness)
        let pool = &self.pool;
        self.histories
            .retain(|id, _| *id == session_id || pool.peek(*id).is_some());
        SessionInfo { id: session_id, cached_tokens: cached, appended_tokens }
    }

    /// Borrow the resident pages for paged scoring (refreshes LRU).
    pub fn kv(&mut self, session_id: u64) -> Option<&SessionKv> {
        self.pool.get(session_id)
    }

    /// Featurize the last `n_q` tokens of a session's history as a query
    /// block for the kernel scoring pass (same embedding space as the
    /// keys, so Hamming scores are meaningful; the value half is not
    /// computed — this runs under the sessions lock). None when the
    /// session has no history.
    pub fn featurize_queries(&self, session_id: u64, n_q: usize) -> Option<Mat> {
        let hist = self.histories.get(&session_id)?;
        if hist.is_empty() {
            return None;
        }
        let lo = hist.len().saturating_sub(n_q);
        Some(featurize_one(&self.key_emb, &hist[lo..]))
    }

    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Undo one `admit` (queue-full rollback): restore the history and
    /// pages to the lengths captured before the turn. Evictions of OTHER
    /// sessions the transient growth triggered are not undone — eviction
    /// is always semantically safe. When the session was absent or
    /// evicted before the turn (`cached_before == 0`) it is dropped
    /// outright.
    pub fn rollback_turn(&mut self, session_id: u64, hist_before: usize, cached_before: usize) {
        if cached_before == 0 {
            self.end_session(session_id);
            return;
        }
        if let Some(hist) = self.histories.get_mut(&session_id) {
            hist.truncate(hist_before);
        }
        self.pool.truncate_session(session_id, cached_before);
    }

    /// Conversation over: drop history and pages (not counted as eviction).
    pub fn end_session(&mut self, session_id: u64) {
        self.histories.remove(&session_id);
        self.pool.remove(session_id);
    }
}

struct Shared {
    queues: Mutex<Vec<BucketQueue>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

pub struct Server {
    router: Router,
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    sessions: Arc<Mutex<SessionStore>>,
    next_id: AtomicU64,
    scheduler: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the scheduler thread. `models[i]` corresponds to
    /// `router.buckets()[i]`. The KV-cache pool uses default sizing; use
    /// `start_with_kv` to tune it.
    pub fn start(
        engine: EngineHandle,
        router: Router,
        models: Vec<ServingModel>,
        policy: BatchPolicy,
    ) -> Result<Server> {
        Server::start_with_kv(engine, router, models, policy, KvCacheConfig::default(), 0x5E55)
    }

    /// Start with an explicit KV-cache configuration and featurizer seed.
    pub fn start_with_kv(
        engine: EngineHandle,
        router: Router,
        models: Vec<ServingModel>,
        policy: BatchPolicy,
        kv: KvCacheConfig,
        kv_seed: u64,
    ) -> Result<Server> {
        anyhow::ensure!(
            models.len() == router.buckets().len(),
            "one ServingModel per bucket required"
        );
        let queues: Vec<BucketQueue> = router
            .buckets()
            .iter()
            .map(|b| BucketQueue::new(b.clone(), policy))
            .collect();
        let shared = Arc::new(Shared {
            queues: Mutex::new(queues),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(Metrics::default());
        let sessions = Arc::new(Mutex::new(SessionStore::new(
            kv,
            SESSION_KEY_DIM,
            SESSION_VAL_DIM,
            kv_seed,
        )));

        let sched_shared = Arc::clone(&shared);
        let sched_metrics = Arc::clone(&metrics);
        let sched_sessions = Arc::clone(&sessions);
        let kernel_workers = policy.kernel_workers.max(1);
        let scheduler = std::thread::Builder::new()
            .name("had-scheduler".into())
            .spawn(move || {
                scheduler_main(
                    sched_shared,
                    engine,
                    models,
                    sched_metrics,
                    sched_sessions,
                    kernel_workers,
                )
            })
            .context("spawning scheduler")?;

        Ok(Server {
            router,
            shared,
            metrics,
            sessions,
            next_id: AtomicU64::new(0),
            scheduler: Some(scheduler),
        })
    }

    /// Submit a request; returns the reply channel.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<Receiver<Response>, RejectReason> {
        if self.shared.shutdown.load(Ordering::Relaxed) {
            return Err(RejectReason::ShuttingDown);
        }
        let bucket_idx = self.router.route_idx(tokens.len())?;
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            arrival: Instant::now(),
            reply: tx,
            session: None,
        };
        let mut queues = self.shared.queues.lock().unwrap();
        match queues[bucket_idx].push(req) {
            Ok(()) => {
                self.shared.cv.notify_one();
                Ok(rx)
            }
            Err(_req) => {
                self.metrics.record_reject();
                Err(RejectReason::QueueFull)
            }
        }
    }

    /// Submit one turn of a multi-turn session: `append_tokens` extends
    /// the session's history and only the non-resident suffix is packed
    /// into the page pool; the request then executes over the full
    /// sequence, routed by total length (`Router::route_session_idx`).
    ///
    /// Rejection is side-effect-free: admission (featurize + bit-pack)
    /// runs under the sessions lock only — the global queue lock is taken
    /// just for the push, and a `QueueFull` push rolls the turn back —
    /// so a rejected turn can simply be retried with the same
    /// `append_tokens`.
    pub fn submit_session(
        &self,
        session_id: u64,
        append_tokens: Vec<i32>,
    ) -> Result<Receiver<Response>, RejectReason> {
        if self.shared.shutdown.load(Ordering::Relaxed) {
            return Err(RejectReason::ShuttingDown);
        }
        let mut store = self.sessions.lock().unwrap();
        let hist_before = store.history_len(session_id);
        let cached_before = store.pool().cached_tokens(session_id);
        // An evicted session restarts its context on admit (see
        // SessionStore::admit), so the served length is append-only then.
        let resident_prefix = if cached_before == 0 { 0 } else { hist_before };
        let bucket_idx = self
            .router
            .route_session_idx(resident_prefix, append_tokens.len())?;
        let info = store.admit(session_id, &append_tokens);
        let tokens = store.tokens(session_id).to_vec();

        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            arrival: Instant::now(),
            reply: tx,
            session: Some(info),
        };
        let pushed = {
            let mut queues = self.shared.queues.lock().unwrap();
            match queues[bucket_idx].push(req) {
                Ok(()) => {
                    self.shared.cv.notify_one();
                    true
                }
                Err(_req) => false,
            }
        };
        if !pushed {
            store.rollback_turn(session_id, hist_before, cached_before);
            drop(store);
            self.metrics.record_reject();
            return Err(RejectReason::QueueFull);
        }
        // publish gauges before releasing the sessions lock so a
        // concurrent admission cannot overwrite them with older values
        self.metrics.record_session(info.cached_tokens, info.appended_tokens);
        self.metrics
            .update_cache_pool(store.pool().bytes(), store.pool().stats().evictions);
        drop(store);
        Ok(rx)
    }

    /// Blocking convenience: submit and wait for the response.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let rx = self
            .submit(tokens)
            .map_err(|r| anyhow::anyhow!("rejected: {r}"))?;
        rx.recv().context("server dropped the request")
    }

    /// Blocking convenience for one session turn.
    pub fn infer_session(&self, session_id: u64, append_tokens: Vec<i32>) -> Result<Response> {
        let rx = self
            .submit_session(session_id, append_tokens)
            .map_err(|r| anyhow::anyhow!("rejected: {r}"))?;
        rx.recv().context("server dropped the request")
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Shared handle to the session store (demos, draining, inspection).
    pub fn sessions(&self) -> Arc<Mutex<SessionStore>> {
        Arc::clone(&self.sessions)
    }

    /// Snapshot of the page-pool counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.sessions.lock().unwrap().pool().stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        if let Some(t) = self.scheduler.take() {
            let _ = t.join();
        }
    }
}

/// Score one drained batch's session requests with the blocked
/// XNOR-popcount kernel, sessions sharded across `workers` scoped
/// threads. Returns the per-request kernel time (µs; 0 for sessionless
/// requests or sessions whose pages were evicted between admission and
/// execution).
///
/// The sessions lock is taken once per request, only long enough to
/// snapshot that request's `SessionKv` and featurize its query block —
/// the snapshot copies the f32 value pages too, which dominates its
/// cost, so holds are kept per-request rather than one batch-wide hold
/// (Arc-shared pages are the follow-up that would drop the copy, see
/// ROADMAP). Scoring itself runs lock-free, so concurrent admissions
/// stall at most for one snapshot, never for the scoring pass.
///
/// This is the CPU-bitpacked scoring pass of batch execution: each
/// request's decode-style query block (its most recent tokens,
/// featurized like the keys) attends over the session's resident packed
/// pages. Until the full CPU serving backend replaces PJRT re-execution
/// (ROADMAP §attention kernel), its product is the per-request kernel
/// timing recorded in `Metrics` and echoed on the `Response`.
fn kernel_pass(
    workers: usize,
    sessions: &Mutex<SessionStore>,
    reqs: &[Request],
    metrics: &Metrics,
) -> Vec<u128> {
    let mut kernel_us = vec![0u128; reqs.len()];
    if !reqs.iter().any(|r| r.session.is_some()) {
        return kernel_us;
    }
    let mut jobs: Vec<(usize, Mat, SessionKv)> = Vec::new();
    for (slot, r) in reqs.iter().enumerate() {
        let Some(s) = r.session else { continue };
        // one bounded lock hold per request, released before scoring
        let store = sessions.lock().unwrap();
        let Some(kv) = store.pool().peek(s.id) else { continue };
        if kv.is_empty() {
            continue;
        }
        let Some(q) = store.featurize_queries(s.id, KERNEL_QUERY_ROWS) else { continue };
        jobs.push((slot, q, kv.clone()));
    }
    if jobs.is_empty() {
        return kernel_us;
    }
    let cfg = HadAttnConfig { n_top: KERNEL_TOP_N, temp: 1.0 };
    let timed = parallel_map_n(workers, &jobs, |_, (slot, q, kv)| {
        let t0 = Instant::now();
        let out = crate::binary::had_attention_paged(q, kv, &cfg);
        std::hint::black_box(&out);
        (*slot, t0.elapsed().as_micros())
    });
    for (slot, us) in timed {
        kernel_us[slot] = us;
        metrics.record_kernel(us);
    }
    kernel_us
}

fn scheduler_main(
    shared: Arc<Shared>,
    engine: EngineHandle,
    models: Vec<ServingModel>,
    metrics: Arc<Metrics>,
    sessions: Arc<Mutex<SessionStore>>,
    kernel_workers: usize,
) {
    let mut served = 0u64;
    loop {
        // collect a ready batch under the lock
        let work: Option<(usize, Vec<Request>)> = {
            let mut queues = shared.queues.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) {
                    // drain everything remaining before exit
                    if let Some(i) = (0..queues.len()).find(|&i| !queues[i].is_empty()) {
                        let reqs = queues[i].drain_batch();
                        break Some((i, reqs));
                    }
                    break None;
                }
                let now = Instant::now();
                if let Some(i) = (0..queues.len()).find(|&i| queues[i].ready(now)) {
                    let reqs = queues[i].drain_batch();
                    break Some((i, reqs));
                }
                // sleep until the nearest deadline (or a notify)
                let timeout = queues
                    .iter()
                    .filter_map(|q| q.next_deadline(now))
                    .min()
                    .unwrap_or(std::time::Duration::from_millis(50));
                let (q, _tmo) = shared
                    .cv
                    .wait_timeout(queues, timeout.max(std::time::Duration::from_micros(100)))
                    .unwrap();
                queues = q;
            }
        };
        let Some((idx, reqs)) = work else { break };
        let model = &models[idx];
        let bucket = {
            let queues = shared.queues.lock().unwrap();
            queues[idx].bucket.clone()
        };

        // assemble and execute OUTSIDE the queue lock
        let kernel_us = kernel_pass(kernel_workers, &sessions, &reqs, &metrics);
        let (xs, real) = assemble_padded(&reqs, bucket.n_ctx, bucket.batch, crate::data::PAD);
        let mut inputs: Vec<HostTensor> = model.params.clone();
        inputs.push(HostTensor::i32(vec![bucket.batch, bucket.n_ctx], xs));
        inputs.push(HostTensor::vec_f32(model.sigma_q.clone()));
        inputs.push(HostTensor::vec_f32(model.sigma_k.clone()));
        inputs.push(HostTensor::scalar_f32(model.n_top));
        let artifact = format!("{}__{}", bucket.config, model.fwd);

        match engine.exec(&artifact, inputs) {
            Ok(out) => {
                let logits = out[0].as_f32().unwrap_or(&[]);
                let n_classes = logits.len() / bucket.batch.max(1);
                // record metrics BEFORE replying: a client that sees its
                // response must also see it in a subsequent snapshot
                let lats: Vec<u128> =
                    reqs.iter().map(|r| r.arrival.elapsed().as_micros()).collect();
                metrics.record_batch(&lats, real);
                for ((b, req), latency_us) in reqs.iter().enumerate().zip(&lats) {
                    let row = &logits[b * n_classes..(b + 1) * n_classes];
                    let _ = req.reply.send(Response {
                        id: req.id,
                        pred: argmax(row) as i32,
                        logits: row.to_vec(),
                        bucket: bucket.config.clone(),
                        latency_us: *latency_us,
                        batch_occupancy: real,
                        cached_tokens: req.session.map_or(0, |s| s.cached_tokens),
                        kernel_us: kernel_us[b],
                    });
                    served += 1;
                }
            }
            Err(e) => {
                log_warn!("batch execution failed on {artifact}: {e:#}");
                // drop reply senders: clients observe disconnection
            }
        }
    }
    log_info!("scheduler exiting after {served} responses");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(budget_pages: usize) -> KvCacheConfig {
        // d=16 -> 8 B/token keys; d_v=8 -> 32 B/token values; 4-token pages
        KvCacheConfig { page_tokens: 4, byte_budget: budget_pages * 4 * (8 + 32) }
    }

    #[test]
    fn session_store_incremental_admission() {
        let mut store = SessionStore::new(tiny_cfg(100), 16, 8, 1);
        let a = store.admit(42, &[1, 2, 3, 4]);
        assert_eq!((a.cached_tokens, a.appended_tokens), (0, 4));
        let b = store.admit(42, &[5, 6]);
        assert_eq!((b.cached_tokens, b.appended_tokens), (4, 2));
        assert_eq!(store.history_len(42), 6);
        assert_eq!(store.tokens(42), &[1, 2, 3, 4, 5, 6]);
        assert_eq!(store.kv(42).unwrap().len(), 6);
        let stats = store.pool().stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        store.end_session(42);
        assert_eq!(store.history_len(42), 0);
        assert!(store.kv(42).is_none());
    }

    #[test]
    fn identical_tokens_pack_identically_across_sessions() {
        let mut store = SessionStore::new(tiny_cfg(100), 16, 8, 2);
        store.admit(1, &[7, 8, 9]);
        store.admit(2, &[7, 8, 9]);
        let k1 = store.kv(1).unwrap().key(0).to_vec();
        let k2 = store.kv(2).unwrap().key(0).to_vec();
        assert_eq!(k1, k2, "featurizer must be deterministic per token");
    }

    #[test]
    fn evicted_session_restarts_fresh_and_history_is_bounded() {
        let mut store = SessionStore::new(tiny_cfg(1), 16, 8, 3);
        store.admit(1, &[1, 2, 3, 4]);
        store.admit(2, &[5, 6, 7, 8]); // evicts session 1's page
        assert!(store.kv(1).is_none());
        // eviction dropped the history too: the store stays bounded by
        // the byte budget, not by how many session ids were ever seen
        assert_eq!(store.history_len(1), 0);
        let again = store.admit(1, &[9, 10]);
        // the turn starts a fresh context; cached_tokens == 0 signals it
        assert_eq!((again.cached_tokens, again.appended_tokens), (0, 2));
        assert_eq!(store.history_len(1), 2);
        assert_eq!(store.tokens(1), &[9, 10]);
        assert_eq!(store.kv(1).unwrap().len(), 2);
        assert!(store.pool().stats().evictions >= 1);
    }

    #[test]
    fn featurize_queries_matches_key_featurization_of_tail() {
        let mut store = SessionStore::new(tiny_cfg(100), 16, 8, 5);
        assert!(store.featurize_queries(1, 4).is_none(), "no history yet");
        store.admit(1, &[1, 2, 3, 4, 5, 6]);
        let q = store.featurize_queries(1, 4).unwrap();
        assert_eq!((q.rows, q.cols), (4, 16));
        // queries share the keys' embedding space: packing the query
        // block must reproduce the resident packed keys of the last 4
        // tokens exactly
        let qp = crate::binary::PackedMat::pack(4, 16, &q.data);
        let kv = store.kv(1).unwrap();
        for (i, tok) in (2..6).enumerate() {
            assert_eq!(qp.row(i), kv.key(tok), "token {tok}");
        }
        // n_q larger than the history clamps to the whole history
        assert_eq!(store.featurize_queries(1, 100).unwrap().rows, 6);
    }

    #[test]
    fn kernel_pass_times_session_requests_only() {
        let sessions = Mutex::new(SessionStore::new(tiny_cfg(100), 16, 8, 6));
        let info = sessions.lock().unwrap().admit(3, &[1, 2, 3, 4, 5]);
        let metrics = Metrics::default();
        let mk = |id: u64, session: Option<SessionInfo>| {
            let (tx, rx) = channel();
            std::mem::forget(rx); // keep the reply channel alive
            Request { id, tokens: vec![1; 5], arrival: Instant::now(), reply: tx, session }
        };
        let reqs = vec![mk(0, None), mk(1, Some(info))];
        let us = kernel_pass(2, &sessions, &reqs, &metrics);
        assert_eq!(us.len(), 2);
        assert_eq!(us[0], 0, "sessionless requests skip the kernel pass");
        assert_eq!(metrics.snapshot().kernel_requests, 1, "one session request scored");
        // a session whose pages are gone is skipped, not an error
        let ghost = SessionInfo { id: 999, cached_tokens: 0, appended_tokens: 1 };
        let us2 = kernel_pass(2, &sessions, &[mk(2, Some(ghost))], &metrics);
        assert_eq!(us2, vec![0]);
        assert_eq!(metrics.snapshot().kernel_requests, 1);
    }

    #[test]
    fn empty_append_is_a_pure_hit() {
        let mut store = SessionStore::new(tiny_cfg(100), 16, 8, 4);
        store.admit(9, &[1, 2]);
        let a = store.admit(9, &[]);
        assert_eq!((a.cached_tokens, a.appended_tokens), (2, 0));
        assert_eq!(store.kv(9).unwrap().len(), 2);
    }
}
