//! Length-bucket router: maps request length -> the smallest compiled
//! context bucket that fits (one PJRT executable per bucket, as one CUDA
//! graph per shape in GPU serving stacks).

use crate::coordinator::request::RejectReason;

/// One servable bucket: a config name + its context/batch geometry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub config: String,
    pub n_ctx: usize,
    pub batch: usize,
}

#[derive(Clone, Debug)]
pub struct Router {
    /// sorted ascending by n_ctx
    buckets: Vec<Bucket>,
}

impl Router {
    pub fn new(mut buckets: Vec<Bucket>) -> Router {
        assert!(!buckets.is_empty(), "router needs at least one bucket");
        buckets.sort_by_key(|b| b.n_ctx);
        Router { buckets }
    }

    /// The standard bucket set over the longqa configs.
    pub fn longqa_default() -> Router {
        Router::new(
            [(128usize, 16usize), (256, 16), (512, 8), (1024, 4)]
                .iter()
                .map(|&(n, b)| Bucket {
                    config: format!("longqa_{n}"),
                    n_ctx: n,
                    batch: b,
                })
                .collect(),
        )
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Smallest bucket with n_ctx >= len.
    pub fn route(&self, len: usize) -> Result<&Bucket, RejectReason> {
        self.route_idx(len).map(|i| &self.buckets[i])
    }

    /// Index of the smallest fitting bucket (the queue index the server
    /// admits into).
    pub fn route_idx(&self, len: usize) -> Result<usize, RejectReason> {
        self.buckets
            .iter()
            .position(|b| b.n_ctx >= len)
            .ok_or(RejectReason::TooLong)
    }

    /// Session-aware admission: a multi-turn request executes over its
    /// full resident sequence (cached prefix + appended suffix), so it is
    /// routed by the TOTAL length even though only the suffix is new
    /// work. Overflow-checked so a hostile `cached + appended` cannot
    /// wrap into a small bucket.
    pub fn route_session(
        &self,
        cached_tokens: usize,
        appended_tokens: usize,
    ) -> Result<&Bucket, RejectReason> {
        self.route_session_idx(cached_tokens, appended_tokens)
            .map(|i| &self.buckets[i])
    }

    /// Index form of `route_session` (what `Server::submit_session` uses).
    pub fn route_session_idx(
        &self,
        cached_tokens: usize,
        appended_tokens: usize,
    ) -> Result<usize, RejectReason> {
        let total = cached_tokens
            .checked_add(appended_tokens)
            .ok_or(RejectReason::TooLong)?;
        self.route_idx(total)
    }

    pub fn max_ctx(&self) -> usize {
        self.buckets.last().unwrap().n_ctx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{quickcheck, usize_in};

    #[test]
    fn routes_to_smallest_fitting() {
        let r = Router::longqa_default();
        assert_eq!(r.route(10).unwrap().n_ctx, 128);
        assert_eq!(r.route(128).unwrap().n_ctx, 128);
        assert_eq!(r.route(129).unwrap().n_ctx, 256);
        assert_eq!(r.route(1000).unwrap().n_ctx, 1024);
        assert_eq!(r.route(1025).unwrap_err(), RejectReason::TooLong);
    }

    #[test]
    fn routing_invariants_property() {
        // for any length <= max: the chosen bucket fits AND no smaller
        // bucket fits (minimality) — the core router invariant.
        let r = Router::longqa_default();
        quickcheck(&usize_in(1, 1024), |&len| {
            let b = r.route(len).unwrap();
            let fits = b.n_ctx >= len;
            let minimal = r
                .buckets()
                .iter()
                .filter(|c| c.n_ctx >= len)
                .all(|c| c.n_ctx >= b.n_ctx);
            fits && minimal
        });
    }

    #[test]
    fn session_routing_uses_total_length() {
        let r = Router::longqa_default();
        // 120 cached + 20 appended = 140 total -> 256 bucket, not 128
        assert_eq!(r.route_session(120, 20).unwrap().n_ctx, 256);
        assert_eq!(r.route_session(0, 128).unwrap().n_ctx, 128);
        assert_eq!(r.route_session(1024, 1).unwrap_err(), RejectReason::TooLong);
        assert_eq!(r.route_session(usize::MAX, 2).unwrap_err(), RejectReason::TooLong);
    }

    #[test]
    fn buckets_sorted() {
        let r = Router::new(vec![
            Bucket { config: "b".into(), n_ctx: 512, batch: 4 },
            Bucket { config: "a".into(), n_ctx: 128, batch: 8 },
        ]);
        assert_eq!(r.buckets()[0].n_ctx, 128);
    }
}
