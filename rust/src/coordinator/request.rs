//! Request/response types for the long-context serving engine.

use std::sync::mpsc::{Sender, SyncSender};
use std::time::Instant;

use crate::generate::{GenState, StreamEvent};

/// Session context attached to a multi-turn request admitted through
/// `Server::submit_session`: identifies the KV-cache session and records
/// how much of the sequence was already resident at admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionInfo {
    pub id: u64,
    /// tokens whose packed pages were already resident (reused work)
    pub cached_tokens: usize,
    /// tokens newly packed at admission (this turn's work)
    pub appended_tokens: usize,
}

/// A classification request over a token sequence (the paper's motivating
/// workload: long-context QA served at batch).
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub arrival: Instant,
    pub reply: Sender<Response>,
    /// Present when admitted via the session path.
    pub session: Option<SessionInfo>,
    /// Trace identity from the admission-boundary sampling decision
    /// (`obs::sample_request`). `SpanId::NONE` when the request was not
    /// sampled — every stage span keyed off it is then a no-op.
    pub trace: crate::obs::SpanId,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// argmax class
    pub pred: i32,
    /// full logits row
    pub logits: Vec<f32>,
    /// which length bucket served it
    pub bucket: String,
    /// end-to-end latency (arrival -> response ready)
    pub latency_us: u128,
    /// how many real requests shared the executed batch
    pub batch_occupancy: usize,
    /// tokens served from resident KV pages (0 for sessionless requests)
    pub cached_tokens: usize,
    /// CPU time the blocked XNOR-popcount kernel spent scoring this
    /// request's decode segment (0 when the batch executed on the PJRT
    /// path, where no CPU kernel runs)
    pub kernel_us: u128,
    /// total CPU time the serving backend spent decoding this request's
    /// suffix — `kernel_us / decode_us` is the per-request kernel share
    /// (0 on the PJRT path)
    pub decode_us: u128,
}

/// One admitted generation stream, queued until the continuous-batching
/// scheduler activates it (checks its session's KV out of the pool and
/// prefils in the next tick). The prompt is already part of the session
/// history — `admitted_len` records the history length at admission so
/// retirement can verify the history is still exactly the context this
/// stream extended before appending the generated tokens to it.
pub struct GenAdmit {
    pub id: u64,
    pub session: u64,
    pub state: GenState,
    /// Bounded event channel (`BatchPolicy::stream_event_cap`): a reader
    /// that falls `cap` events behind is disconnected rather than
    /// buffering without bound (`StopReason::Disconnected`).
    pub reply: SyncSender<StreamEvent>,
    pub arrival: Instant,
    /// session history length (including this prompt) at admission
    pub admitted_len: usize,
    /// Trace identity for the stream (see [`Request::trace`]); parent of
    /// every prefill / decode-step / sampling span the stream produces.
    pub trace: crate::obs::SpanId,
}

/// Why a request was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// longer than the largest bucket
    TooLong,
    /// admission queue full (backpressure)
    QueueFull,
    /// engine shutting down
    ShuttingDown,
    /// generation with no context at all (empty history AND empty prompt)
    EmptyGeneration,
    /// operation the active execution backend cannot serve (generation
    /// requires the CPU backend; the legacy PJRT path has no token loop)
    Unsupported,
    /// the admission queue's head has already waited past the queue TTL
    /// (`BatchPolicy::queue_ttl`) — the scheduler is stalled or
    /// saturated, so queueing more work would only time out too
    Timeout,
}

impl RejectReason {
    /// Every variant, for exhaustive wire-code round-trip tests.
    pub const ALL: [RejectReason; 6] = [
        RejectReason::TooLong,
        RejectReason::QueueFull,
        RejectReason::ShuttingDown,
        RejectReason::EmptyGeneration,
        RejectReason::Unsupported,
        RejectReason::Timeout,
    ];

    /// Stable machine-readable code for HTTP error bodies and the net
    /// validators. Part of the wire contract: never rename a code —
    /// clients and `scripts/validate_net.py` key off these, not the
    /// human-facing `Display` strings.
    pub fn wire_code(self) -> &'static str {
        match self {
            RejectReason::TooLong => "too_long",
            RejectReason::QueueFull => "queue_full",
            RejectReason::ShuttingDown => "shutting_down",
            RejectReason::EmptyGeneration => "empty_generation",
            RejectReason::Unsupported => "unsupported",
            RejectReason::Timeout => "timeout",
        }
    }

    /// Inverse of [`RejectReason::wire_code`] (client-side decoding).
    pub fn from_wire_code(code: &str) -> Option<RejectReason> {
        RejectReason::ALL.into_iter().find(|r| r.wire_code() == code)
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::TooLong => write!(f, "sequence exceeds largest context bucket"),
            RejectReason::QueueFull => write!(f, "admission queue full"),
            RejectReason::ShuttingDown => write!(f, "server shutting down"),
            RejectReason::EmptyGeneration => write!(f, "generation needs a non-empty context"),
            RejectReason::Unsupported => write!(f, "unsupported on this execution backend"),
            RejectReason::Timeout => write!(f, "admission queue stalled past its TTL"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reject_wire_codes_round_trip_and_stay_stable() {
        for r in RejectReason::ALL {
            assert_eq!(RejectReason::from_wire_code(r.wire_code()), Some(r));
        }
        // pin the published strings — renaming one is a breaking change
        assert_eq!(RejectReason::QueueFull.wire_code(), "queue_full");
        assert_eq!(RejectReason::TooLong.wire_code(), "too_long");
        assert_eq!(RejectReason::ShuttingDown.wire_code(), "shutting_down");
        assert_eq!(RejectReason::EmptyGeneration.wire_code(), "empty_generation");
        assert_eq!(RejectReason::Unsupported.wire_code(), "unsupported");
        assert_eq!(RejectReason::Timeout.wire_code(), "timeout");
        assert_eq!(RejectReason::from_wire_code("nonsense"), None);
        let codes: std::collections::BTreeSet<_> =
            RejectReason::ALL.iter().map(|r| r.wire_code()).collect();
        assert_eq!(codes.len(), RejectReason::ALL.len(), "codes must be distinct");
    }
}
