//! L3 serving coordinator: the paper's motivating workload (long-context
//! inference) served through length-bucketed routing, dynamic batching,
//! and the CPU bitpacked serving backend (`serve::HadBackend`; the PJRT
//! engine remains as a legacy path / optional cross-check), with
//! backpressure and metrics.
//!
//! Since the generation subsystem landed, the scheduler is a
//! token-granular continuous-batching loop: classification-style batch
//! turns flush exactly as before, while generation streams admitted via
//! `Server::submit_generate` hold one of `BatchPolicy::max_streams`
//! tickets and contribute ONE decode step per scheduler tick — new
//! admissions prefill in the same pass, tokens stream to clients as
//! `generate::StreamEvent`s the moment they are sampled, and finished
//! streams retire with an explicit `generate::StopReason` (stop token,
//! token budget, context/KV pressure, client disconnect). TTFT and
//! inter-token latency percentiles land in `Metrics` next to the batch
//! latency numbers.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{assemble_padded, BatchPolicy, BucketQueue, StreamQueue};
pub use metrics::{Metrics, Snapshot};
pub use request::{GenAdmit, RejectReason, Request, Response, SessionInfo};
pub use router::{Bucket, Router};
pub use server::{Server, ServingModel, SessionStore};
