//! L3 serving coordinator: the paper's motivating workload (long-context
//! inference) served through length-bucketed routing, dynamic batching,
//! and the CPU bitpacked serving backend (`serve::HadBackend`; the PJRT
//! engine remains as a legacy path / optional cross-check), with
//! backpressure and metrics.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{assemble_padded, BatchPolicy, BucketQueue};
pub use metrics::{Metrics, Snapshot};
pub use request::{RejectReason, Request, Response, SessionInfo};
pub use router::{Bucket, Router};
pub use server::{Server, ServingModel, SessionStore};
