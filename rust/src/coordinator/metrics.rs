//! Serving metrics: latency percentiles, throughput, batch occupancy.
//!
//! Backed by the `obs::registry` instruments: every timing series is a
//! bounded log-bucketed histogram (O(1) memory under sustained load —
//! the old `Vec<u128>` sample buffers grew per-request forever), counters
//! and gauges are lock-free atomics. The `Snapshot` surface is unchanged;
//! percentiles follow the same `util::bench::percentile_us` convention
//! and are exact for sub-millisecond values (the histogram's linear
//! range), within one bucket (≤6.25%) above.

use std::fmt;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::generate::StopReason;
use crate::kvcache::CacheStats;
use crate::obs::{Counter, Gauge, Histogram, Registry};

/// Wall-clock anchors that can't be counters: serving start (for req/s)
/// and the first/latest generated-token instants (for tok/s over the
/// generating span only).
#[derive(Debug, Default)]
struct Clocks {
    started: Option<Instant>,
    gen_started: Option<Instant>,
    gen_last: Option<Instant>,
}

/// Thread-safe metrics sink shared by batcher and server threads.
pub struct Metrics {
    registry: Registry,
    // timing histograms
    latency: Arc<Histogram>,
    kernel: Arc<Histogram>,
    decode: Arc<Histogram>,
    ttft: Arc<Histogram>,
    inter_token: Arc<Histogram>,
    tick: Arc<Histogram>,
    // counters
    requests: Arc<Counter>,
    batches: Arc<Counter>,
    rejected: Arc<Counter>,
    occupancy_sum: Arc<Counter>,
    session_requests: Arc<Counter>,
    cache_hit_tokens: Arc<Counter>,
    cache_miss_tokens: Arc<Counter>,
    gen_streams: Arc<Counter>,
    gen_tokens: Arc<Counter>,
    gen_budget_stops: Arc<Counter>,
    // robustness counters
    deadline_exceeded: Arc<Counter>,
    drain_shutdowns: Arc<Counter>,
    stream_errors: Arc<Counter>,
    slow_reader_disconnects: Arc<Counter>,
    faults_injected: Arc<Counter>,
    decode_errors: Arc<Counter>,
    admission_deferrals: Arc<Counter>,
    // net-layer counters (HTTP front-end; zero when serving in-process)
    net_connections: Arc<Counter>,
    net_requests: Arc<Counter>,
    net_parse_errors: Arc<Counter>,
    net_slow_writes: Arc<Counter>,
    // spill-tier counters (zero without a spill store); fed from the
    // pool's cumulative `CacheStats` via `sync_spill`, which diffs
    // against `spill_seen` so the registry counters stay monotone
    spill_pages_out: Arc<Counter>,
    spill_pages_in: Arc<Counter>,
    spill_bytes: Arc<Counter>,
    hydrate_hits: Arc<Counter>,
    store_checksum_failures: Arc<Counter>,
    // prefix-sharing counters (zero with sharing off); same
    // cumulative-diff feed as the spill counters
    shared_pages: Arc<Counter>,
    prefix_hits: Arc<Counter>,
    prefix_tokens_reused: Arc<Counter>,
    cow_copies: Arc<Counter>,
    spill_seen: Mutex<CacheStats>,
    // gauges (absolute values, last write wins)
    cache_bytes: Arc<Gauge>,
    cache_evictions: Arc<Gauge>,
    queue_depth: Arc<Gauge>,
    active_streams: Arc<Gauge>,
    clocks: Mutex<Clocks>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        let registry = Registry::new();
        Metrics {
            latency: registry.histogram("latency_us"),
            kernel: registry.histogram("kernel_us"),
            decode: registry.histogram("decode_us"),
            ttft: registry.histogram("ttft_us"),
            inter_token: registry.histogram("inter_token_us"),
            tick: registry.histogram("tick_us"),
            requests: registry.counter("requests"),
            batches: registry.counter("batches"),
            rejected: registry.counter("rejected"),
            occupancy_sum: registry.counter("occupancy_sum"),
            session_requests: registry.counter("session_requests"),
            cache_hit_tokens: registry.counter("cache_hit_tokens"),
            cache_miss_tokens: registry.counter("cache_miss_tokens"),
            gen_streams: registry.counter("gen_streams"),
            gen_tokens: registry.counter("gen_tokens"),
            gen_budget_stops: registry.counter("gen_budget_stops"),
            deadline_exceeded: registry.counter("deadline_exceeded"),
            drain_shutdowns: registry.counter("drain_shutdowns"),
            stream_errors: registry.counter("stream_errors"),
            slow_reader_disconnects: registry.counter("slow_reader_disconnects"),
            faults_injected: registry.counter("faults_injected"),
            decode_errors: registry.counter("decode_errors"),
            admission_deferrals: registry.counter("admission_deferrals"),
            net_connections: registry.counter("net_connections"),
            net_requests: registry.counter("net_requests"),
            net_parse_errors: registry.counter("net_parse_errors"),
            net_slow_writes: registry.counter("net_slow_writes"),
            spill_pages_out: registry.counter("spill_pages_out"),
            spill_pages_in: registry.counter("spill_pages_in"),
            spill_bytes: registry.counter("spill_bytes"),
            hydrate_hits: registry.counter("hydrate_hits"),
            store_checksum_failures: registry.counter("store_checksum_failures"),
            shared_pages: registry.counter("shared_pages"),
            prefix_hits: registry.counter("prefix_hits"),
            prefix_tokens_reused: registry.counter("prefix_tokens_reused"),
            cow_copies: registry.counter("cow_copies"),
            spill_seen: Mutex::new(CacheStats::default()),
            cache_bytes: registry.gauge("cache_bytes"),
            cache_evictions: registry.gauge("cache_evictions"),
            queue_depth: registry.gauge("queue_depth"),
            active_streams: registry.gauge("active_streams"),
            clocks: Mutex::new(Clocks::default()),
            registry,
        }
    }
}

impl fmt::Debug for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Metrics")
            .field("requests", &self.requests.get())
            .field("batches", &self.batches.get())
            .field("gen_tokens", &self.gen_tokens.get())
            .finish_non_exhaustive()
    }
}

#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub p50_us: u128,
    pub p90_us: u128,
    pub p99_us: u128,
    pub mean_us: f64,
    pub mean_occupancy: f64,
    pub throughput_rps: f64,
    /// requests admitted through the session path
    pub session_requests: u64,
    /// tokens served from resident KV pages across all session admissions
    pub cache_hit_tokens: u64,
    /// tokens packed cold at admission
    pub cache_miss_tokens: u64,
    /// hit_tokens / (hit_tokens + miss_tokens); 0 with no session traffic
    pub cache_hit_rate: f64,
    /// resident pool bytes at the last admission
    pub cache_bytes: u64,
    /// cumulative pool evictions at the last admission
    pub cache_evictions: u64,
    /// popcount backend every kernel request dispatched through
    /// (`binary::simd::KernelBackend::active`, `HAD_KERNEL` override)
    pub kernel_backend: &'static str,
    /// CPU features detected on this host (e.g. "x86_64: popcnt avx2")
    pub cpu_features: String,
    /// requests scored by the CPU kernel during batch decode
    pub kernel_requests: u64,
    /// per-request kernel time percentiles/mean (µs; 0 with no kernel traffic)
    pub kernel_p50_us: u128,
    pub kernel_p99_us: u128,
    pub kernel_mean_us: f64,
    /// requests decoded end-to-end by the CPU serving backend
    pub decode_requests: u64,
    /// per-request backend decode time percentiles/mean (µs)
    pub decode_p50_us: u128,
    pub decode_p99_us: u128,
    pub decode_mean_us: f64,
    /// generation streams retired by the continuous-batching scheduler
    pub gen_streams: u64,
    /// tokens generated across all streams
    pub gen_tokens: u64,
    /// streams retired by context/KV budget pressure (StopReason::Budget)
    pub gen_budget_stops: u64,
    /// streams retired because their wall-clock deadline or queue TTL
    /// elapsed (StopReason::DeadlineExceeded)
    pub deadline_exceeded: u64,
    /// in-flight or queued streams force-retired by a drain shutdown
    /// (StopReason::Shutdown)
    pub drain_shutdowns: u64,
    /// streams retired because their decode step panicked
    /// (StopReason::Error; the panic was isolated)
    pub stream_errors: u64,
    /// streams disconnected because the client stopped draining its
    /// bounded event channel (slow-reader policy)
    pub slow_reader_disconnects: u64,
    /// faults fired by `util::fault` injection sites (0 unless a chaos
    /// plan is active)
    pub faults_injected: u64,
    /// classification batch shards whose decode panicked (requests in
    /// the shard got no response; the batch survived)
    pub decode_errors: u64,
    /// admission rounds in which a queued stream was deferred because
    /// activating it would overcommit the pool's aggregate byte budget
    pub admission_deferrals: u64,
    /// TCP connections accepted by the HTTP front-end
    pub net_connections: u64,
    /// HTTP requests parsed and dispatched (all endpoints)
    pub net_requests: u64,
    /// connections dropped for malformed/oversized HTTP input
    pub net_parse_errors: u64,
    /// chunk writes that hit the write deadline or an injected
    /// `net_write` stall (slow or vanished streaming clients)
    pub net_slow_writes: u64,
    /// chain-pages moved to the disk spill tier instead of destroyed
    pub spill_pages_out: u64,
    /// chain-pages hydrated back from the spill tier at checkout
    pub spill_pages_in: u64,
    /// resident bytes freed by moving stripes to the spill tier
    pub spill_bytes: u64,
    /// checkouts that hydrated at least one page (re-prefill avoided)
    pub hydrate_hits: u64,
    /// spill-store reads that failed verification (fault, IO, checksum)
    pub store_checksum_failures: u64,
    /// chain-pages published to (or deduplicated against) the
    /// cross-session prefix registry
    pub shared_pages: u64,
    /// checkouts/activations that adopted at least one registry stripe
    pub prefix_hits: u64,
    /// prompt tokens whose prefill was skipped by adopting shared pages
    pub prefix_tokens_reused: u64,
    /// per-chain private copies made when a session diverged inside a
    /// shared stripe (copy-on-write)
    pub cow_copies: u64,
    /// time-to-first-token percentiles/mean (µs; admission -> emission)
    pub ttft_p50_us: u128,
    pub ttft_p99_us: u128,
    pub ttft_mean_us: f64,
    /// inter-token latency percentiles/mean (µs; 0 with no multi-token streams)
    pub inter_token_p50_us: u128,
    pub inter_token_p99_us: u128,
    pub inter_token_mean_us: f64,
    /// generated tokens per second of serving wall time
    pub gen_tokens_per_s: f64,
}

fn as_u64(us: u128) -> u64 {
    us.min(u64::MAX as u128) as u64
}

impl Metrics {
    /// The instrument registry backing this sink — the exporter snapshots
    /// it to `metrics.jsonl` while tracing, and new instruments
    /// registered here show up there without touching `Snapshot`.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn record_batch(&self, latencies_us: &[u128], occupancy: usize) {
        {
            let mut c = self.clocks.lock().unwrap();
            if c.started.is_none() {
                c.started = Some(Instant::now());
            }
        }
        for &us in latencies_us {
            self.latency.record(as_u64(us));
        }
        self.requests.add(latencies_us.len() as u64);
        self.batches.inc();
        self.occupancy_sum.add(occupancy as u64);
    }

    pub fn record_reject(&self) {
        self.rejected.inc();
    }

    /// One session admission: `hit_tokens` were already resident,
    /// `miss_tokens` were packed cold this turn.
    pub fn record_session(&self, hit_tokens: usize, miss_tokens: usize) {
        self.session_requests.inc();
        self.cache_hit_tokens.add(hit_tokens as u64);
        self.cache_miss_tokens.add(miss_tokens as u64);
    }

    /// Refresh the pool gauges (absolute values, taken after admission).
    pub fn update_cache_pool(&self, bytes: usize, evictions: u64) {
        self.cache_bytes.set(bytes as u64);
        self.cache_evictions.set(evictions);
    }

    /// One scheduler tick: duration plus the loop's load gauges (admission
    /// queue depth and live continuous-batching streams). Lands in the
    /// registry (and the exporter's JSONL snapshots), not in `Snapshot`.
    pub fn record_tick(&self, us: u128, queue_depth: usize, active_streams: usize) {
        self.tick.record(as_u64(us));
        self.queue_depth.set(queue_depth as u64);
        self.active_streams.set(active_streams as u64);
    }

    /// One request's share of batch decode: the CPU time the blocked
    /// XNOR-popcount kernel spent scoring its segment.
    pub fn record_kernel(&self, us: u128) {
        self.kernel.record(as_u64(us));
    }

    /// One request's total backend decode time (its suffix's forward).
    pub fn record_decode(&self, us: u128) {
        self.decode.record(as_u64(us));
    }

    /// A stream's first generated token: `us` since admission (TTFT —
    /// includes queueing, activation, and the prefill decode).
    pub fn record_first_token(&self, us: u128) {
        self.touch_gen_clock();
        self.ttft.record(as_u64(us));
        self.gen_tokens.inc();
    }

    /// Gap between consecutive generated tokens of one stream.
    pub fn record_inter_token(&self, us: u128) {
        self.touch_gen_clock();
        self.inter_token.record(as_u64(us));
        self.gen_tokens.inc();
    }

    fn touch_gen_clock(&self) {
        let mut c = self.clocks.lock().unwrap();
        let now = Instant::now();
        if c.gen_started.is_none() {
            c.gen_started = Some(now);
        }
        c.gen_last = Some(now);
    }

    /// A generation stream retired, classified by its stop reason:
    /// budget stops, deadline misses, drain shutdowns, and isolated
    /// panics get their own counters on top of the stream total.
    pub fn record_stream_retired(&self, reason: StopReason) {
        self.gen_streams.inc();
        match reason {
            StopReason::Budget => self.gen_budget_stops.inc(),
            StopReason::DeadlineExceeded => self.deadline_exceeded.inc(),
            StopReason::Shutdown => self.drain_shutdowns.inc(),
            StopReason::Error => self.stream_errors.inc(),
            StopReason::StopToken | StopReason::MaxTokens | StopReason::Disconnected => {}
        }
    }

    /// A client fell `stream_event_cap` events behind and was
    /// disconnected (always paired with a Disconnected retirement).
    pub fn record_slow_reader(&self) {
        self.slow_reader_disconnects.inc();
    }

    /// One injected fault fired at an injection site.
    pub fn record_fault(&self) {
        self.faults_injected.inc();
    }

    /// A classification batch shard panicked mid-decode (isolated).
    pub fn record_decode_error(&self) {
        self.decode_errors.inc();
    }

    /// A queued stream was held back this round because activating it
    /// would push aggregate checked-out bytes past the pool budget.
    pub fn record_admission_deferral(&self) {
        self.admission_deferrals.inc();
    }

    /// The HTTP listener accepted a TCP connection.
    pub fn record_net_connection(&self) {
        self.net_connections.inc();
    }

    /// One HTTP request parsed and dispatched (any endpoint).
    pub fn record_net_request(&self) {
        self.net_requests.inc();
    }

    /// A connection sent malformed/oversized HTTP and was dropped.
    pub fn record_net_parse_error(&self) {
        self.net_parse_errors.inc();
    }

    /// A streamed chunk write hit the write deadline (or an injected
    /// `net_write` stall) — the client is slow or gone.
    pub fn record_net_slow_write(&self) {
        self.net_slow_writes.inc();
    }

    /// Fold the pool's cumulative spill counters into the registry.
    /// `stats` is a monotone snapshot (`PagePool::stats`); this diffs
    /// against the last-seen values under a lock, so concurrent callers
    /// (decode shards, the retire path) never double-count a delta.
    pub fn sync_spill(&self, stats: &CacheStats) {
        let mut seen = self.spill_seen.lock().unwrap();
        self.spill_pages_out.add(stats.spill_pages_out.saturating_sub(seen.spill_pages_out));
        self.spill_pages_in.add(stats.spill_pages_in.saturating_sub(seen.spill_pages_in));
        self.spill_bytes.add(stats.spill_bytes.saturating_sub(seen.spill_bytes));
        self.hydrate_hits.add(stats.hydrate_hits.saturating_sub(seen.hydrate_hits));
        self.store_checksum_failures
            .add(stats.store_checksum_failures.saturating_sub(seen.store_checksum_failures));
        self.shared_pages.add(stats.shared_pages.saturating_sub(seen.shared_pages));
        self.prefix_hits.add(stats.prefix_hits.saturating_sub(seen.prefix_hits));
        self.prefix_tokens_reused
            .add(stats.prefix_tokens_reused.saturating_sub(seen.prefix_tokens_reused));
        self.cow_copies.add(stats.cow_copies.saturating_sub(seen.cow_copies));
        *seen = *stats;
    }

    pub fn snapshot(&self) -> Snapshot {
        let (started, gen_span) = {
            let c = self.clocks.lock().unwrap();
            let span = match (c.gen_started, c.gen_last) {
                (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
                _ => 0.0,
            };
            (c.started, span)
        };
        let elapsed = started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let requests = self.requests.get();
        let batches = self.batches.get();
        let gen_tokens = self.gen_tokens.get();
        let hit = self.cache_hit_tokens.get();
        let miss = self.cache_miss_tokens.get();
        Snapshot {
            requests,
            batches,
            rejected: self.rejected.get(),
            p50_us: self.latency.percentile(0.50) as u128,
            p90_us: self.latency.percentile(0.90) as u128,
            p99_us: self.latency.percentile(0.99) as u128,
            mean_us: self.latency.mean(),
            mean_occupancy: if batches == 0 {
                0.0
            } else {
                self.occupancy_sum.get() as f64 / batches as f64
            },
            throughput_rps: if elapsed > 0.0 { requests as f64 / elapsed } else { 0.0 },
            session_requests: self.session_requests.get(),
            cache_hit_tokens: hit,
            cache_miss_tokens: miss,
            cache_hit_rate: {
                let total = hit + miss;
                if total == 0 {
                    0.0
                } else {
                    hit as f64 / total as f64
                }
            },
            cache_bytes: self.cache_bytes.get(),
            cache_evictions: self.cache_evictions.get(),
            kernel_backend: crate::binary::KernelBackend::active().name(),
            cpu_features: crate::binary::simd::cpu_features(),
            kernel_requests: self.kernel.count(),
            kernel_p50_us: self.kernel.percentile(0.50) as u128,
            kernel_p99_us: self.kernel.percentile(0.99) as u128,
            kernel_mean_us: self.kernel.mean(),
            decode_requests: self.decode.count(),
            decode_p50_us: self.decode.percentile(0.50) as u128,
            decode_p99_us: self.decode.percentile(0.99) as u128,
            decode_mean_us: self.decode.mean(),
            gen_streams: self.gen_streams.get(),
            gen_tokens,
            gen_budget_stops: self.gen_budget_stops.get(),
            deadline_exceeded: self.deadline_exceeded.get(),
            drain_shutdowns: self.drain_shutdowns.get(),
            stream_errors: self.stream_errors.get(),
            slow_reader_disconnects: self.slow_reader_disconnects.get(),
            faults_injected: self.faults_injected.get(),
            decode_errors: self.decode_errors.get(),
            admission_deferrals: self.admission_deferrals.get(),
            net_connections: self.net_connections.get(),
            net_requests: self.net_requests.get(),
            net_parse_errors: self.net_parse_errors.get(),
            net_slow_writes: self.net_slow_writes.get(),
            spill_pages_out: self.spill_pages_out.get(),
            spill_pages_in: self.spill_pages_in.get(),
            spill_bytes: self.spill_bytes.get(),
            hydrate_hits: self.hydrate_hits.get(),
            store_checksum_failures: self.store_checksum_failures.get(),
            shared_pages: self.shared_pages.get(),
            prefix_hits: self.prefix_hits.get(),
            prefix_tokens_reused: self.prefix_tokens_reused.get(),
            cow_copies: self.cow_copies.get(),
            ttft_p50_us: self.ttft.percentile(0.50) as u128,
            ttft_p99_us: self.ttft.percentile(0.99) as u128,
            ttft_mean_us: self.ttft.mean(),
            inter_token_p50_us: self.inter_token.percentile(0.50) as u128,
            inter_token_p99_us: self.inter_token.percentile(0.99) as u128,
            inter_token_mean_us: self.inter_token.mean(),
            gen_tokens_per_s: {
                // first-to-last token span: excludes pre-stream traffic
                // and anything after the final token (0 until a second
                // token makes the span non-degenerate)
                if gen_span > 0.0 {
                    gen_tokens as f64 / gen_span
                } else {
                    0.0
                }
            },
        }
    }
}

impl Snapshot {
    pub fn print(&self, label: &str) {
        println!(
            "{label}: {} reqs in {} batches (occ {:.2}), rejected {} | latency p50 {:.2} ms p90 {:.2} ms p99 {:.2} ms mean {:.2} ms | {:.1} req/s",
            self.requests,
            self.batches,
            self.mean_occupancy,
            self.rejected,
            self.p50_us as f64 / 1e3,
            self.p90_us as f64 / 1e3,
            self.p99_us as f64 / 1e3,
            self.mean_us / 1e3,
            self.throughput_rps,
        );
        if self.session_requests > 0 {
            println!(
                "{label}: kv-cache: {} session reqs | {} hit / {} miss tokens ({:.1}% hit) | {} KiB resident, {} evictions",
                self.session_requests,
                self.cache_hit_tokens,
                self.cache_miss_tokens,
                100.0 * self.cache_hit_rate,
                self.cache_bytes / 1024,
                self.cache_evictions,
            );
        }
        if self.kernel_requests > 0 {
            println!(
                "{label}: kernel: {} reqs scored | p50 {:.2} ms p99 {:.2} ms mean {:.2} ms per request | backend {} ({})",
                self.kernel_requests,
                self.kernel_p50_us as f64 / 1e3,
                self.kernel_p99_us as f64 / 1e3,
                self.kernel_mean_us / 1e3,
                self.kernel_backend,
                self.cpu_features,
            );
        }
        if self.gen_streams > 0 || self.gen_tokens > 0 {
            println!(
                "{label}: generate: {} streams, {} tokens ({} budget-stopped) | ttft p50 {:.2} ms p99 {:.2} ms | inter-token p50 {:.2} ms p99 {:.2} ms | {:.1} tok/s",
                self.gen_streams,
                self.gen_tokens,
                self.gen_budget_stops,
                self.ttft_p50_us as f64 / 1e3,
                self.ttft_p99_us as f64 / 1e3,
                self.inter_token_p50_us as f64 / 1e3,
                self.inter_token_p99_us as f64 / 1e3,
                self.gen_tokens_per_s,
            );
        }
        let robustness = self.deadline_exceeded
            + self.drain_shutdowns
            + self.stream_errors
            + self.slow_reader_disconnects
            + self.faults_injected
            + self.decode_errors
            + self.admission_deferrals;
        if robustness > 0 {
            println!(
                "{label}: robustness: {} deadline-exceeded, {} drain-shutdown, {} stream-error, {} slow-reader, {} decode-error, {} admission-deferral | {} faults injected",
                self.deadline_exceeded,
                self.drain_shutdowns,
                self.stream_errors,
                self.slow_reader_disconnects,
                self.decode_errors,
                self.admission_deferrals,
                self.faults_injected,
            );
        }
        if self.spill_pages_out > 0 || self.store_checksum_failures > 0 {
            println!(
                "{label}: spill: {} pages out ({} KiB freed), {} pages in across {} hydrating checkouts | {} checksum failures",
                self.spill_pages_out,
                self.spill_bytes / 1024,
                self.spill_pages_in,
                self.hydrate_hits,
                self.store_checksum_failures,
            );
        }
        if self.shared_pages > 0 || self.cow_copies > 0 {
            println!(
                "{label}: prefix-sharing: {} shared pages | {} adoptions reusing {} tokens | {} COW copies",
                self.shared_pages,
                self.prefix_hits,
                self.prefix_tokens_reused,
                self.cow_copies,
            );
        }
        if self.net_connections > 0 || self.net_requests > 0 {
            println!(
                "{label}: net: {} connections, {} requests | {} parse-error, {} slow-write",
                self.net_connections,
                self.net_requests,
                self.net_parse_errors,
                self.net_slow_writes,
            );
        }
        if self.decode_requests > 0 {
            let share = if self.decode_mean_us > 0.0 {
                100.0 * self.kernel_mean_us / self.decode_mean_us
            } else {
                0.0
            };
            println!(
                "{label}: decode: {} reqs served | p50 {:.2} ms p99 {:.2} ms mean {:.2} ms per request | kernel share {share:.1}%",
                self.decode_requests,
                self.decode_p50_us as f64 / 1e3,
                self.decode_p99_us as f64 / 1e3,
                self.decode_mean_us / 1e3,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        let lats: Vec<u128> = (1..=100).collect();
        m.record_batch(&lats, 8);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 1);
        assert_eq!(s.p50_us, 51);
        assert_eq!(s.p99_us, 100);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
    }

    #[test]
    fn occupancy_and_rejections() {
        let m = Metrics::default();
        m.record_batch(&[10, 10], 2);
        m.record_batch(&[10, 10, 10, 10], 4);
        m.record_reject();
        let s = m.snapshot();
        assert_eq!(s.rejected, 1);
        assert!((s.mean_occupancy - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.cache_hit_rate, 0.0);
    }

    #[test]
    fn kernel_timings() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().kernel_requests, 0);
        for us in [10u128, 20, 30, 40] {
            m.record_kernel(us);
        }
        let s = m.snapshot();
        assert_eq!(s.kernel_requests, 4);
        assert_eq!(s.kernel_p50_us, 30);
        assert_eq!(s.kernel_p99_us, 40);
        assert!((s.kernel_mean_us - 25.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_reports_kernel_backend_and_features() {
        use crate::binary::KernelBackend;
        let s = Metrics::default().snapshot();
        assert!(
            KernelBackend::available().iter().any(|b| b.name() == s.kernel_backend),
            "snapshot backend {:?} not in the available set",
            s.kernel_backend
        );
        assert!(s.cpu_features.contains(std::env::consts::ARCH));
    }

    #[test]
    fn decode_timings() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().decode_requests, 0);
        for us in [100u128, 200, 300, 400] {
            m.record_decode(us);
        }
        let s = m.snapshot();
        assert_eq!(s.decode_requests, 4);
        assert_eq!(s.decode_p50_us, 300);
        assert_eq!(s.decode_p99_us, 400);
        assert!((s.decode_mean_us - 250.0).abs() < 1e-9);
    }

    #[test]
    fn generation_timings() {
        let m = Metrics::default();
        let empty = m.snapshot();
        assert_eq!((empty.gen_streams, empty.gen_tokens), (0, 0));
        assert_eq!(empty.ttft_p50_us, 0);
        assert_eq!(empty.gen_tokens_per_s, 0.0);
        // two streams: 3 + 2 tokens (a real gap so the first-to-last
        // token span is non-degenerate)
        m.record_first_token(500);
        m.record_inter_token(40);
        m.record_inter_token(60);
        m.record_stream_retired(StopReason::StopToken);
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.record_first_token(900);
        m.record_inter_token(80);
        m.record_stream_retired(StopReason::Budget);
        let s = m.snapshot();
        assert_eq!(s.gen_streams, 2);
        assert_eq!(s.gen_tokens, 5);
        assert_eq!(s.gen_budget_stops, 1);
        assert_eq!(s.ttft_p50_us, 900);
        assert_eq!(s.ttft_p99_us, 900);
        assert!((s.ttft_mean_us - 700.0).abs() < 1e-9);
        assert_eq!(s.inter_token_p50_us, 60);
        assert_eq!(s.inter_token_p99_us, 80);
        assert!((s.inter_token_mean_us - 60.0).abs() < 1e-9);
        assert!(s.gen_tokens_per_s > 0.0, "throughput clock started");
        // throughput measures the first-to-last TOKEN span: idle time
        // between the last token and the snapshot must not deflate it
        std::thread::sleep(std::time::Duration::from_millis(200));
        let late = m.snapshot();
        assert!(
            late.gen_tokens_per_s > 25.0,
            "post-generation idle time deflated throughput: {}",
            late.gen_tokens_per_s
        );
    }

    #[test]
    fn robustness_counters_classify_retirements() {
        let m = Metrics::default();
        let empty = m.snapshot();
        assert_eq!(empty.deadline_exceeded, 0);
        assert_eq!(empty.faults_injected, 0);
        m.record_stream_retired(StopReason::DeadlineExceeded);
        m.record_stream_retired(StopReason::DeadlineExceeded);
        m.record_stream_retired(StopReason::Shutdown);
        m.record_stream_retired(StopReason::Error);
        m.record_stream_retired(StopReason::Disconnected);
        m.record_stream_retired(StopReason::MaxTokens);
        m.record_slow_reader();
        m.record_fault();
        m.record_fault();
        m.record_fault();
        m.record_decode_error();
        m.record_admission_deferral();
        let s = m.snapshot();
        assert_eq!(s.gen_streams, 6, "every retirement counts a stream");
        assert_eq!(s.deadline_exceeded, 2);
        assert_eq!(s.drain_shutdowns, 1);
        assert_eq!(s.stream_errors, 1);
        assert_eq!(s.gen_budget_stops, 0);
        assert_eq!(s.slow_reader_disconnects, 1);
        assert_eq!(s.faults_injected, 3);
        assert_eq!(s.decode_errors, 1);
        assert_eq!(s.admission_deferrals, 1);
        // and they land in the registry for the trace exporter
        let snap = format!("{}", m.registry().snapshot_json());
        assert!(snap.contains("\"deadline_exceeded\":2"));
        assert!(snap.contains("\"faults_injected\":3"));
    }

    #[test]
    fn net_counters_surface_with_pinned_names() {
        let m = Metrics::default();
        let empty = m.snapshot();
        assert_eq!(empty.net_connections, 0);
        assert_eq!(empty.net_requests, 0);
        m.record_net_connection();
        m.record_net_connection();
        m.record_net_request();
        m.record_net_request();
        m.record_net_request();
        m.record_net_parse_error();
        m.record_net_slow_write();
        let s = m.snapshot();
        assert_eq!(s.net_connections, 2);
        assert_eq!(s.net_requests, 3);
        assert_eq!(s.net_parse_errors, 1);
        assert_eq!(s.net_slow_writes, 1);
        // the registry names are the wire contract for metrics.jsonl and
        // GET /v1/metrics — pin them
        let snap = format!("{}", m.registry().snapshot_json());
        assert!(snap.contains("\"net_connections\":2"));
        assert!(snap.contains("\"net_requests\":3"));
        assert!(snap.contains("\"net_parse_errors\":1"));
        assert!(snap.contains("\"net_slow_writes\":1"));
    }

    #[test]
    fn spill_counters_delta_sync_with_pinned_names() {
        let m = Metrics::default();
        let empty = m.snapshot();
        assert_eq!(empty.spill_pages_out, 0);
        assert_eq!(empty.hydrate_hits, 0);
        // pool stats are cumulative; syncing the same snapshot twice
        // must not double-count
        let stats = CacheStats {
            spill_pages_out: 8,
            spill_pages_in: 4,
            spill_bytes: 4096,
            hydrate_hits: 2,
            store_checksum_failures: 1,
            ..CacheStats::default()
        };
        m.sync_spill(&stats);
        m.sync_spill(&stats);
        let s = m.snapshot();
        assert_eq!(s.spill_pages_out, 8);
        assert_eq!(s.spill_pages_in, 4);
        assert_eq!(s.spill_bytes, 4096);
        assert_eq!(s.hydrate_hits, 2);
        assert_eq!(s.store_checksum_failures, 1);
        // a later, larger snapshot adds only the delta
        let grown = CacheStats { spill_pages_out: 11, ..stats };
        m.sync_spill(&grown);
        assert_eq!(m.snapshot().spill_pages_out, 11);
        // the registry names are the wire contract for metrics.jsonl and
        // GET /v1/metrics — pin them
        let snap = format!("{}", m.registry().snapshot_json());
        assert!(snap.contains("\"spill_pages_out\":11"));
        assert!(snap.contains("\"spill_pages_in\":4"));
        assert!(snap.contains("\"spill_bytes\":4096"));
        assert!(snap.contains("\"hydrate_hits\":2"));
        assert!(snap.contains("\"store_checksum_failures\":1"));
    }

    #[test]
    fn prefix_sharing_counters_delta_sync_with_pinned_names() {
        let m = Metrics::default();
        let empty = m.snapshot();
        assert_eq!(
            (empty.shared_pages, empty.prefix_hits, empty.prefix_tokens_reused, empty.cow_copies),
            (0, 0, 0, 0)
        );
        // pool stats are cumulative; syncing the same snapshot twice
        // must not double-count
        let stats = CacheStats {
            shared_pages: 16,
            prefix_hits: 3,
            prefix_tokens_reused: 24,
            cow_copies: 4,
            ..CacheStats::default()
        };
        m.sync_spill(&stats);
        m.sync_spill(&stats);
        let s = m.snapshot();
        assert_eq!(s.shared_pages, 16);
        assert_eq!(s.prefix_hits, 3);
        assert_eq!(s.prefix_tokens_reused, 24);
        assert_eq!(s.cow_copies, 4);
        // a later, larger snapshot adds only the delta
        let grown = CacheStats { prefix_tokens_reused: 32, ..stats };
        m.sync_spill(&grown);
        assert_eq!(m.snapshot().prefix_tokens_reused, 32);
        // the registry names are the wire contract for metrics.jsonl and
        // GET /v1/metrics — pin them
        let snap = format!("{}", m.registry().snapshot_json());
        assert!(snap.contains("\"shared_pages\":16"));
        assert!(snap.contains("\"prefix_hits\":3"));
        assert!(snap.contains("\"prefix_tokens_reused\":32"));
        assert!(snap.contains("\"cow_copies\":4"));
    }

    #[test]
    fn cache_counters() {
        let m = Metrics::default();
        m.record_session(0, 128); // cold first turn
        m.record_session(128, 16); // warm follow-up
        m.record_session(144, 16);
        m.update_cache_pool(4096, 1);
        let s = m.snapshot();
        assert_eq!(s.session_requests, 3);
        assert_eq!(s.cache_hit_tokens, 272);
        assert_eq!(s.cache_miss_tokens, 160);
        let want = 272.0 / (272.0 + 160.0);
        assert!((s.cache_hit_rate - want).abs() < 1e-12);
        assert_eq!((s.cache_bytes, s.cache_evictions), (4096, 1));
    }

    #[test]
    fn tick_metrics_land_in_registry() {
        let m = Metrics::default();
        m.record_tick(120, 3, 2);
        m.record_tick(80, 1, 4);
        let snap = format!("{}", m.registry().snapshot_json());
        assert!(snap.contains("\"tick_us\""));
        assert!(snap.contains("\"queue_depth\":1"), "gauge holds last write");
        assert!(snap.contains("\"active_streams\":4"));
    }

    #[test]
    fn property_snapshot_percentiles_track_exact_vectors() {
        // Satellite: the histogram-backed snapshot must stay within one
        // bucket's relative error of the exact sorted-Vec percentiles the
        // old unbounded implementation computed — across magnitudes, not
        // just the sub-millisecond linear range.
        use crate::util::bench::percentile_us;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xC0FFEE);
        for case in 0..20 {
            let m = Metrics::default();
            let n = 1 + (rng.next_u64() % 300) as usize;
            let mut vals: Vec<u128> = Vec::with_capacity(n);
            for _ in 0..n {
                let e = rng.next_u64() % 32; // spans ns..hours in µs
                let v = (1u64 << e) + rng.next_u64() % (1u64 << e).max(1);
                vals.push(v as u128);
                m.record_decode(v as u128);
            }
            vals.sort_unstable();
            let s = m.snapshot();
            for (p, got) in [(0.50, s.decode_p50_us), (0.99, s.decode_p99_us)] {
                let exact = percentile_us(&vals, p);
                let tol = Histogram::error_bound(exact as u64) as u128;
                let diff = got.abs_diff(exact);
                assert!(
                    diff <= tol,
                    "case {case} p={p}: snapshot {got} vs exact {exact} (tol {tol})"
                );
            }
            let exact_mean = vals.iter().sum::<u128>() as f64 / vals.len() as f64;
            assert!(
                (s.decode_mean_us - exact_mean).abs() < 1e-6 * exact_mean.max(1.0),
                "mean is tracked exactly (sum/count)"
            );
            assert_eq!(s.decode_requests, n as u64);
        }
    }
}
